"""The paper's Figure 3 application: distributed log processing.

Access -> HTTP(auth) -> FanOut -> HTTP(each log shard, in parallel)
-> Render, authored through the declarative SDK: typed function
declarations, ``sdk.each`` fan-out over the shard fetches, one Platform
front door. Run under a bursty load and watch the PI controller
re-balance compute vs communication cores.

    PYTHONPATH=src python examples/log_processing.py
"""
import numpy as np

from repro import sdk
from repro.core import HttpRequest, HttpResponse, Item


@sdk.function(inputs=("token",), outputs=("auth_req",))
def access(ins):
    return {"auth_req": [Item(HttpRequest(
        "GET", f"http://auth.svc/endpoints?tok={ins['token'][0].data}"))]}


@sdk.function(inputs=("endpoints",), outputs=("log_reqs",))
def fanout(ins):
    return {"log_reqs": [
        Item(HttpRequest("GET", u), key=str(i))
        for i, u in enumerate(str(ins["endpoints"][0].data.body).split())
    ]}


@sdk.function(inputs=("logs",), outputs=("page",))
def render(ins):
    lines = errors = 0
    for it in ins["logs"]:
        body = it.data.body
        text = body.decode() if isinstance(body, bytes) else str(body)
        for line in text.splitlines():
            lines += 1
            errors += "lvl=3" in line
    return {"page": [Item(f"<html>{lines} lines, {errors} errors</html>".encode())]}


def build(platform: sdk.Platform, shards: int = 8) -> sdk.App:
    hosts = [f"logs{i}.svc" for i in range(shards)]
    platform.service(
        "auth.svc",
        lambda req: HttpResponse(200, " ".join(f"http://{h}/tail" for h in hosts)),
        base_latency_s=1e-3,
    )
    rng = np.random.default_rng(0)
    for h in hosts:
        blob = b"\n".join(
            b"2026-07-15T12:00:00 svc=api lvl=%d msg=request" % rng.integers(0, 4)
            for _ in range(200)
        )
        platform.service(h, lambda req, blob=blob: HttpResponse(200, blob),
                         base_latency_s=2e-3, bandwidth_bps=1e9)

    with sdk.composition("log_processing") as app:
        acc = access(token=app.input("token"))
        h1 = sdk.http("auth_call", requests=acc.auth_req)
        fan = fanout(endpoints=h1.responses)
        h2 = sdk.http("fetch_logs", requests=sdk.each(fan.log_reqs))
        ren = render(logs=h2.responses)
        app.output("result", ren.page)
    platform.deploy(app)
    return app


def main():
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=8, comm_slots=1))
    app = build(platform)

    rng = np.random.default_rng(1)
    t, n = 0.0, 0
    while t < 4.0:
        rate = 300.0 if 1.0 < t < 3.0 else 40.0  # burst in the middle
        t += float(rng.exponential(1.0 / rate))
        platform.invoke(app, {"token": [Item(f"tok{n}")]}, at=t)
        n += 1
    platform.run()

    node = platform.node
    print(f"invocations: {n}, failed: {node.failed_count}")
    print("latency:", {k: round(v, 2)
                       for k, v in platform.latency.summary().items()})
    alloc = [(round(t, 2), c, m) for t, c, m, _ in node.controller.history[::20]]
    print("controller (t, compute_cores, comm_cores) samples:", alloc[:12])
    print("peak committed KiB:", round(node.committed_peak_bytes / 1024, 1))


if __name__ == "__main__":
    main()
