"""The paper's Figure 3 application: distributed log processing.

Access -> HTTP(auth) -> FanOut -> HTTP(each log shard, in parallel)
-> Render. Run under a bursty load and watch the PI controller re-balance
compute vs communication cores.

    PYTHONPATH=src python examples/log_processing.py
"""
import numpy as np

from repro.core import (
    Composition,
    FunctionRegistry,
    HttpRequest,
    HttpResponse,
    Item,
    ServiceRegistry,
    WorkerNode,
)


def build(reg: FunctionRegistry, services: ServiceRegistry, shards: int = 8):
    hosts = [f"logs{i}.svc" for i in range(shards)]
    services.register(
        "auth.svc",
        lambda req: HttpResponse(200, " ".join(f"http://{h}/tail" for h in hosts)),
        base_latency_s=1e-3,
    )
    rng = np.random.default_rng(0)
    for h in hosts:
        blob = b"\n".join(
            b"2026-07-15T12:00:00 svc=api lvl=%d msg=request" % rng.integers(0, 4)
            for _ in range(200)
        )
        services.register(h, lambda req, blob=blob: HttpResponse(200, blob),
                          base_latency_s=2e-3, bandwidth_bps=1e9)

    reg.register_function(
        "access",
        lambda ins: {"auth_req": [Item(HttpRequest(
            "GET", f"http://auth.svc/endpoints?tok={ins['token'][0].data}"))]},
    )
    reg.register_function(
        "fanout",
        lambda ins: {"log_reqs": [
            Item(HttpRequest("GET", u), key=str(i))
            for i, u in enumerate(str(ins["endpoints"][0].data.body).split())
        ]},
    )

    def render(ins):
        lines = errors = 0
        for it in ins["logs"]:
            body = it.data.body
            text = body.decode() if isinstance(body, bytes) else str(body)
            for line in text.splitlines():
                lines += 1
                errors += "lvl=3" in line
        return {"page": [Item(f"<html>{lines} lines, {errors} errors</html>".encode())]}

    reg.register_function("render", render)

    c = Composition("log_processing")
    acc = c.compute("access", "access", inputs=("token",), outputs=("auth_req",))
    h1 = c.http("auth_call")
    fan = c.compute("fanout", "fanout", inputs=("endpoints",), outputs=("log_reqs",))
    h2 = c.http("fetch_logs")
    ren = c.compute("render", "render", inputs=("logs",), outputs=("page",))
    c.edge(acc["auth_req"], h1["requests"], "all")
    c.edge(h1["responses"], fan["endpoints"], "all")
    c.edge(fan["log_reqs"], h2["requests"], "each")   # parallel shard fetch
    c.edge(h2["responses"], ren["logs"], "all")
    c.bind_input("token", acc["token"])
    c.bind_output("result", ren["page"])
    reg.register_composition(c)
    return c


def main():
    reg, services = FunctionRegistry(), ServiceRegistry()
    comp = build(reg, services)
    node = WorkerNode(reg, services, num_slots=8, comm_slots=1)

    rng = np.random.default_rng(1)
    t, n = 0.0, 0
    while t < 4.0:
        rate = 300.0 if 1.0 < t < 3.0 else 40.0  # burst in the middle
        t += float(rng.exponential(1.0 / rate))
        node.invoke_at(t, comp, {"token": [Item(f"tok{n}")]})
        n += 1
    node.run()

    print(f"invocations: {n}, failed: {node.failed_count}")
    print("latency:", {k: round(v, 2) for k, v in node.latency.summary().items()})
    alloc = [(round(t, 2), c, m) for t, c, m, _ in node.controller.history[::20]]
    print("controller (t, compute_cores, comm_cores) samples:", alloc[:12])
    print("peak committed KiB:", round(node.committed_peak_bytes / 1024, 1))


if __name__ == "__main__":
    main()
