"""SS7.7: Text2SQL agentic AI workflow as a declarative SDK application.

Five steps, mirroring the paper's pipeline:
  1. parse the natural-language prompt        (compute)
  2. prompt an LLM over HTTP                  (communication)
  3. extract the SQL query from the response  (compute)
  4. run the SQL against a database over HTTP (communication)
  5. format the database rows                 (compute)

The LLM endpoint is served by OUR OWN serving stack: a reduced-config
granite-8b running under the continuous batcher (examples are CPU-sized;
the same code drives a TPU slice). The database is an in-process table
with a tiny WHERE-clause evaluator. The pipeline structure, scheduling,
and both HTTP hops are real platform code paths — declared as dataflow
through the SDK, deployed and invoked through one Platform object.

    PYTHONPATH=src python examples/text2sql_agent.py
"""
import json
import re

import jax

from repro import sdk
from repro.configs import get_smoke
from repro.core import HttpRequest, HttpResponse, Item
from repro.models.model import build as build_model
from repro.serving.batching import ContinuousBatcher, Request


# ----------------------------------------------------------- LLM service
class TinyLLMService:
    """Our serving engine behind a REST-ish endpoint."""

    def __init__(self):
        cfg = get_smoke("granite-8b")
        self.cfg = cfg
        api = build_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        self.batcher = ContinuousBatcher(api, params, num_slots=4, cache_len=32)
        self._rid = 0

    def handle(self, req: HttpRequest) -> HttpResponse:
        prompt = json.loads(req.body)["prompt"]
        toks = [hash(w) % self.cfg.vocab_size for w in prompt.split()][:24]
        self._rid += 1
        self.batcher.submit(Request(self._rid, toks or [1], max_new_tokens=8))
        out = self.batcher.run_to_completion()[self._rid]
        # a real deployment would detokenize; we surface the raw ids plus
        # the deterministic query the (untrained) model stands in for
        completion = " ".join(map(str, out))
        return HttpResponse(200, json.dumps({
            "completion": completion,
            "sql": "SELECT city, population FROM cities WHERE population > 1000000",
        }))


# ------------------------------------------------------------ DB service
CITIES = [
    ("zurich", 436_000), ("geneva", 203_000), ("berlin", 3_700_000),
    ("paris", 2_100_000), ("madrid", 3_300_000), ("bern", 134_000),
]


def db_handler(req: HttpRequest) -> HttpResponse:
    q = json.loads(req.body)["sql"]
    m = re.search(r"population\s*>\s*(\d+)", q)
    thresh = int(m.group(1)) if m else 0
    rows = [(c, p) for c, p in CITIES if p > thresh]
    return HttpResponse(200, json.dumps(rows))


# ------------------------------------------------- compute declarations
@sdk.function(inputs=("question",), outputs=("llm_req",))
def parse_prompt(ins):
    prompt = ins["question"][0].data
    llm_prompt = f"Translate to SQL over table cities(city, population): {prompt}"
    body = json.dumps({"prompt": llm_prompt})
    return {"llm_req": [Item(HttpRequest("POST", "http://llm.svc/v1/complete", body))]}


@sdk.function(inputs=("llm_resp",), outputs=("db_req",))
def extract_sql(ins):
    resp = json.loads(ins["llm_resp"][0].data.body)
    sql = resp["sql"]
    return {"db_req": [Item(HttpRequest("POST", "http://db.svc/query",
                                        json.dumps({"sql": sql})))]}


@sdk.function(inputs=("db_resp",), outputs=("answer",))
def format_rows(ins):
    rows = json.loads(ins["db_resp"][0].data.body)
    lines = [f"{c}: {p:,}" for c, p in rows]
    return {"answer": [Item(("\n".join(lines)).encode())]}


def text2sql_app() -> sdk.App:
    with sdk.composition("text2sql") as app:
        p = parse_prompt(_name="parse", question=app.input("question"))
        h1 = sdk.http("llm_call", requests=p.llm_req)
        e = extract_sql(_name="extract", llm_resp=h1.responses)
        h2 = sdk.http("db_call", requests=e.db_req)
        f = format_rows(_name="format", db_resp=h2.responses)
        app.output("answer", f.answer)
    return app


def main():
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=4, comm_slots=2))
    platform.service("llm.svc", TinyLLMService().handle, base_latency_s=5e-3)
    platform.service("db.svc", db_handler, base_latency_s=1e-3)
    app = text2sql_app()
    platform.deploy(app)

    handle = platform.invoke(
        app, {"question": [Item("which cities have over a million people?")]})
    answer = handle.result()
    print("answer:\n" + answer["answer"][0].data.decode())
    # per-step completion times (the paper reports a per-step breakdown)
    inv = handle.invocation
    steps = {name: round(vr.done_t * 1e3, 2) for name, vr in inv.vertex_runs.items()}
    print("step completion times (virtual ms):", steps)
    print(f"end-to-end: {handle.latency*1e3:.2f} ms (virtual)")


if __name__ == "__main__":
    main()
