"""SS7.7: Text2SQL agentic AI workflow as a Dandelion composition.

Five steps, mirroring the paper's pipeline:
  1. parse the natural-language prompt        (compute)
  2. prompt an LLM over HTTP                  (communication)
  3. extract the SQL query from the response  (compute)
  4. run the SQL against a database over HTTP (communication)
  5. format the database rows                 (compute)

The LLM endpoint is served by OUR OWN serving stack: a reduced-config
granite-8b running under the continuous batcher (examples are CPU-sized;
the same code drives a TPU slice). The database is an in-process table
with a tiny WHERE-clause evaluator. The pipeline structure, scheduling,
and both HTTP hops are real platform code paths.

    PYTHONPATH=src python examples/text2sql_agent.py
"""
import json
import re

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import (
    Composition,
    FunctionRegistry,
    HttpRequest,
    HttpResponse,
    Item,
    ServiceRegistry,
    WorkerNode,
)
from repro.models.model import build as build_model
from repro.serving.batching import ContinuousBatcher, Request


# ----------------------------------------------------------- LLM service
class TinyLLMService:
    """Our serving engine behind a REST-ish endpoint."""

    def __init__(self):
        cfg = get_smoke("granite-8b")
        self.cfg = cfg
        api = build_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        self.batcher = ContinuousBatcher(api, params, num_slots=4, cache_len=32)
        self._rid = 0

    def handle(self, req: HttpRequest) -> HttpResponse:
        prompt = json.loads(req.body)["prompt"]
        toks = [hash(w) % self.cfg.vocab_size for w in prompt.split()][:24]
        self._rid += 1
        self.batcher.submit(Request(self._rid, toks or [1], max_new_tokens=8))
        out = self.batcher.run_to_completion()[self._rid]
        # a real deployment would detokenize; we surface the raw ids plus
        # the deterministic query the (untrained) model stands in for
        completion = " ".join(map(str, out))
        return HttpResponse(200, json.dumps({
            "completion": completion,
            "sql": "SELECT city, population FROM cities WHERE population > 1000000",
        }))


# ------------------------------------------------------------ DB service
CITIES = [
    ("zurich", 436_000), ("geneva", 203_000), ("berlin", 3_700_000),
    ("paris", 2_100_000), ("madrid", 3_300_000), ("bern", 134_000),
]


def db_handler(req: HttpRequest) -> HttpResponse:
    q = json.loads(req.body)["sql"]
    m = re.search(r"population\s*>\s*(\d+)", q)
    thresh = int(m.group(1)) if m else 0
    rows = [(c, p) for c, p in CITIES if p > thresh]
    return HttpResponse(200, json.dumps(rows))


# ------------------------------------------------------- compute functions
def parse_prompt(ins):
    prompt = ins["question"][0].data
    llm_prompt = f"Translate to SQL over table cities(city, population): {prompt}"
    body = json.dumps({"prompt": llm_prompt})
    return {"llm_req": [Item(HttpRequest("POST", "http://llm.svc/v1/complete", body))]}


def extract_sql(ins):
    resp = json.loads(ins["llm_resp"][0].data.body)
    sql = resp["sql"]
    return {"db_req": [Item(HttpRequest("POST", "http://db.svc/query",
                                        json.dumps({"sql": sql})))]}


def format_rows(ins):
    rows = json.loads(ins["db_resp"][0].data.body)
    lines = [f"{c}: {p:,}" for c, p in rows]
    return {"answer": [Item(("\n".join(lines)).encode())]}


def main():
    reg, services = FunctionRegistry(), ServiceRegistry()
    llm = TinyLLMService()
    services.register("llm.svc", llm.handle, base_latency_s=5e-3)
    services.register("db.svc", db_handler, base_latency_s=1e-3)
    for name, fn in (("parse_prompt", parse_prompt),
                     ("extract_sql", extract_sql),
                     ("format_rows", format_rows)):
        reg.register_function(name, fn)

    c = Composition("text2sql")
    p = c.compute("parse", "parse_prompt", inputs=("question",), outputs=("llm_req",))
    h1 = c.http("llm_call")
    e = c.compute("extract", "extract_sql", inputs=("llm_resp",), outputs=("db_req",))
    h2 = c.http("db_call")
    f = c.compute("format", "format_rows", inputs=("db_resp",), outputs=("answer",))
    c.edge(p["llm_req"], h1["requests"])
    c.edge(h1["responses"], e["llm_resp"])
    c.edge(e["db_req"], h2["requests"])
    c.edge(h2["responses"], f["db_resp"])
    c.bind_input("question", p["question"])
    c.bind_output("answer", f["answer"])
    reg.register_composition(c)

    node = WorkerNode(reg, services, num_slots=4, comm_slots=2)
    done = []
    node.invoke(c, {"question": [Item("which cities have over a million people?")]},
                on_done=done.append)
    node.run()
    inv = done[0]
    assert not inv.failed, inv.failed
    print("answer:\n" + inv.outputs["answer"][0].data.decode())
    # per-step completion times (the paper reports a per-step breakdown)
    steps = {name: round(vr.done_t * 1e3, 2) for name, vr in inv.vertex_runs.items()}
    print("step completion times (virtual ms):", steps)
    print(f"end-to-end: {inv.latency*1e3:.2f} ms (virtual)")


if __name__ == "__main__":
    main()
