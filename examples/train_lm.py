"""Train a ~100M-parameter LM for a few hundred steps with the full
fault-tolerance loop: async checkpoints, a simulated mid-run crash, and
bitwise-exact resume.

Each training phase runs as a declarative-SDK compute function invoked
through a single-node ``sdk.Platform`` (``memoize=False``: the payload
mutates the checkpoint directory) — the same front door the serving and
log-processing examples use, here carrying an arbitrary heavyweight jax
payload.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import sdk
from repro.config import ModelConfig
from repro.core import Item
from repro.config.parallel import ParallelPlan
from repro.config.shapes import ShapeConfig
from repro.models.model import build
from repro.training.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.training.data import make_batch
from repro.training.train_step import (
    abstract_train_state,
    build_train_step,
    init_train_state,
)


def hundred_m_config() -> ModelConfig:
    """~100M-parameter llama-style config (GPT-2-small scale)."""
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=10, d_model=640,
        num_heads=10, num_kv_heads=10, d_ff=1792, vocab_size=32000,
        head_dim=64, tie_embeddings=True,
    )


def run(steps, batch, seq, ckpt_dir, crash_at=None, lr=3e-4, log_every=None,
        ckpt_every=None):
    log_every = log_every or max(1, min(20, steps // 5))
    ckpt_every = ckpt_every or max(1, min(50, steps // 4))
    cfg = hundred_m_config()
    api = build(cfg)
    plan = ParallelPlan(remat="none", zero3=False).restrict_to(())
    shape = ShapeConfig("train", seq_len=seq, global_batch=batch, kind="train")
    step_fn = jax.jit(
        build_train_step(api, plan, lr=lr, warmup_steps=20, total_steps=steps),
        donate_argnums=(0,),
    )

    start = 0
    if latest_step(ckpt_dir) is not None:
        abstract = abstract_train_state(api, plan)
        state, start = restore_checkpoint(ckpt_dir, None, abstract)
        print(f"  resumed from checkpoint at step {start}")
    else:
        state = init_train_state(api, jax.random.PRNGKey(0), plan)
        print(f"  fresh start ({api.param_count()/1e6:.1f}M params)")

    ckpt = AsyncCheckpointer(ckpt_dir, keep=2)
    losses = {}
    for i in range(start, steps):
        b = jax.tree_util.tree_map(jnp.asarray, make_batch(cfg, shape, i))
        state, metrics = step_fn(state, b)
        if (i + 1) % log_every == 0:
            losses[i + 1] = float(metrics["loss"])
            print(f"  step {i+1:4d} loss {losses[i+1]:.4f}")
        if (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, state)
        if crash_at is not None and i + 1 == crash_at:
            ckpt.wait()
            print(f"  !! simulated crash at step {crash_at}")
            ckpt.close()
            return None, losses
    ckpt.close()
    return state, losses


@sdk.function(inputs=("cmd",), outputs=("report",), memoize=False,
              timeout_s=7 * 86400.0,  # effectively unlimited, like the
                                      # pre-SDK direct run() call
              # knowingly impure: run() writes checkpoints and progress
              # to stdout — real training, not a modeled payload
              pure_unsafe=True)
def train_phase(ins):
    """One training phase as a platform payload: config in, loss report
    out. Crash/resume state lives in the checkpoint directory."""
    cmd = json.loads(ins["cmd"][0].data)
    state, losses = run(**cmd)
    return {"report": [Item(json.dumps({
        "completed": state is not None,
        "losses": {str(k): v for k, v in losses.items()},
    }))]}


def train_app() -> sdk.App:
    with sdk.composition("train_lm") as app:
        phase = train_phase(cmd=app.input("cmd"))
        app.output("report", phase.report)
    return app


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train_lm_ckpt_")

    app = train_app()
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=2, comm_slots=1))
    platform.deploy(app)

    def invoke_phase(**cmd):
        handle = platform.invoke(app, {"cmd": [Item(json.dumps(cmd))]})
        return json.loads(handle.result()["report"][0].data)

    base = dict(steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=ckpt_dir)
    crash_at = max(1, min(args.steps // 2, 100))
    print(f"phase 1: train to step {crash_at}, then crash")
    invoke_phase(crash_at=crash_at, **base)

    print("phase 2: restart from the latest checkpoint and finish")
    report = invoke_phase(**base)
    assert report["completed"]
    losses = {int(k): v for k, v in report["losses"].items()}
    if losses:
        print(f"final loss {losses[max(losses)]:.4f} "
              f"(from {losses[min(losses)]:.4f} at step {min(losses)})")
    if not args.ckpt_dir:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
