"""End-to-end serving driver: batched LM inference through the platform.

Client requests enter a Dandelion composition whose compute function is a
*prefill+decode generation call* against the continuous-batching engine -
i.e. the model is the payload and the platform owns admission, fan-out,
memory contexts, and engine scheduling. Any of the 10 assigned
architectures is selectable with --arch (reduced config on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch olmoe-1b-7b --requests 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.core import (
    Composition,
    FunctionRegistry,
    Item,
    WorkerNode,
)
from repro.models.model import build as build_model
from repro.serving.batching import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} ({api.param_count()/1e6:.1f}M params)")

    def extras_fn(rid):
        if cfg.family == "encdec":
            return {"frames": jnp.zeros((1, 16, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "vlm":
            return {"patches": jnp.zeros((1, cfg.num_patches or 8, cfg.d_model), jnp.bfloat16)}
        return {}

    batcher = ContinuousBatcher(api, params, num_slots=args.slots,
                                cache_len=32, extras_fn=extras_fn)
    rid_counter = [0]

    # the generation call is a pure compute function: prompt ids in,
    # generated ids out - the platform cold-starts a context per request
    def generate_fn(inputs):
        prompt = list(np.frombuffer(inputs["prompt"][0].data, np.int32))
        rid_counter[0] += 1
        rid = rid_counter[0]
        batcher.submit(Request(rid, prompt, max_new_tokens=args.max_new))
        out = batcher.run_to_completion()[rid]
        return {"tokens": [Item(np.asarray(out, np.int32).tobytes())]}

    reg = FunctionRegistry()
    reg.register_function("generate", generate_fn, context_bytes=8 << 20)

    comp = Composition("serve_lm")
    g = comp.compute("generate", "generate", inputs=("prompt",), outputs=("tokens",))
    comp.bind_input("prompt", g["prompt"])
    comp.bind_output("tokens", g["tokens"])
    reg.register_composition(comp)

    node = WorkerNode(reg, num_slots=4, comm_slots=1)
    rng = np.random.default_rng(0)
    results = []
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(3, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
        node.invoke_at(i * 1e-3, comp, {"prompt": [Item(prompt.tobytes())]},
                       on_done=results.append)
    node.run()
    wall = time.time() - t0

    ok = [r for r in results if not r.failed]
    toks = sum(len(np.frombuffer(r.outputs["tokens"][0].data, np.int32)) for r in ok)
    print(f"served {len(ok)}/{args.requests} requests, {toks} tokens, "
          f"{wall:.2f}s wall ({toks/wall:.1f} tok/s)")
    print("platform latency (virtual):",
          {k: round(v, 3) for k, v in node.latency.summary().items()})
    for r in ok[:3]:
        print("  ->", np.frombuffer(r.outputs["tokens"][0].data, np.int32).tolist())


if __name__ == "__main__":
    main()
