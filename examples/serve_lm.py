"""End-to-end serving driver: batched LM inference through the platform.

Client requests enter a Dandelion composition whose compute function is a
*prefill+decode generation call* against the continuous-batching engine -
i.e. the model is the payload and the platform owns admission, fan-out,
memory contexts, and engine scheduling. The generation call is declared
through the SDK (``sdk.declare``; ``memoize=False`` because the batcher
is stateful) and driven through a single-node Platform's handle API.
Any of the 10 assigned architectures is selectable with --arch (reduced
config on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch olmoe-1b-7b --requests 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sdk
from repro.configs import ARCH_IDS, get_smoke
from repro.core import Item
from repro.models.model import build as build_model
from repro.serving.batching import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} ({api.param_count()/1e6:.1f}M params)")

    def extras_fn(rid):
        if cfg.family == "encdec":
            return {"frames": jnp.zeros((1, 16, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "vlm":
            return {"patches": jnp.zeros((1, cfg.num_patches or 8, cfg.d_model), jnp.bfloat16)}
        return {}

    batcher = ContinuousBatcher(api, params, num_slots=args.slots,
                                cache_len=32, extras_fn=extras_fn)
    rid_counter = [0]

    # the generation call is a pure compute function: prompt ids in,
    # generated ids out - the platform cold-starts a context per request
    def generate_fn(inputs):
        prompt = list(np.frombuffer(inputs["prompt"][0].data, np.int32))
        rid_counter[0] += 1
        rid = rid_counter[0]
        batcher.submit(Request(rid, prompt, max_new_tokens=args.max_new))
        out = batcher.run_to_completion()[rid]
        return {"tokens": [Item(np.asarray(out, np.int32).tobytes())]}

    generate = sdk.declare(
        "generate", generate_fn, inputs=("prompt",), outputs=("tokens",),
        context_bytes=8 << 20, memoize=False,
        # knowingly impure: drives the stateful continuous batcher and a
        # closed-over request counter — real serving, not a modeled payload
        pure_unsafe=True,
    )
    with sdk.composition("serve_lm") as app:
        g = generate(prompt=app.input("prompt"))
        app.output("tokens", g.tokens)

    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=4, comm_slots=1))
    platform.deploy(app)

    rng = np.random.default_rng(0)
    handles = []
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(3, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
        handles.append(platform.invoke(
            app, {"prompt": [Item(prompt.tobytes())]}, at=i * 1e-3))
    platform.run()
    wall = time.time() - t0

    ok = [h for h in handles if h.done]
    toks = sum(len(np.frombuffer(h.outputs["tokens"][0].data, np.int32)) for h in ok)
    print(f"served {len(ok)}/{args.requests} requests, {toks} tokens, "
          f"{wall:.2f}s wall ({toks/wall:.1f} tok/s)")
    print("platform latency (virtual):",
          {k: round(v, 3) for k, v in platform.latency.summary().items()})
    for h in ok[:3]:
        print("  ->", np.frombuffer(h.outputs["tokens"][0].data, np.int32).tolist())


if __name__ == "__main__":
    main()
