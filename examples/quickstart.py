"""Quickstart: register a compute function, compose it with an HTTP call,
invoke through a worker node, and inspect the cold-start breakdown.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    ClusterManager,
    ColdStartProfile,
    Composition,
    ControlPlaneConfig,
    ElasticControlPlane,
    EventLoop,
    FunctionRegistry,
    HttpRequest,
    HttpResponse,
    Item,
    ServiceRegistry,
    WorkerNode,
    measure,
)

# 1. A pure compute function: declared inputs -> declared outputs, no
#    syscalls, no sockets. This is the unit Dandelion cold-starts in ~us.
def word_count(inputs):
    text = inputs["doc"][0].data.body
    words = len(text.split())
    return {"stats": [Item(f"words={words}".encode())]}


def main():
    reg = FunctionRegistry()
    services = ServiceRegistry()
    reg.register_function("word_count", word_count)
    services.register(
        "docs.svc",
        lambda req: HttpResponse(200, b"the quick brown fox " * 128),
        base_latency_s=1e-3,
    )

    # 2. A composition: fetch a document over HTTP, count its words.
    comp = Composition("quickstart")
    fetch = comp.http("fetch")
    count = comp.compute("count", "word_count", inputs=("doc",), outputs=("stats",))
    comp.edge(fetch["responses"], count["doc"], "all")
    comp.bind_input("request", fetch["requests"])
    comp.bind_output("stats", count["stats"])
    reg.register_composition(comp)

    # 3. Invoke through the worker node (frontend -> dispatcher -> engines).
    node = WorkerNode(reg, services, num_slots=4, comm_slots=1)
    results = []
    for i in range(10):
        node.invoke_at(
            i * 1e-3, comp,
            {"request": [Item(HttpRequest("GET", "http://docs.svc/doc1"))]},
            on_done=results.append,
        )
    node.run()

    print("results:", results[0].outputs["stats"][0].data)
    print("latency:", {k: round(v, 3) for k, v in node.latency.summary().items()})
    print("committed memory after drain:", node.tracker.committed, "bytes")

    # 4. The platform's headline: per-request sandbox creation cost.
    bd, exec_s = measure(reg, "word_count",
                         {"doc": [Item(HttpResponse(200, b"hello world"))]},
                         samples=7)
    print("cold-start breakdown (us):",
          {k: round(v, 1) for k, v in bd.us().items()})

    # 5. Cluster scale: the Dirigent-style elastic control plane routes on
    #    code-cache locality and grows/shrinks the node pool with load.
    loop = EventLoop()
    profiles = {"word_count": ColdStartProfile(3e-4, 20e-3, 0.0)}

    def factory(name):
        return WorkerNode(reg, services, loop=loop, num_slots=4,
                          profiles=profiles, code_cache_entries=32,
                          base_bytes=256 << 20, name=name)

    cp = ElasticControlPlane(
        loop, factory,
        config=ControlPlaneConfig(
            min_nodes=1, max_nodes=4, target_outstanding_per_node=6.0,
            keepalive_s=5.0, tick_interval_s=0.25,
            node_boot=ColdStartProfile(0.5, 0.0, 0.0),
        ),
    )
    cluster = ClusterManager(control_plane=cp)
    for i in range(300):  # 2s burst, then silence
        cluster.invoke_at(
            i * (2.0 / 300), comp,
            {"request": [Item(HttpRequest("GET", "http://docs.svc/doc1"))]},
        )
    cluster.run(until=30.0)
    loop.run()
    print("elastic cluster:",
          {k: round(v, 3) for k, v in cp.summary().items()})


if __name__ == "__main__":
    main()
