"""Quickstart: the declarative SDK front door in one file.

1. declare a typed compute function with ``@sdk.function``;
2. compose it with an HTTP communication function using port-level
   dataflow expressions;
3. deploy + invoke through a single-node ``sdk.Platform`` and await
   ``InvocationHandle`` futures;
4. inspect the real cold-start breakdown;
5. rerun the same app, unchanged, on an elastic cluster — the platform
   shape is configuration, not code.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import sdk
from repro.core import HttpRequest, HttpResponse, Item, measure


# 1. A pure compute function: declared inputs -> declared outputs, no
#    syscalls, no sockets. This is the unit Dandelion cold-starts in ~us.
#    The decorator captures every piece of ComputeFunction metadata at
#    the definition site (context bytes, timeouts, jax payloads, ...).
@sdk.function(inputs=("doc",), outputs=("stats",))
def word_count(inputs):
    text = inputs["doc"][0].data.body
    words = len(text.split())
    return {"stats": [Item(f"words={words}".encode())]}


# 2. A composition: fetch a document over HTTP, count its words. Edges
#    are written as dataflow (`doc=fetch.responses`), validated eagerly,
#    and compile to the core Composition IR unchanged.
def quickstart_app() -> sdk.App:
    with sdk.composition("quickstart") as app:
        fetch = sdk.http("fetch", requests=app.input("request"))
        count = word_count(_name="count", doc=fetch.responses)
        app.output("stats", count.stats)
    return app


def main():
    app = quickstart_app()

    # 3. One Platform object owns the registry, services, event loop and
    #    node; deploy() registers functions + graph, invoke() returns a
    #    future-style handle that works the same on every platform shape.
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=4, comm_slots=1))
    platform.service(
        "docs.svc",
        lambda req: HttpResponse(200, b"the quick brown fox " * 128),
        base_latency_s=1e-3,
    )
    platform.deploy(app)

    handles = [
        platform.invoke(
            app, {"request": [Item(HttpRequest("GET", "http://docs.svc/doc1"))]},
            at=i * 1e-3,
        )
        for i in range(10)
    ]
    print("results:", handles[0].result()["stats"][0].data)
    print("latency:", {k: round(v, 3)
                       for k, v in platform.latency.summary().items()})
    print("committed memory after drain:",
          platform.node.tracker.committed, "bytes")

    # 4. The platform's headline: per-request sandbox creation cost,
    #    measured on the real cold-start code paths.
    bd, exec_s = measure(platform.registry, "word_count",
                         {"doc": [Item(HttpResponse(200, b"hello world"))]},
                         samples=7)
    print("cold-start breakdown (us):",
          {k: round(v, 1) for k, v in bd.us().items()})

    # 5. Cluster scale: the SAME app on the Dirigent-style elastic
    #    control plane (code-cache-affinity routing, autoscaled pool) —
    #    only the Platform shape changes.
    cluster = sdk.Platform(
        elastic=sdk.Elastic(
            config=sdk.ControlPlaneConfig(
                min_nodes=1, max_nodes=4, target_outstanding_per_node=6.0,
                keepalive_s=5.0, tick_interval_s=0.25,
                node_boot=sdk.ColdStartProfile(0.5, 0.0, 0.0),
            ),
            node=sdk.NodeSpec(num_slots=4, code_cache_entries=32,
                              base_bytes=256 << 20),
        ),
        profiles={"word_count": sdk.ColdStartProfile(3e-4, 20e-3, 0.0)},
    )
    cluster.service(
        "docs.svc",
        lambda req: HttpResponse(200, b"the quick brown fox " * 128),
        base_latency_s=1e-3,
    )
    cluster.deploy(app)
    for i in range(300):  # 2s burst, then silence
        cluster.invoke(
            app, {"request": [Item(HttpRequest("GET", "http://docs.svc/doc1"))]},
            at=i * (2.0 / 300),
        )
    cluster.run(until=30.0)
    cluster.run()
    print("elastic cluster:",
          {k: round(v, 3) for k, v in cluster.control_plane.summary().items()})


if __name__ == "__main__":
    main()
