"""Serving-on-Dandelion: batched LM inference as a composition workload.

The paper's core claim is that cloud-native apps — user logic plus
higher-level services like AI inference — can run as DAGs of pure
functions over the elastic platform, booting sandboxes per request. This
module expresses one LM serving request as exactly that:

    tokenize -> prefill -> decode_0 -> ... -> decode_{N-1} -> detokenize

Every vertex is a pure compute function; the KV cache rides between the
prefill/decode vertices as a ``KVCache`` item inside the ordinary
``MemoryContext`` dataflow, so its *real byte size* is what the platform
commits, and — under cross-node placement — what a cache migration
charges to the producing node's comm engine (``TransferProfile`` on
``KVCache.nbytes`` bytes).

Costs are priced from the ``repro.launch.hlo_analysis`` models:

  * model-weight cold start (param bytes / disk bandwidth + compile time
    from the HLO op count) becomes the prefill/decode functions'
    ``ColdStartProfile.cold_setup_s``, charged only when the executing
    node holds no resident weights (``core.workloads.WeightStore``);
  * per-step execute time comes from ``serving_step_terms`` rooflines;
    the same terms parameterize the platform's ``BatchStepModel`` so a
    batching engine coalesces co-resident decode steps into one step.

Token streams are deterministic functions of the prompt digest, so runs
are byte-stable and batching on/off produces identical tokens (pinned by
tests/test_inference_service.py).
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import sdk
from repro.config.parallel import HardwareSpec, TPU_V5E
from repro.core import (
    BatchStepModel,
    ColdStartProfile,
    Composition,
    FunctionRegistry,
    Item,
    WeightStore,
)
from repro.core.dag import COMPUTE, Edge, PortRef, Vertex
from repro.launch.hlo_analysis import (
    WeightColdStart,
    serving_step_terms,
    weight_coldstart_estimate,
)

SANDBOX_SETUP_S = 0.3e-3      # dandelion context-bind path (Table 1)


@dataclass(frozen=True)
class LMSpec:
    """Model geometry the cost models need (nothing else)."""

    name: str = "lm-1b"
    n_params: float = 1.3e9
    n_layers: int = 24
    d_model: int = 2048
    vocab_size: int = 32_000
    dtype_bytes: int = 2          # bf16 weights + KV
    ops_per_layer: int = 60       # HLO instruction estimate per layer
    prompt_len_hint: int = 128    # representative shapes for profiles
    seq_len_hint: int = 160

    @property
    def param_bytes(self) -> int:
        return int(self.n_params * self.dtype_bytes)

    @property
    def kv_bytes_per_token(self) -> int:
        return 2 * self.n_layers * self.d_model * self.dtype_bytes  # K + V

    @property
    def flops_per_token(self) -> float:
        return 2.0 * self.n_params

    @property
    def hlo_ops_estimate(self) -> int:
        return self.n_layers * self.ops_per_layer + 40  # + embed/head/sample


def lm_spec_from_config(cfg, **overrides) -> LMSpec:
    """An ``LMSpec`` priced from a ``repro.configs`` ``ModelConfig``.

    The cost models only read four things off the geometry: parameter
    count, and the KV width ``2 * n_layers * d_model * dtype_bytes``.
    Real architectures use GQA, so the *true* per-token KV width is
    ``2 * n_layers * (num_kv_heads * head_dim) * dtype_bytes`` — we fold
    that in by setting the spec's ``d_model`` to the KV projection width
    rather than the residual width. ``overrides`` (e.g. ``name=``,
    ``seq_len_hint=``) pass through to the ``LMSpec`` constructor."""
    fields = dict(
        name=cfg.name,
        n_params=float(cfg.num_params()),
        n_layers=cfg.num_layers,
        d_model=cfg.num_kv_heads * cfg.resolved_head_dim,
        vocab_size=cfg.vocab_size,
    )
    fields.update(overrides)
    return LMSpec(**fields)


@dataclass(frozen=True)
class KVCache:
    """Opaque KV-cache handle carried as an item between vertices.

    Holds no real activations — only the prompt digest and length the
    pure decode function needs — but reports the *modeled* cache size
    through ``nbytes``, which is the only thing the platform reads:
    ``MemoryContext.write_set`` commits it, ``cluster.CrossNodePlacer``
    charges it per migrated edge. ``fingerprint()`` exposes the handle's
    full identity to the payload memo (``items.fingerprint_sets``), so a
    decode chain over a repeated prompt digest replays as memo hits —
    priced ``BatchStepModel`` steps with fingerprint-stable payloads,
    never re-running the token arithmetic (pinned by
    tests/test_inference_service.py)."""

    model: str
    digest: str
    seq_len: int
    bytes_per_token: int

    @property
    def nbytes(self) -> int:
        return self.seq_len * self.bytes_per_token

    def fingerprint(self) -> bytes:
        """Content identity for the payload memo: decode is a pure
        function of exactly these four fields (token values derive from
        ``digest`` + position), so equal fingerprints imply equal
        outputs."""
        return (
            f"{self.model}:{self.digest}:{self.seq_len}:{self.bytes_per_token}"
        ).encode()


def _next_token(digest: str, position: int, vocab: int) -> int:
    h = hashlib.blake2b(f"{digest}:{position}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little") % vocab


@dataclass
class InferenceService:
    """Everything a platform needs to run the workload: registered
    function names, SDK function declarations, calibrated profiles, the
    batch-step model, and the weight-store spec."""

    spec: LMSpec
    profiles: Dict[str, ColdStartProfile]
    batch_model: BatchStepModel
    weight_cold: WeightColdStart
    prefill_step_s: float
    decode_step_s: float
    fn_names: Tuple[str, ...] = ()
    # the four stage declarations, keyed "tokenize"/"prefill"/"decode"/
    # "detok" — already registered; carry their calibrated profiles
    specs: Dict[str, sdk.FunctionSpec] = field(default_factory=dict)
    # per-function batch pricing: decode always; prefill too when chunked.
    # Multiplexed platforms merge several services' dicts onto one node —
    # the engine prices each coalesced step by the step's fn_name.
    batch_models: Dict[str, BatchStepModel] = field(default_factory=dict)
    prefill_chunk: Optional[int] = None

    def make_weight_store(self, *, keepalive_s: float = 0.0,
                          pinned: bool = False,
                          capacity_bytes: Optional[int] = None) -> WeightStore:
        """A fresh per-node store holding this service's weights. The
        tokenize/detokenize frontends don't touch the model, so only
        prefill/decode are registered against it. ``capacity_bytes``
        bounds node weight RAM (``WeightStore`` evicts LRU-idle residents
        to fit — the multiplexing path)."""
        ws = WeightStore(keepalive_s=keepalive_s, pinned=pinned,
                         capacity_bytes=capacity_bytes)
        self.register_weights(ws)
        return ws

    def register_weights(self, ws: WeightStore) -> WeightStore:
        """Register this service's weights into an existing store — the
        multiplexing path, where several models' services share one
        per-node store and compete for its capacity."""
        ws.register(self.spec.name, self.spec.param_bytes,
                    (self._fn("prefill"), self._fn("decode")))
        return ws

    def _fn(self, stage: str) -> str:
        return f"{self.spec.name}_{stage}"


def register_inference_service(
    reg: FunctionRegistry,
    spec: LMSpec = LMSpec(),
    *,
    hw: HardwareSpec = TPU_V5E,
    disk_bandwidth_bps: float = 2e9,
    compile_s_per_op: float = 1e-3,
    step_overhead_s: float = 150e-6,
    hlo_text: Optional[str] = None,
    prefill_chunk: Optional[int] = None,
) -> InferenceService:
    """Register the four serving functions and price their profiles from
    the HLO cost models. ``hlo_text`` (a real optimized-HLO dump, e.g.
    from ``launch.dryrun``) refines the compile-time term; without it the
    layer-count estimate is used.

    ``prefill_chunk`` (tokens) makes prefill *chunked*: the prefill
    function is declared batchable so it rides the BATCH engine alongside
    decode, each request occupying ``ceil(prompt_len / chunk)`` units of
    the coalesced step (``Vertex.batch_units``); a per-function
    ``BatchStepModel`` prices one chunk. Default ``None`` keeps the
    historical whole-prompt CPU prefill byte-identically."""
    if prefill_chunk is not None and prefill_chunk < 1:
        raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
    kv_bpt = spec.kv_bytes_per_token
    vocab = spec.vocab_size
    name = spec.name

    def tokenize(ins):
        prompt = ins["prompt"][0].data
        raw = prompt if isinstance(prompt, (bytes, bytearray)) else str(prompt).encode()
        digest = hashlib.blake2b(raw, digest_size=8).hexdigest()
        n = max(1, len(raw) // 4)      # ~4 bytes per token
        rng = np.random.default_rng(int(digest, 16) % (2**32))
        toks = rng.integers(0, vocab, size=n, dtype=np.int32)
        return {"tokens": [Item(toks)]}

    def prefill(ins):
        toks = ins["tokens"][0].data
        digest = hashlib.blake2b(np.asarray(toks).tobytes(), digest_size=8).hexdigest()
        kv = KVCache(name, digest, seq_len=int(np.asarray(toks).size),
                     bytes_per_token=kv_bpt)
        first = _next_token(digest, kv.seq_len, vocab)
        return {"kv": [Item(kv)], "tok": [Item(first)]}

    def decode(ins):
        kv_in: KVCache = ins["kv"][0].data
        kv = KVCache(name, kv_in.digest, kv_in.seq_len + 1, kv_bpt)
        return {"kv": [Item(kv)], "tok": [Item(_next_token(kv_in.digest, kv.seq_len, vocab))]}

    def detokenize(ins):
        toks = [it.data for it in ins["toks"]]
        text = ("tok:" + ",".join(str(t) for t in toks)).encode()
        return {"text": [Item(text)]}

    # typed declarations (SDK front door); registered in the legacy order
    specs = {
        "tokenize": sdk.declare(
            f"{name}_tokenize", tokenize,
            inputs=("prompt",), outputs=("tokens",), context_bytes=1 << 20,
        ),
        "prefill": sdk.declare(
            f"{name}_prefill", prefill,
            inputs=("tokens",), outputs=("kv", "tok"),
            batchable=prefill_chunk is not None,
            context_bytes=spec.prompt_len_hint * kv_bpt + (4 << 20),
        ),
        "decode": sdk.declare(
            f"{name}_decode", decode,
            inputs=("kv", "tok"), outputs=("kv", "tok"), batchable=True,
            context_bytes=spec.seq_len_hint * kv_bpt + (1 << 20),
        ),
        "detok": sdk.declare(
            f"{name}_detok", detokenize,
            inputs=("toks",), outputs=("text",), context_bytes=1 << 20,
        ),
    }
    for s in specs.values():
        s.register_into(reg)

    # ---- cost models (launch.hlo_analysis) -----------------------------
    weight_cold = weight_coldstart_estimate(
        spec.param_bytes,
        hlo_text=hlo_text,
        hlo_ops=spec.hlo_ops_estimate,
        disk_bandwidth_bps=disk_bandwidth_bps,
        compile_s_per_op=compile_s_per_op,
    )
    prefill_terms = serving_step_terms(
        param_bytes=spec.param_bytes,
        flops_per_seq=spec.flops_per_token * spec.prompt_len_hint,
        kv_bytes_per_seq=spec.prompt_len_hint * kv_bpt,
        batch=1, peak_flops=hw.peak_flops, hbm_bw=hw.hbm_bandwidth,
        ici_bw=hw.ici_bandwidth,
    )
    decode_terms = serving_step_terms(
        param_bytes=spec.param_bytes,
        flops_per_seq=spec.flops_per_token,
        kv_bytes_per_seq=spec.seq_len_hint * kv_bpt,
        batch=1, peak_flops=hw.peak_flops, hbm_bw=hw.hbm_bandwidth,
        ici_bw=hw.ici_bandwidth,
    )
    batch_model = BatchStepModel(
        flops_per_seq=spec.flops_per_token,
        fixed_bytes=float(spec.param_bytes),
        bytes_per_seq=float(spec.seq_len_hint * kv_bpt),
        peak_flops=hw.peak_flops,
        hbm_bw=hw.hbm_bandwidth,
        overhead_s=step_overhead_s,
    )
    prefill_s = prefill_terms.step_time_s + step_overhead_s
    decode_s = batch_model.step_s(1)
    batch_models = {f"{name}_decode": batch_model}
    if prefill_chunk is not None:
        # one *chunk* is the unit of a coalesced prefill step; a request
        # occupies ceil(prompt_len / chunk) units of that step
        batch_models[f"{name}_prefill"] = BatchStepModel(
            flops_per_seq=spec.flops_per_token * prefill_chunk,
            fixed_bytes=float(spec.param_bytes),
            bytes_per_seq=float(prefill_chunk * kv_bpt),
            peak_flops=hw.peak_flops,
            hbm_bw=hw.hbm_bandwidth,
            overhead_s=step_overhead_s,
        )

    profiles = {
        f"{name}_tokenize": ColdStartProfile(SANDBOX_SETUP_S, 0.2e-3, 0.05),
        f"{name}_prefill": ColdStartProfile(
            # chunked prefill rides the batching engine, which must be
            # able to substitute step_s(units) without RNG skew: no jitter
            SANDBOX_SETUP_S, prefill_s,
            0.0 if prefill_chunk is not None else 0.05,
            cold_setup_s=weight_cold.total_s,
        ),
        f"{name}_decode": ColdStartProfile(
            # jitter-free: the batching engine must be able to substitute
            # step_s(n) for n independent durations without RNG skew
            SANDBOX_SETUP_S, decode_s, 0.0, cold_setup_s=weight_cold.total_s,
        ),
        f"{name}_detok": ColdStartProfile(SANDBOX_SETUP_S, 0.2e-3, 0.05),
    }
    for s in specs.values():
        s.profile = profiles[s.name]
    return InferenceService(
        spec=spec,
        profiles=profiles,
        batch_model=batch_model,
        weight_cold=weight_cold,
        prefill_step_s=prefill_s,
        decode_step_s=decode_s,
        fn_names=tuple(profiles),
        specs=specs,
        batch_models=batch_models,
        prefill_chunk=prefill_chunk,
    )


def request_app(
    spec: LMSpec,
    *,
    prompt_len: int,
    n_decode: int,
    specs: Optional[Dict[str, sdk.FunctionSpec]] = None,
    prefill_chunk: Optional[int] = None,
) -> sdk.App:
    """One serving request as a declarative SDK application: the decode
    chain is unrolled to this request's token budget, each link passing
    the (growing) KV cache item and the previous token forward, every
    token also feeding detokenize. Without ``specs`` (an
    ``InferenceService.specs`` mapping), typed references to the
    registered function names are used. ``prefill_chunk`` (matching the
    service's) sizes the prefill vertex at ``ceil(prompt_len / chunk)``
    units of a coalesced BATCH step."""
    kv_bpt = spec.kv_bytes_per_token
    name = spec.name
    prefill_units = (None if prefill_chunk is None
                     else max(1, math.ceil(prompt_len / prefill_chunk)))
    if specs is None:
        specs = {
            "tokenize": sdk.ref(f"{name}_tokenize",
                                inputs=("prompt",), outputs=("tokens",)),
            "prefill": sdk.ref(f"{name}_prefill",
                               inputs=("tokens",), outputs=("kv", "tok")),
            "decode": sdk.ref(f"{name}_decode",
                              inputs=("kv", "tok"), outputs=("kv", "tok")),
            "detok": sdk.ref(f"{name}_detok",
                             inputs=("toks",), outputs=("text",)),
        }
    with sdk.composition(f"{name}_p{prompt_len}_d{n_decode}") as app:
        tok = specs["tokenize"](_name="tokenize", _context_bytes=1 << 20,
                                prompt=app.input("prompt"))
        pre = specs["prefill"](
            _name="prefill",
            _context_bytes=prompt_len * kv_bpt + (4 << 20),
            _batch_units=prefill_units,
            tokens=tok.tokens,
        )
        det = specs["detok"](_name="detokenize", _context_bytes=1 << 20)
        det.feed(toks=pre.tok)
        prev = pre
        for i in range(n_decode):
            # context sized to the cache at this step: in + out copies
            d = specs["decode"](
                _name=f"decode{i}",
                _context_bytes=2 * (prompt_len + i + 1) * kv_bpt + (1 << 20),
                kv=prev.kv, tok=prev.tok,
            )
            det.feed(toks=d.tok)
            prev = d
        app.output("text", det.text)
    return app


def build_request_composition(
    spec: LMSpec,
    *,
    prompt_len: int,
    n_decode: int,
    prefill_chunk: Optional[int] = None,
) -> Composition:
    """The request DAG as an IR ``Composition`` (see ``request_app``).
    The functions must already be registered
    (``register_inference_service``).

    Builds the IR directly — no SDK builder objects — because serving
    traces construct thousands of distinct ``(prompt_len, n_decode)``
    shapes per run and the declarative front door dominated the
    simulator's admission cost. Field-for-field structurally identical
    to ``request_app(...).compile()``: same vertex declaration order,
    same edge append order, same bindings (pinned by
    tests/test_inference_service.py)."""
    kv_bpt = spec.kv_bytes_per_token
    name = spec.name
    comp = Composition(f"{name}_p{prompt_len}_d{n_decode}")
    vertices = comp.vertices
    vertices["tokenize"] = Vertex(
        "tokenize", COMPUTE, f"{name}_tokenize", ("prompt",), ("tokens",),
        context_bytes=1 << 20,
    )
    vertices["prefill"] = Vertex(
        "prefill", COMPUTE, f"{name}_prefill", ("tokens",), ("kv", "tok"),
        context_bytes=prompt_len * kv_bpt + (4 << 20),
        batch_units=(1 if prefill_chunk is None
                     else max(1, math.ceil(prompt_len / prefill_chunk))),
    )
    vertices["detokenize"] = Vertex(
        "detokenize", COMPUTE, f"{name}_detok", ("toks",), ("text",),
        context_bytes=1 << 20,
    )
    edges = comp.edges
    in_adj, out_adj = comp._in_adj, comp._out_adj

    def _edge(sv: str, ss: str, dv: str, ds: str) -> None:
        e = Edge(PortRef(sv, ss), PortRef(dv, ds))
        edges.append(e)
        out_adj.setdefault(sv, []).append(e)
        in_adj.setdefault(dv, []).append(e)

    _edge("tokenize", "tokens", "prefill", "tokens")
    _edge("prefill", "tok", "detokenize", "toks")
    prev = "prefill"
    for i in range(n_decode):
        vn = f"decode{i}"
        vertices[vn] = Vertex(
            vn, COMPUTE, f"{name}_decode", ("kv", "tok"), ("kv", "tok"),
            context_bytes=2 * (prompt_len + i + 1) * kv_bpt + (1 << 20),
        )
        _edge(prev, "kv", vn, "kv")
        _edge(prev, "tok", vn, "tok")
        _edge(vn, "tok", "detokenize", "toks")
        prev = vn
    comp._adj_edges_n = len(edges)
    comp.input_bindings["prompt"] = PortRef("tokenize", "prompt")
    comp.output_bindings["text"] = PortRef("detokenize", "text")
    return comp


def expected_tokens(prompt: bytes, spec: LMSpec, n_decode: int) -> List[int]:
    """Reference token stream for a prompt — what any platform run must
    produce regardless of batching, placement, or policy (the pure-
    function contract)."""
    digest_p = hashlib.blake2b(prompt, digest_size=8).hexdigest()
    n = max(1, len(prompt) // 4)
    rng = np.random.default_rng(int(digest_p, 16) % (2**32))
    toks = rng.integers(0, spec.vocab_size, size=n, dtype=np.int32)
    digest = hashlib.blake2b(toks.tobytes(), digest_size=8).hexdigest()
    return [_next_token(digest, n + i, spec.vocab_size) for i in range(n_decode + 1)]
