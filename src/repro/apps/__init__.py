"""Reference applications authored through the declarative SDK."""
from repro.apps.log_processing import (
    build_log_processing,
    log_processing_app,
    register_log_services,
)

__all__ = [
    "build_log_processing",
    "log_processing_app",
    "register_log_services",
]
