"""Reference applications built on the public composition API."""
from repro.apps.log_processing import build_log_processing

__all__ = ["build_log_processing"]
