"""The paper's Figure 3 application: distributed log processing.

Access -> HTTP(auth) -> FanOut -> HTTP(each shard, parallel) -> Render.
Shared by tests, benchmarks, and examples.
"""
from __future__ import annotations

from repro.core import (
    Composition,
    FunctionRegistry,
    HttpRequest,
    HttpResponse,
    Item,
    ServiceRegistry,
)


def build_log_processing(
    reg: FunctionRegistry,
    services: ServiceRegistry,
    *,
    shards: int = 3,
    log_bytes: int = 2000,
    auth_latency_s: float = 1e-3,
    shard_latency_s: float = 2e-3,
) -> Composition:
    hosts = [f"logs{i}.svc" for i in range(shards)]
    services.register(
        "auth.svc",
        lambda req: HttpResponse(200, " ".join(f"http://{h}/tail" for h in hosts)),
        base_latency_s=auth_latency_s,
    )
    blob = b"log-entry " * (log_bytes // 10)
    for h in hosts:
        services.register(
            h, lambda req, blob=blob: HttpResponse(200, blob),
            base_latency_s=shard_latency_s, bandwidth_bps=1e9,
        )

    reg.register_function(
        "access",
        lambda ins: {"auth_req": [Item(HttpRequest(
            "GET", f"http://auth.svc/endpoints?tok={ins['token'][0].data}"))]},
    )
    reg.register_function(
        "fanout",
        lambda ins: {"log_reqs": [
            Item(HttpRequest("GET", u), key=str(i))
            for i, u in enumerate(str(ins["endpoints"][0].data.body).split())
        ]},
    )
    reg.register_function(
        "render",
        lambda ins: {"page": [Item(
            f"rendered {sum(len(str(i.data.body)) for i in ins['logs'])} bytes".encode()
        )]},
    )

    c = Composition("log_processing")
    acc = c.compute("access", "access", inputs=("token",), outputs=("auth_req",))
    h1 = c.http("auth_call")
    fan = c.compute("fanout", "fanout", inputs=("endpoints",), outputs=("log_reqs",))
    h2 = c.http("fetch_logs")
    ren = c.compute("render", "render", inputs=("logs",), outputs=("page",))
    c.edge(acc["auth_req"], h1["requests"], "all")
    c.edge(h1["responses"], fan["endpoints"], "all")
    c.edge(fan["log_reqs"], h2["requests"], "each")
    c.edge(h2["responses"], ren["logs"], "all")
    c.bind_input("token", acc["token"])
    c.bind_output("result", ren["page"])
    reg.register_composition(c)
    return c
