"""The paper's Figure 3 application: distributed log processing.

Access -> HTTP(auth) -> FanOut -> HTTP(each shard, parallel) -> Render.
Shared by tests, benchmarks, and examples.

Authored through the declarative SDK (``repro.sdk``): the three compute
stages are typed function declarations, the DAG is built from port-level
dataflow expressions (with ``sdk.each`` on the shard fetch), and the
result compiles to exactly the ``core/dag.py`` Composition the old
hand-wired builder produced (pinned by tests/test_sdk.py).
"""
from __future__ import annotations

from repro import sdk
from repro.core import (
    Composition,
    FunctionRegistry,
    HttpRequest,
    HttpResponse,
    Item,
    ServiceRegistry,
)


def log_processing_specs():
    """The three compute-stage declarations (access, fanout, render).
    The shard fan-out is data-driven (one fetch per URL in the auth
    response), so the specs don't depend on the shard count."""
    access = sdk.declare(
        "access",
        lambda ins: {"auth_req": [Item(HttpRequest(
            "GET", f"http://auth.svc/endpoints?tok={ins['token'][0].data}"))]},
        inputs=("token",), outputs=("auth_req",),
    )
    fanout = sdk.declare(
        "fanout",
        lambda ins: {"log_reqs": [
            Item(HttpRequest("GET", u), key=str(i))
            for i, u in enumerate(str(ins["endpoints"][0].data.body).split())
        ]},
        inputs=("endpoints",), outputs=("log_reqs",),
    )
    render = sdk.declare(
        "render",
        lambda ins: {"page": [Item(
            f"rendered {sum(len(str(i.data.body)) for i in ins['logs'])} bytes".encode()
        )]},
        inputs=("logs",), outputs=("page",),
    )
    return access, fanout, render


def log_processing_app() -> sdk.App:
    """The Figure 3 DAG as a declarative SDK application."""
    access, fanout, render = log_processing_specs()
    with sdk.composition("log_processing") as app:
        acc = access(token=app.input("token"))
        h1 = sdk.http("auth_call", requests=acc.auth_req)
        fan = fanout(endpoints=h1.responses)
        h2 = sdk.http("fetch_logs", requests=sdk.each(fan.log_reqs))
        ren = render(logs=h2.responses)
        app.output("result", ren.page)
    return app


def register_log_services(
    services: ServiceRegistry,
    *,
    shards: int = 3,
    log_bytes: int = 2000,
    auth_latency_s: float = 1e-3,
    shard_latency_s: float = 2e-3,
) -> None:
    """The auth endpoint plus one log-shard endpoint per shard."""
    hosts = [f"logs{i}.svc" for i in range(shards)]
    services.register(
        "auth.svc",
        lambda req: HttpResponse(200, " ".join(f"http://{h}/tail" for h in hosts)),
        base_latency_s=auth_latency_s,
    )
    blob = b"log-entry " * (log_bytes // 10)
    for h in hosts:
        services.register(
            h, lambda req, blob=blob: HttpResponse(200, blob),
            base_latency_s=shard_latency_s, bandwidth_bps=1e9,
        )


def build_log_processing(
    reg: FunctionRegistry,
    services: ServiceRegistry,
    *,
    shards: int = 3,
    log_bytes: int = 2000,
    auth_latency_s: float = 1e-3,
    shard_latency_s: float = 2e-3,
) -> Composition:
    """Legacy entry point: register services + functions + composition
    into explicit registries and return the IR. (SDK-native callers use
    ``log_processing_app`` with a ``sdk.Platform`` instead.)"""
    register_log_services(
        services, shards=shards, log_bytes=log_bytes,
        auth_latency_s=auth_latency_s, shard_latency_s=shard_latency_s,
    )
    app = log_processing_app()
    for spec in app.function_specs():
        spec.register_into(reg)
    return reg.register_composition(app.compile(reg))
