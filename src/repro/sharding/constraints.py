"""Opt-in activation sharding constraints (beyond-paper optimization).

The baseline lets GSPMD propagate shardings from parameters/inputs alone.
That leaves big gaps: the HLO analysis (EXPERIMENTS.md SSPerf) shows XLA
*replicating the attention-head dimension* inside the layer scan and
all-reducing gradients in pre-contraction [B, S, F] form - 10-30x
compute/byte waste. These helpers pin the intent:

  * activations carry batch over the data axes;
  * the head / ffn / vocab dimension of intermediates carries the model
    axis (when divisible);

Constraints are no-ops unless a ``activation_constraints(mesh, plan)``
context is active at trace time, so CPU tests and the paper-faithful
baseline lower unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_constraints", default=None
)


@contextlib.contextmanager
def activation_constraints(mesh: Mesh, plan):
    token = _CTX.set({
        "mesh": mesh,
        "data": tuple(plan.data_axes),
        "model": tuple(plan.tensor_axes),
    })
    try:
        yield
    finally:
        _CTX.reset(token)


def _axes_for(ctx, names: Tuple[str, ...], dim: int):
    names = tuple(a for a in names if a in ctx["mesh"].axis_names)
    if not names:
        return None
    prod = int(np.prod([ctx["mesh"].shape[a] for a in names]))
    if prod <= 1 or dim % prod != 0:
        return None
    return names if len(names) > 1 else names[0]


def shard_act(x: jax.Array, kind: str) -> jax.Array:
    """Constrain one activation. kinds:
    bsd   [B,S,D]    batch->data
    bshd  [B,S,H,dh] batch->data, heads->model
    bsf   [B,S,F]    batch->data, features->model
    bsv   [B,S,V]    batch->data, vocab->model
    bd    [B,D]      batch->data
    bhd   [B,H,dh]   batch->data, heads->model
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    d, m = ctx["data"], ctx["model"]
    if kind == "bsd":
        spec = P(_axes_for(ctx, d, x.shape[0]))
    elif kind in ("bshd",):
        spec = P(_axes_for(ctx, d, x.shape[0]), None,
                 _axes_for(ctx, m, x.shape[2]), None)
    elif kind in ("bsf", "bsv"):
        spec = P(_axes_for(ctx, d, x.shape[0]), None,
                 _axes_for(ctx, m, x.shape[2]))
    elif kind == "bd":
        spec = P(_axes_for(ctx, d, x.shape[0]))
    elif kind == "bhd":
        spec = P(_axes_for(ctx, d, x.shape[0]), _axes_for(ctx, m, x.shape[1]))
    elif kind == "bshp":
        # SSD inputs [B, S, H, P]: SSM head counts rarely divide the model
        # axis, but the head_dim P does - sharding P shards every SSD
        # einsum (state, y_diag, y_off) without touching the recurrence
        spec = P(_axes_for(ctx, d, x.shape[0]), None, None,
                 _axes_for(ctx, m, x.shape[3]))
    else:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx["mesh"], spec)
    )
