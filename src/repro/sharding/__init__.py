"""Logical-axis -> mesh-axis sharding rules and NamedSharding derivation."""
from repro.sharding.rules import (
    AxisRules,
    batch_spec,
    cache_shardings,
    default_rules,
    param_shardings,
    spec_for_axes,
)

__all__ = [
    "AxisRules",
    "batch_spec",
    "cache_shardings",
    "default_rules",
    "param_shardings",
    "spec_for_axes",
]
