"""Logical-axis -> mesh-axis mapping.

Parameter/cache templates carry *logical* axis names (see
``repro.models.common``). This module turns them into concrete
``PartitionSpec``s for a given mesh and ``ParallelPlan``.

Design rules (all enforced mechanically, so every (arch x shape x mesh)
cell lowers without hand-tuning):

  * Each logical axis has an ordered list of *candidate* mesh-axis groups.
  * A candidate is taken only if (a) none of its mesh axes is already used
    by another dim of the same tensor, and (b) the dim size is divisible by
    the product of the candidate's mesh-axis sizes. Otherwise we fall
    through to the next candidate, and finally to replication.
  * Candidates are filtered to axes present in the mesh, so one rule set
    serves both the single-pod ("data","model") and multi-pod
    ("pod","data","model") meshes.

The fallback-to-replication rule is what makes e.g. GQA caches with
kv_heads=8 on a 16-way model axis work: the ``cache_seq`` dim (which is
always a large power of two) takes the model axis instead, turning decode
attention into a flash-decode-style partial-softmax + all-reduce - the
TPU-native analogue of sharding over heads.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.config.parallel import ParallelPlan
from repro.models.common import is_spec

AxisRules = Dict[Optional[str], Tuple[Tuple[str, ...], ...]]


def default_rules(plan: ParallelPlan, kind: str = "train") -> AxisRules:
    """Build the logical->mesh candidate table.

    kind="train": ZeRO-3 FSDP (embed dim sharded over fsdp axes; weights are
    all-gathered per layer inside the scan) x TP over the model axis.

    kind="serve": weights replicated over data axes (no per-step gather on
    the latency path), TP over the model axis; MoE expert FFN dims fall
    through to the data axes when the model axis is taken by the expert dim
    (keeps 235B-scale expert stacks under per-chip HBM).
    """
    t = tuple(plan.tensor_axes)
    d = tuple(plan.data_axes)
    f = tuple(plan.fsdp_axes)
    e = tuple(plan.expert_axes)
    if kind == "train":
        embed = (f,) if plan.zero3 else ()
        ffn: Tuple[Tuple[str, ...], ...] = (t, f)
        vocab = (t,)
    else:  # serve
        embed = ()
        ffn = (t, d)
        vocab = (t,)
    return {
        None: (),
        "layers": (),
        "vocab": vocab,
        "embed": embed,
        "heads": (t,),
        "kv_heads": (t,),
        "ffn": ffn,
        "experts": (e,),
        "ssm_in": (t,),
        "ssm_state": (t,),
        "batch": (d,),
        "cache_seq": (t,),
        "window": (t,),
    }


def spec_for_axes(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Mesh,
) -> P:
    """Assign mesh axes to one tensor's dims (first-fit with divisibility)."""
    used: set = set()
    entries: list = []
    for size, name in zip(shape, axes):
        assigned = None
        for cand in rules.get(name, ()):
            cand = tuple(a for a in cand if a in mesh.axis_names and a not in used)
            if not cand:
                continue
            prod = int(np.prod([mesh.shape[a] for a in cand]))
            if prod > 1 and size % prod == 0:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        entries.append(assigned)
    # strip trailing Nones for tidier HLO annotations
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _tree_shardings(template, rules: AxisRules, mesh: Mesh):
    """ParamSpec tree -> NamedSharding tree."""

    def one(s):
        return NamedSharding(mesh, spec_for_axes(s.shape, s.axes, rules, mesh))

    return jax.tree_util.tree_map(one, template, is_leaf=is_spec)


def param_shardings(template, mesh: Mesh, plan: ParallelPlan, kind: str = "train"):
    return _tree_shardings(template, default_rules(plan, kind), mesh)


def cache_shardings(cache_template, mesh: Mesh, plan: ParallelPlan):
    return _tree_shardings(cache_template, default_rules(plan, "serve"), mesh)


def batch_spec(plan: ParallelPlan, mesh: Mesh, batch_size: int) -> P:
    """PartitionSpec for [B, ...] host batches (tokens/targets/frames)."""
    axes = tuple(a for a in plan.data_axes if a in mesh.axis_names)
    if not axes:
        return P()
    prod = int(np.prod([mesh.shape[a] for a in axes]))
    if batch_size % prod != 0:
        # shed trailing axes until divisible (e.g. global_batch=1 long-ctx)
        while axes:
            prod = int(np.prod([mesh.shape[a] for a in axes]))
            if batch_size % prod == 0:
                break
            axes = axes[:-1]
        if not axes:
            return P()
    return P(axes if len(axes) > 1 else axes[0])


def batch_sharding(plan: ParallelPlan, mesh: Mesh, batch_size: int) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(plan, mesh, batch_size))


def tree_batch_shardings(abstract_batch, plan: ParallelPlan, mesh: Mesh):
    """Shard every leaf of a batch tree over the data axes (dim 0)."""

    def one(x):
        return batch_sharding(plan, mesh, x.shape[0])

    return jax.tree_util.tree_map(one, abstract_batch)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
