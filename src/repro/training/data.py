"""Synthetic data pipeline with background prefetch.

The container has no datasets; the pipeline generates deterministic
pseudo-random token batches (seeded per step, so restart-from-checkpoint
resumes the exact stream - required for bitwise-reproducible recovery
tests). Structure mirrors a real pipeline: an index-based sampler, a
per-batch materialization function, and a double-buffered prefetch thread
so host batch assembly overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.config import ModelConfig
from repro.config.shapes import ShapeConfig


def make_batch(
    cfg: ModelConfig, shape: ShapeConfig, step: int, *, batch_override: int = 0,
    seq_override: int = 0, seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Deterministic synthetic batch for ``step``."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    tokens = rng.integers(0, cfg.vocab_size, (b, s + 1), dtype=np.int32)
    batch: Dict[str, np.ndarray] = {
        "tokens": tokens[:, :-1],
        "targets": tokens[:, 1:],
    }
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (b, cfg.encoder_frames, cfg.d_model), dtype=np.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (b, cfg.num_patches, cfg.d_model), dtype=np.float32
        )
    return batch


class PrefetchingLoader:
    """Iterator that materializes batches on a background thread."""

    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        *,
        start_step: int = 0,
        num_steps: Optional[int] = None,
        batch_override: int = 0,
        seq_override: int = 0,
        seed: int = 0,
        prefetch: int = 2,
    ):
        self.cfg, self.shape = cfg, shape
        self.start_step = start_step
        self.num_steps = num_steps
        self.batch_override = batch_override
        self.seq_override = seq_override
        self.seed = seed
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.start_step
        while not self._stop.is_set():
            if self.num_steps is not None and step >= self.start_step + self.num_steps:
                self._q.put(None)
                return
            batch = make_batch(
                self.cfg,
                self.shape,
                step,
                batch_override=self.batch_override,
                seq_override=self.seq_override,
                seed=self.seed,
            )
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
