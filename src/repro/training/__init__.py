"""Training substrate: optimizer, step builder, checkpointing, data."""
from repro.training.optimizer import AdamWState, adamw_init, adamw_update
from repro.training.train_step import TrainState, build_train_step, make_train_state_specs

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "TrainState",
    "build_train_step",
    "make_train_state_specs",
]
