"""Sharded, fault-tolerant checkpointing with an async background writer.

Format: one directory per step:

    <dir>/step_000123/
        manifest.json     # tree structure, shapes, dtypes, shard map, hashes
        shard_<i>.npz     # flat arrays owned by host shard i

Design points required at 1000+ node scale:
  * **Sharded writes** - each host writes only the addressable shards of its
    local devices (single-host here, but the shard loop is per-device).
  * **Async** - ``AsyncCheckpointer.save`` snapshots device arrays to host
    memory synchronously (cheap) and writes to disk on a background thread,
    overlapping I/O with the next training steps; ``wait()`` joins.
  * **Integrity** - every shard file carries a content hash recorded in the
    manifest; restore verifies before use (detects torn writes from a node
    dying mid-checkpoint).
  * **Atomicity** - writes go to ``<dir>.tmp`` and are renamed only after
    the manifest is fsync'd, so a crash never leaves a half checkpoint that
    looks valid.
  * **Resharding restore** - arrays are saved unsharded-per-shard with
    global metadata; ``restore`` accepts any target sharding tree and uses
    ``jax.make_array_from_callback`` so a 16-device checkpoint can restart
    on a 512-device mesh (elastic restart).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name, leaf))
    return out


def _hash_bytes(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree) -> str:
    """Synchronous sharded save. Returns the checkpoint path."""
    ckpt = os.path.join(directory, f"step_{step:08d}")
    tmp = ckpt + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    named = _flatten_with_names(tree)
    arrays: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            # npz cannot store bf16; round-trip via uint16 view
            stored = arr.view(np.uint16)
            dtype_tag = "bfloat16"
        else:
            stored = arr
            dtype_tag = str(arr.dtype)
        key = f"leaf_{i:05d}"
        arrays[key] = stored
        manifest["leaves"][name] = {
            "key": key,
            "shape": list(arr.shape),
            "dtype": dtype_tag,
        }
    shard_path = os.path.join(tmp, "shard_00000.npz")
    np.savez(shard_path, **arrays)
    with open(shard_path, "rb") as f:
        manifest["shards"] = {"shard_00000.npz": _hash_bytes(f.read())}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)
    return ckpt


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: Optional[int],
    abstract_tree,
    shardings=None,
):
    """Restore into ``abstract_tree`` structure, resharding to ``shardings``.

    ``shardings`` (optional) is a matching tree of NamedSharding; when given,
    arrays are placed with ``jax.device_put`` per-sharding (works across any
    mesh, enabling elastic restart on different topology).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    # integrity check
    for fname, want in manifest["shards"].items():
        with open(os.path.join(ckpt, fname), "rb") as f:
            got = _hash_bytes(f.read())
        if got != want:
            raise IOError(f"checkpoint shard {fname} corrupt: {got} != {want}")
    data = np.load(os.path.join(ckpt, "shard_00000.npz"))

    named = _flatten_with_names(abstract_tree)
    treedef = jax.tree_util.tree_structure(abstract_tree)
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "addressable_devices")
        )
        if shardings is not None
        else [None] * len(named)
    )
    out = []
    for (name, leaf), sh in zip(named, shard_leaves):
        meta = manifest["leaves"][name]
        raw = data[meta["key"]]
        if meta["dtype"] == "bfloat16":
            arr = raw.view(jnp.bfloat16)
        else:
            arr = raw.astype(meta["dtype"])
        arr = arr.reshape(meta["shape"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer (overlaps I/O with training)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next save/wait
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.directory) if d.startswith("step_")
            and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d))

    def save(self, step: int, tree):
        """Snapshot to host memory now; write to disk in the background."""
        if self._err:
            raise self._err
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
