"""Train-step builder: value_and_grad + AdamW with FSDP/TP shardings.

``build_train_step`` returns a pure ``step(state, batch) -> (state, metrics)``
plus the NamedSharding trees for state and batch, ready for
``jax.jit(step, in_shardings=..., out_shardings=..., donate_argnums=0)``.

Scale features folded in:
  * remat policy from the ParallelPlan ("none" | "full" | "dots");
  * gradient accumulation via ``lax.scan`` over microbatches (the scan keeps
    HLO size O(1) in the accumulation count);
  * optional int8 error-feedback gradient compression round-trip (models the
    cross-pod link payload; see repro.training.compress);
  * ZeRO-3: parameter/optimizer sharding comes from repro.sharding rules -
    XLA inserts the per-layer all-gathers inside the layer scan, which is
    where compute/communication overlap happens (latency hiding over the
    scan's sequential dimension).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.parallel import ParallelPlan
from repro.models.model import ModelApi
from repro.sharding.rules import (
    batch_sharding,
    param_shardings,
    replicated,
)
from repro.training import compress as compress_lib
from repro.training.optimizer import (
    AdamWState,
    abstract_adamw_state,
    adamw_init,
    adamw_update,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    residual: Any  # CompressedState or None (compression off)


def init_train_state(api: ModelApi, rng: jax.Array, plan: ParallelPlan) -> TrainState:
    params = api.init_params(rng)
    residual = (
        compress_lib.init_residual(params) if plan.compress_grads else None
    )
    return TrainState(params=params, opt=adamw_init(params), residual=residual)


def abstract_train_state(api: ModelApi, plan: ParallelPlan) -> TrainState:
    ap = api.abstract_params()
    residual = None
    if plan.compress_grads:
        residual = compress_lib.CompressedState(
            residual=jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), ap
            )
        )
    return TrainState(params=ap, opt=abstract_adamw_state(ap), residual=residual)


def make_train_state_specs(
    api: ModelApi, plan: ParallelPlan, mesh: Mesh
) -> Tuple[TrainState, TrainState]:
    """Returns (abstract_state, state_shardings)."""
    abstract = abstract_train_state(api, plan)
    pshard = param_shardings(api.param_template, mesh, plan, kind="train")
    f32_shard = pshard  # moments/residual inherit the parameter sharding
    shardings = TrainState(
        params=pshard,
        opt=AdamWState(step=replicated(mesh), mu=f32_shard, nu=f32_shard),
        residual=(
            compress_lib.CompressedState(residual=f32_shard)
            if plan.compress_grads
            else None
        ),
    )
    return abstract, shardings


def _split_microbatches(batch, accum: int):
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} not divisible by grad_accum {accum}"
        return x.reshape(accum, b // accum, *x.shape[1:])

    return jax.tree_util.tree_map(split, batch)


def build_train_step(
    api: ModelApi,
    plan: ParallelPlan,
    *,
    lr: float = 3e-4,
    weight_decay: float = 0.1,
    warmup_steps: int = 100,
    total_steps: int = 10000,
) -> Callable:
    """Pure (state, batch) -> (state, metrics). Not yet jitted."""

    def loss_fn(params, mb):
        return api.train_loss(params, mb, remat=plan.remat)

    def step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        accum = max(1, plan.grad_accum)
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            mbs = _split_microbatches(batch, accum)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )

            def body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + l, g_acc), None

            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), mbs)
            loss = loss / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)

        residual = state.residual
        if plan.compress_grads and residual is not None:
            grads, residual = compress_lib.tree_compress_with_feedback(
                grads, residual
            )

        new_params, new_opt, opt_metrics = adamw_update(
            grads,
            state.opt,
            state.params,
            lr=lr,
            weight_decay=weight_decay,
            warmup_steps=warmup_steps,
            total_steps=total_steps,
        )
        metrics = {"loss": loss.astype(jnp.float32), **opt_metrics}
        return TrainState(new_params, new_opt, residual), metrics

    return step


def jit_train_step(
    api: ModelApi,
    plan: ParallelPlan,
    mesh: Mesh,
    abstract_batch,
    **kw,
):
    """AOT-ready jitted train step with explicit in/out shardings.

    Returns (jitted_fn, abstract_state, state_shardings, batch_shardings).
    """
    step = build_train_step(api, plan, **kw)
    abstract, state_sh = make_train_state_specs(api, plan, mesh)
    batch_sh = jax.tree_util.tree_map(
        lambda x: batch_sharding(plan, mesh, x.shape[0]), abstract_batch
    )
    metrics_sh = {
        "loss": replicated(mesh),
        "grad_norm": replicated(mesh),
        "lr": replicated(mesh),
    }
    fn = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
    )
    return fn, abstract, state_sh, batch_sh
