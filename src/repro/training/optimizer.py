"""AdamW with decoupled weight decay and f32 master moments.

Implemented from scratch (no optax in the container). Moments are kept in
float32 regardless of the parameter dtype; the update math runs in f32 and
is cast back to the parameter dtype at the end, which is the standard
mixed-precision recipe for bf16 training.

State sharding: each moment tensor inherits the *parameter's* sharding, so
under ZeRO-3 the optimizer state is fully sharded too (this is what makes
95-layer x 8192-width training fit the 16 GB/chip budget).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array       # scalar int32
    mu: Any               # first moment tree (f32)
    nu: Any               # second moment tree (f32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def abstract_adamw_state(abstract_params) -> AdamWState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree_util.tree_map(f32, abstract_params),
        nu=jax.tree_util.tree_map(f32, abstract_params),
    )


def _cosine_lr(step, base_lr, warmup, total):
    warm = base_lr * (step + 1) / max(1, warmup)
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    warmup_steps: int = 100,
    total_steps: int = 10000,
    grad_clip: float = 1.0,
):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step
    lr_t = _cosine_lr(step.astype(jnp.float32), lr, warmup_steps, total_steps)

    # global-norm clip in f32
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    )
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))

    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * jnp.square(gf)
        mhat = m2 / bc1
        vhat = v2 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr_t * (step_ + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr_t}
    return new_params, AdamWState(step + 1, new_mu, new_nu), metrics
