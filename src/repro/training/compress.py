"""int8 error-feedback gradient compression.

Used in two places:

  * **Grad-accum accumulator** - when ``grad_accum > 1``, per-microbatch
    gradients are accumulated in int8 + per-tensor f32 scale with an
    error-feedback residual, halving the HBM footprint and bandwidth of the
    accumulator loop relative to an f32 buffer (the dominant memory-term
    cost of large accumulation counts).

  * **Cross-replica all-reduce** (``compress_psum``) - inside ``shard_map``
    regions the gradient all-reduce over a (slow, cross-pod) axis can be
    performed on the int8 payload: quantize -> psum(int8-as-int32) ->
    dequantize, with the quantization error fed back into the next step's
    gradient. This is the classic 1-bit-Adam-family trick adapted to int8.

Error feedback guarantees the *time-averaged* gradient is unbiased: the
residual e_t = g_t - dq(q(g_t + e_{t-1})) is added to the next gradient, so
quantization error does not accumulate as bias (Karimireddy et al., 2019).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedState(NamedTuple):
    """Error-feedback residual tree (same structure/dtype=f32 as grads)."""

    residual: Any


def init_residual(params) -> CompressedState:
    return CompressedState(
        residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    )


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, residual: jax.Array):
    """Quantize g+residual; return (q, scale, new_residual)."""
    corrected = g.astype(jnp.float32) + residual
    q, scale = quantize(corrected)
    new_residual = corrected - dequantize(q, scale)
    return q, scale, new_residual


def tree_compress_with_feedback(grads, state: CompressedState):
    """Apply error-feedback int8 compression leaf-wise.

    Returns (dequantized grads tree, new CompressedState). The round trip
    through int8 is what a cross-link transfer would carry; callers that
    own a ``shard_map`` axis can psum the int8 payload instead.
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(state.residual)
    outs = [compress_with_feedback(g, r) for g, r in zip(flat_g, flat_r)]
    dq = jax.tree_util.tree_unflatten(
        treedef, [dequantize(q, s) for q, s, _ in outs]
    )
    new_res = jax.tree_util.tree_unflatten(treedef, [r for _, _, r in outs])
    return dq, CompressedState(residual=new_res)


def compress_psum(g: jax.Array, residual: jax.Array, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (shard_map only).

    The int8 payload is widened to int32 for the integer psum (TPU ICI
    reduces int32 natively); the *communicated* volume in a real bucketed
    implementation is the int8 tensor + one f32 scale. We also psum the
    scale and use the max scale across replicas so dequantization is
    consistent.
    """
    corrected = g.astype(jnp.float32) + residual
    q, scale = quantize(corrected)
    new_residual = corrected - dequantize(q, scale)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    q_shared = jnp.clip(
        jnp.round(corrected / scale_max), -127, 127
    ).astype(jnp.int32)
    summed = jax.lax.psum(q_shared, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = summed.astype(jnp.float32) * scale_max / n
    return mean, new_residual
