"""Composition lint: graph-level checks on the built IR.

Where the purity verifier reads payload *source*, this pass reads the
compiled :class:`~repro.core.dag.Composition` — the shape mistakes that
``validate()`` (which guards well-formedness) deliberately accepts but
that waste work or mislead at runtime:

  * ``graph-unreachable``     (warn) — a vertex no composition input can
    reach: registered, scheduled against, never fed by a request;
  * ``graph-dangling-output`` (info) — an output set consumed by no edge
    and exported by no output binding (often fine: the last decode step
    of an inference chain legitimately drops its ``kv`` set);
  * ``graph-comm-retry``      (warn) — a ``RetryPolicy`` on a COMM
    vertex: the dispatcher only honors retries when the in-flight
    payload's method is idempotent (``Dispatcher._comm_idempotent``,
    PR 6), so a retry budget on a POST-carrying vertex silently does
    nothing;
  * ``graph-fanout-local``    (info) — an ``each``/``key`` fan-out on a
    multi-node deployment without ``crossnode``: every instance lands on
    the owning node (the fig12 oversubscription scenario).

Severities are chosen so the repo's own apps stay strict-clean: none of
these is provably wrong from the graph alone, so none blocks.
"""
from __future__ import annotations

from typing import List, Optional, Set

from ..core import dag
from .findings import Finding, INFO, Report, WARN


def _idempotent_methods() -> frozenset:
    try:
        from ..core.dispatcher import IDEMPOTENT_METHODS
        return frozenset(IDEMPOTENT_METHODS)
    except Exception:
        return frozenset({"GET", "HEAD", "OPTIONS", "PUT", "DELETE"})


def _reachable_from(comp: "dag.Composition", roots: Set[str]) -> Set[str]:
    seen = set(roots)
    frontier = list(roots)
    while frontier:
        v = frontier.pop()
        for e in comp.out_edges(v):
            if e.dst.vertex not in seen:
                seen.add(e.dst.vertex)
                frontier.append(e.dst.vertex)
    return seen


def lint_composition(comp: "dag.Composition", *, cluster: bool = False,
                     crossnode: bool = False,
                     _prefix: str = "") -> Report:
    """Lint one composition (recursing into SUBGRAPH vertices)."""
    findings: List[Finding] = []
    loc = f"<composition:{comp.name}>"

    def here(v: str) -> str:
        return f"{_prefix}{v}"

    # unreachable: only meaningful relative to declared inputs — in a
    # DAG every vertex is reachable from *some* zero-in-degree vertex,
    # so we ask the stronger question "can a request's inputs reach it?"
    if comp.input_bindings:
        roots = {p.vertex for p in comp.input_bindings.values()}
        reach = _reachable_from(comp, roots)
        for name in comp.vertices:
            if name not in reach:
                findings.append(Finding(
                    rule="graph-unreachable", severity=WARN, file=loc,
                    line=0, function=here(name),
                    message=f"vertex {name!r} is unreachable from the "
                            f"composition inputs "
                            f"{sorted(comp.input_bindings)}; it will "
                            f"never receive request data"))

    exported = {p for p in comp.output_bindings.values()}
    for v in comp.vertices.values():
        consumed = {e.src.set_name for e in comp.out_edges(v.name)}
        for out_set in v.outputs:
            if out_set in consumed:
                continue
            if any(p.vertex == v.name and p.set_name == out_set
                   for p in exported):
                continue
            findings.append(Finding(
                rule="graph-dangling-output", severity=INFO, file=loc,
                line=0, function=here(v.name),
                message=f"output set {out_set!r} of {v.name!r} feeds no "
                        f"edge and no output binding; its items are "
                        f"dropped on completion"))

        if (v.kind == dag.COMM and v.retry is not None
                and v.retry.max_retries > 0):
            methods = ", ".join(sorted(_idempotent_methods()))
            findings.append(Finding(
                rule="graph-comm-retry", severity=WARN, file=loc,
                line=0, function=here(v.name),
                message=f"RetryPolicy(max_retries="
                        f"{v.retry.max_retries}) on COMM vertex "
                        f"{v.name!r}: the dispatcher retries comm tasks "
                        f"only for idempotent payload methods "
                        f"({methods}); non-idempotent requests fail "
                        f"without retry regardless of this policy"))

        if v.kind == dag.SUBGRAPH and v.subgraph is not None:
            findings.extend(lint_composition(
                v.subgraph, cluster=cluster, crossnode=crossnode,
                _prefix=f"{here(v.name)}/").findings)

    if cluster and not crossnode:
        for e in comp.edges:
            if e.mode in ("each", "key"):
                findings.append(Finding(
                    rule="graph-fanout-local", severity=INFO, file=loc,
                    line=0, function=here(e.dst.vertex),
                    message=f"'{e.mode}' fan-out into "
                            f"{e.dst.vertex!r} on a multi-node "
                            f"deployment without crossnode: every "
                            f"instance is placed on the owning node "
                            f"(enable crossnode=True / CROSSNODE=1 to "
                            f"spread)"))

    return Report(findings)


def registration_lint_hook(mode: str = "warn"):
    """Build a hook for :func:`repro.core.dag.add_registration_hook`.

    ``warn`` emits one ``warnings.warn`` per linted composition with
    findings; ``strict`` raises ``ValueError`` when any unwaived
    warn/error-severity finding exists. The hook runs at
    ``FunctionRegistry.register_composition`` time — before any
    dispatch touches the graph.
    """
    if mode not in ("warn", "strict"):
        raise ValueError(f"registration lint mode must be 'warn' or "
                         f"'strict', got {mode!r}")

    def hook(comp: "dag.Composition") -> None:
        report = lint_composition(comp)
        if not report.findings:
            return
        serious = [f for f in report.unwaived
                   if f.severity in (WARN, "error")]
        if mode == "strict" and serious:
            raise ValueError(
                f"composition {comp.name!r} failed registration lint:\n"
                + "\n".join(f.render() for f in serious))
        if serious:
            import warnings
            warnings.warn(
                f"composition {comp.name!r}: "
                + "; ".join(f.render() for f in serious),
                stacklevel=3)

    return hook
