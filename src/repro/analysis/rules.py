"""Rule predicates shared by the purity verifier and the determinism lint.

Each ``check_*`` takes an AST node plus a :class:`RuleContext` and flags
findings into the context's :class:`~repro.analysis.walker.Analysis`.
The purity pass runs the full list over compute-function bodies; the
det-lint pass runs the byte-identity subset (wall-clock, rng, set-iter,
id-order, builtin-hash) over whole simulator modules — I/O and mutation
are legitimate for the simulator itself, which *models* a cluster.

Name matching is canonical, not textual: ``np.random.normal`` and
``numpy.random.normal`` resolve identically through the
:class:`~repro.analysis.walker.ImportTable` (file imports for det-lint,
the live ``__globals__`` for payload analysis), so aliasing cannot dodge
a rule.
"""
from __future__ import annotations

import ast
from typing import FrozenSet, Optional

from .walker import (Analysis, ImportTable, dotted_name, is_set_expr,
                     root_name)

# --------------------------------------------------------------- catalogs
WALL_CLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns", "time.clock_gettime",
    "time.sleep",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

# stdlib ``random`` functions that draw from the process-global RNG
RANDOM_GLOBAL_FNS = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "triangular", "gauss", "normalvariate",
    "lognormvariate", "expovariate", "vonmisesvariate", "betavariate",
    "paretovariate", "weibullvariate", "getrandbits", "randbytes", "seed",
})

# ``numpy.random`` module-level functions backed by the global RandomState
NP_GLOBAL_FNS = frozenset({
    "seed", "random", "rand", "randn", "randint", "random_sample",
    "random_integers", "ranf", "sample", "choice", "shuffle",
    "permutation", "bytes", "uniform", "normal", "standard_normal",
    "poisson", "exponential", "beta", "binomial", "gamma", "lognormal",
    "laplace", "gumbel", "logistic", "multinomial",
    "multivariate_normal", "dirichlet", "geometric", "hypergeometric",
    "negative_binomial", "pareto", "power", "rayleigh", "triangular",
    "vonmises", "wald", "weibull", "zipf", "chisquare", "f",
    "noncentral_chisquare", "noncentral_f", "standard_cauchy",
    "standard_exponential", "standard_gamma", "standard_t",
})

IO_ROOT_PREFIXES = (
    "subprocess.", "socket.", "shutil.", "requests.", "urllib.",
    "http.client.", "ftplib.", "smtplib.", "sqlite3.",
    "sys.stdout.", "sys.stderr.", "sys.stdin.",
)

MUTATING_METHODS = frozenset({
    "append", "extend", "insert", "remove", "pop", "clear", "update",
    "add", "discard", "setdefault", "popitem", "sort", "reverse",
    "write", "writelines", "send", "put",
})

# aggregates whose result does not depend on iteration order
ORDER_INSENSITIVE = frozenset({
    "sum", "min", "max", "any", "all", "len", "sorted", "set",
    "frozenset",
})


class RuleContext:
    """Everything a rule needs about the tree under analysis."""

    def __init__(self, analysis: Analysis, imports: ImportTable,
                 parents, *, local_names: FrozenSet[str] = frozenset(),
                 set_locals: FrozenSet[str] = frozenset()) -> None:
        self.analysis = analysis
        self.imports = imports
        self.parents = parents
        self.local_names = frozenset(local_names)
        self.set_locals = frozenset(set_locals)

    def canon(self, node: ast.AST) -> Optional[str]:
        dotted = dotted_name(node)
        if dotted is None:
            return None
        root = dotted.split(".", 1)[0]
        if root in self.local_names:     # shadowed by a local binding
            return None
        return self.imports.resolve(dotted)

    def flag(self, rule: str, node: ast.AST, message: str, *,
             severity: str = "error") -> None:
        self.analysis.flag(rule, node, message, severity=severity)


# ------------------------------------------------------------ byte-identity
def check_wall_clock(node: ast.AST, ctx: RuleContext) -> None:
    if not isinstance(node, ast.Call):
        return
    canon = ctx.canon(node.func)
    if canon in WALL_CLOCK_CALLS:
        ctx.flag("wall-clock", node,
                 f"{canon}() reads the host clock; modeled paths must "
                 f"take time from the event loop")


def check_rng(node: ast.AST, ctx: RuleContext) -> None:
    if not isinstance(node, ast.Call):
        return
    canon = ctx.canon(node.func)
    if canon is None:
        return
    seeded = bool(node.args or node.keywords)
    if canon.startswith("random."):
        attr = canon.split(".", 1)[1]
        if attr in RANDOM_GLOBAL_FNS:
            ctx.flag("rng", node,
                     f"{canon}() draws from the process-global RNG; "
                     f"use a seeded random.Random(seed)")
        elif attr == "Random" and not seeded:
            ctx.flag("rng", node, "random.Random() without a seed")
        elif attr == "SystemRandom":
            ctx.flag("rng", node, "random.SystemRandom is entropy-backed "
                                  "and never reproducible")
    elif canon.startswith("numpy.random."):
        attr = canon.split(".", 2)[2] if canon.count(".") >= 2 else ""
        if attr == "default_rng" and not seeded:
            ctx.flag("rng", node,
                     "numpy.random.default_rng() without a seed")
        elif attr == "RandomState" and not seeded:
            ctx.flag("rng", node,
                     "numpy.random.RandomState() without a seed")
        elif attr in NP_GLOBAL_FNS:
            ctx.flag("rng", node,
                     f"numpy.random.{attr}() uses the global RandomState; "
                     f"use a seeded default_rng(seed)")


def check_builtin_hash(node: ast.AST, ctx: RuleContext) -> None:
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "hash"
            and "hash" not in ctx.local_names
            and ctx.imports.resolve("hash") == "hash"):
        ctx.flag("builtin-hash", node,
                 "hash() on str/bytes is salted per process "
                 "(PYTHONHASHSEED); use zlib.crc32 or hashlib for "
                 "stable digests")


def _is_setty(expr: ast.AST, ctx: RuleContext) -> bool:
    if is_set_expr(expr):
        return True
    return isinstance(expr, ast.Name) and expr.id in ctx.set_locals


def check_set_iter(node: ast.AST, ctx: RuleContext) -> None:
    if isinstance(node, (ast.For, ast.AsyncFor)):
        if _is_setty(node.iter, ctx):
            ctx.flag("set-iter", node.iter,
                     "for-loop over a set: iteration order follows the "
                     "hash seed; wrap in sorted(...)")
        return
    if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp,
                         ast.DictComp)):
        parent = ctx.parents.get(node)
        if (isinstance(parent, ast.Call)
                and isinstance(parent.func, ast.Name)
                and parent.func.id in ORDER_INSENSITIVE):
            return                       # sum(... for x in S) is order-safe
        for gen in node.generators:
            if _is_setty(gen.iter, ctx):
                ctx.flag("set-iter", gen.iter,
                         "comprehension over a set feeds an "
                         "order-sensitive consumer; wrap in sorted(...)")


def _is_id_call(expr: ast.AST) -> bool:
    return (isinstance(expr, ast.Call)
            and isinstance(expr.func, ast.Name) and expr.func.id == "id")


def _orders_by_id(expr: ast.AST) -> bool:
    """True when ``expr``'s *value* is an id() (directly or as a tuple
    component) — i.e. the ordering itself is an object address. id()
    merely appearing inside a subscript/call (identity-keyed dict
    lookups like ``load[id(n)]``) is deterministic data access, not
    address-based ordering, and is not flagged."""
    if _is_id_call(expr):
        return True
    if isinstance(expr, (ast.Tuple, ast.List)):
        return any(_is_id_call(e) for e in expr.elts)
    return False


def check_id_order(node: ast.AST, ctx: RuleContext) -> None:
    if not isinstance(node, ast.Call):
        return
    canon = ctx.canon(node.func)
    if canon in ("sorted", "min", "max"):
        for kw in node.keywords:
            if kw.arg == "key" and (
                    (isinstance(kw.value, ast.Name) and kw.value.id == "id")
                    or (isinstance(kw.value, ast.Lambda)
                        and _orders_by_id(kw.value.body))):
                ctx.flag("id-order", node,
                         f"{canon}(key=id): object addresses vary per "
                         f"process; order by a stable field")
    elif canon in ("heapq.heappush", "heapq.heappushpop", "heapq.heapify",
                   "heapq.merge"):
        for arg in node.args[1:] or node.args:
            if _orders_by_id(arg):
                ctx.flag("id-order", node,
                         f"{canon} entry contains id(): heap order "
                         f"becomes address-dependent; use a sequence "
                         f"counter")


# ----------------------------------------------------------------- purity
def check_io(node: ast.AST, ctx: RuleContext) -> None:
    if not isinstance(node, ast.Call):
        return
    if isinstance(node.func, ast.Name) and node.func.id not in ctx.local_names:
        if node.func.id in ("open", "input"):
            ctx.flag("io", node, f"{node.func.id}() performs host I/O")
            return
        if node.func.id == "print":
            ctx.flag("io", node, "print() writes to stdout — side effect "
                                 "outside the declared outputs")
            return
    canon = ctx.canon(node.func)
    if canon is None:
        return
    if canon in ("builtins.open", "builtins.input", "io.open"):
        ctx.flag("io", node, f"{canon}() performs host I/O")
    elif canon == "builtins.print":
        ctx.flag("io", node, "print() writes to stdout — side effect "
                             "outside the declared outputs")
    elif canon.startswith(IO_ROOT_PREFIXES):
        ctx.flag("io", node, f"{canon}() reaches outside the sandbox "
                             f"(file/network/process I/O)")
    elif canon.startswith("os.") and not canon.startswith("os.path."):
        ctx.flag("io", node, f"{canon}() touches host OS state; pure "
                             f"functions see only their declared inputs")


def check_global_mutation(node: ast.AST, ctx: RuleContext) -> None:
    if isinstance(node, ast.Global):
        ctx.flag("global-mutation", node,
                 f"global {', '.join(node.names)}: rebinding module "
                 f"state breaks idempotent re-execution")
        return
    if isinstance(node, ast.Nonlocal):
        ctx.flag("global-mutation", node,
                 f"nonlocal {', '.join(node.names)}: rebinding "
                 f"closed-over state breaks idempotent re-execution")
        return
    if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in targets:
            if isinstance(t, (ast.Attribute, ast.Subscript)):
                root = root_name(t)
                if root is not None and root not in ctx.local_names:
                    ctx.flag("global-mutation", node,
                             f"assignment into non-local '{root}' mutates "
                             f"state shared across invocations")
        return
    if isinstance(node, ast.Delete):
        for t in node.targets:
            if isinstance(t, (ast.Attribute, ast.Subscript)):
                root = root_name(t)
                if root is not None and root not in ctx.local_names:
                    ctx.flag("global-mutation", node,
                             f"del on non-local '{root}' mutates shared "
                             f"state")
        return
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr in MUTATING_METHODS):
        root = root_name(node.func.value)
        if root is not None and root not in ctx.local_names:
            ctx.flag("global-mutation", node,
                     f"'{root}.{node.func.attr}(...)' mutates non-local "
                     f"state shared across invocations")


#: byte-identity subset (det-lint over simulator sources)
DETERMINISM_CHECKS = (check_wall_clock, check_rng, check_builtin_hash,
                      check_set_iter, check_id_order)

#: full purity contract (compute-function bodies)
PURITY_CHECKS = DETERMINISM_CHECKS + (check_io, check_global_mutation)
