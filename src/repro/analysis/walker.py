"""Shared AST-walker core for all three analysis passes.

The purity verifier, the determinism lint, and (indirectly) the
composition lint all sit on the helpers here:

  * ``parse_pragmas``    — the waiver-pragma grammar
                           (``# det-lint: waive[rule,...] reason=...``);
  * ``ImportTable``      — canonicalizes local names against the file's
                           imports (``np`` -> ``numpy``, ``perf_counter``
                           -> ``time.perf_counter``), with an optional
                           runtime resolver (``fn.__globals__``) layered
                           on top for payload analysis;
  * ``dotted_name``      — collapses ``Attribute`` chains to a dotted
                           string rooted at a ``Name``;
  * ``parent_map``       — child -> parent links so rules can ask "is
                           this comprehension feeding ``sum``/``sorted``?";
  * ``collect_bindings`` — names bound inside a function body (params,
                           assignments, loops, comprehensions, walrus),
                           used to separate locals from closed-over or
                           global state;
  * ``Analysis``         — the per-target accumulator: flags findings,
                           then applies waivers deterministically.

Waiver grammar (both the det-lint CLI and purity analysis honor it):

  ``# det-lint: waive[rule1,rule2] reason=why this is legitimately real``
      on the offending line (or alone on the line directly above it);
  ``# det-lint: file waive[rule] reason=...``
      anywhere in the file — waives the rule for the whole file.

``waive[*]`` waives every rule at that scope. A pragma without a
``reason=`` is itself a finding (``bad-waiver``): waivers must name the
contract they invoke (real-exec vs. modeled path).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .findings import ERROR, Finding, Report

PRAGMA_RE = re.compile(
    r"#\s*det-lint:\s*(?P<file>file\s+)?waive\[(?P<rules>[^\]]*)\]"
    r"(?:\s+reason=(?P<reason>.*?))?\s*$"
)


class Waivers:
    """Parsed waiver pragmas for one source file."""

    def __init__(self) -> None:
        # lineno -> {rule or "*": reason}
        self.line: Dict[int, Dict[str, str]] = {}
        # rule or "*" -> reason (file scope)
        self.file: Dict[str, str] = {}
        self.bad: List[Tuple[int, str]] = []  # (lineno, message)

    def reason_for(self, rule: str, lineno: int) -> Optional[str]:
        """Waiver reason covering ``rule`` at ``lineno``, or None."""
        for scope in (self.line.get(lineno, {}), self.file):
            hit = scope.get(rule, scope.get("*"))
            if hit is not None:
                return hit
        return None


def parse_pragmas(lines: Sequence[str], *, first_lineno: int = 1) -> Waivers:
    """Extract waiver pragmas from source lines.

    ``first_lineno`` is the file lineno of ``lines[0]`` — payload
    analysis parses a dedented block but records findings in file
    coordinates, so its waivers must live there too. A pragma on a line
    that holds *only* the comment also covers the next line, so hazards
    can be annotated above long statements.
    """
    w = Waivers()
    for i, raw in enumerate(lines, start=first_lineno):
        m = PRAGMA_RE.search(raw)
        if not m:
            continue
        rules = [r.strip() for r in m.group("rules").split(",") if r.strip()]
        reason = (m.group("reason") or "").strip()
        if not rules:
            w.bad.append((i, "waiver pragma with empty rule list"))
            continue
        if not reason:
            w.bad.append((i, "waiver pragma missing reason="))
            continue
        entry = {r: reason for r in rules}
        if m.group("file"):
            w.file.update(entry)
            continue
        w.line.setdefault(i, {}).update(entry)
        if raw.lstrip().startswith("#"):  # comment-only line: cover next
            w.line.setdefault(i + 1, {}).update(entry)
    return w


class ImportTable:
    """Canonicalize dotted names against a file's imports.

    ``import numpy as np``            -> np.X        => numpy.X
    ``from time import perf_counter`` -> perf_counter => time.perf_counter
    ``from datetime import datetime`` -> datetime.now => datetime.datetime.now

    ``runtime`` (a function's ``__globals__`` merged with its closure
    cells) takes precedence when available — payload analysis resolves
    roots against the live namespace, so aliases never fool it.
    """

    def __init__(self, runtime: Optional[Dict[str, object]] = None) -> None:
        self.aliases: Dict[str, str] = {}
        self.runtime = runtime or {}

    @classmethod
    def from_tree(cls, tree: ast.AST,
                  runtime: Optional[Dict[str, object]] = None
                  ) -> "ImportTable":
        table = cls(runtime)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    table.aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    table.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}")
        return table

    def _canon_root(self, root: str) -> Optional[str]:
        obj = self.runtime.get(root)
        if obj is not None:
            mod = getattr(obj, "__name__", None)
            if isinstance(obj, type(ast)):        # a module object
                return mod
            qual = getattr(obj, "__qualname__", None)
            owner = getattr(obj, "__module__", None)
            if qual and owner:
                return f"{owner}.{qual}"
        return self.aliases.get(root)

    def resolve(self, dotted: str) -> str:
        """Rewrite the root segment to its canonical module path."""
        root, _, rest = dotted.partition(".")
        canon = self._canon_root(root)
        if canon is None:
            return dotted
        return f"{canon}.{rest}" if rest else canon


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for an Attribute chain rooted at a Name, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """Base Name of an Attribute/Subscript/Starred chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _bind_target(target: ast.AST, names: Set[str]) -> None:
    # only structural targets bind names; ``x[i] = v`` / ``x.a = v``
    # *mutate* x (the global-mutation rule's business), they don't bind it
    if isinstance(target, ast.Name):
        names.add(target.id)
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            _bind_target(elt, names)
    elif isinstance(target, ast.Starred):
        _bind_target(target.value, names)


def collect_bindings(fn_node: ast.AST) -> Set[str]:
    """Names bound anywhere in a function body (its local scope).

    Conservative: nested ``def``/``lambda`` parameters are included too,
    which can only *suppress* findings (never invent them) — acceptable
    for a lint whose errors must be trustworthy.
    """
    names: Set[str] = set()
    args = getattr(fn_node, "args", None)
    if args is not None:
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            names.add(a.arg)
        if args.vararg:
            names.add(args.vararg.arg)
        if args.kwarg:
            names.add(args.kwarg.arg)
    for node in ast.walk(fn_node):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                _bind_target(t, names)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            _bind_target(node.target, names)
        elif isinstance(node, ast.comprehension):
            _bind_target(node.target, names)
        elif isinstance(node, ast.NamedExpr):
            _bind_target(node.target, names)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    _bind_target(item.optional_vars, names)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            names.add(node.name)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.arg):
            names.add(node.arg)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                names.add((a.asname or a.name).split(".")[0])
    return names


def set_typed_locals(scope_node: ast.AST) -> Set[str]:
    """Names assigned a syntactically-evident set expression in scope."""
    out: Set[str] = set()
    for node in ast.walk(scope_node):
        if isinstance(node, ast.Assign) and is_set_expr(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif (isinstance(node, ast.AnnAssign) and node.value is not None
              and is_set_expr(node.value)
              and isinstance(node.target, ast.Name)):
            out.add(node.target.id)
    return out


def is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return is_set_expr(node.left) or is_set_expr(node.right)
    return False


class Analysis:
    """Per-target accumulator: rules flag into it, waivers apply once.

    ``line_offset`` shifts node linenos into file coordinates when the
    analyzed tree was parsed from a dedented block (payload analysis).
    """

    def __init__(self, file: str, *, waivers: Optional[Waivers] = None,
                 line_offset: int = 0, function: str = "") -> None:
        self.file = file
        self.waivers = waivers or Waivers()
        self.line_offset = line_offset
        self.function = function
        self._findings: List[Finding] = []
        for lineno, msg in self.waivers.bad:
            self._findings.append(Finding(
                rule="bad-waiver", severity=ERROR, file=file,
                line=lineno, message=msg, function=function))

    def flag(self, rule: str, node: ast.AST, message: str, *,
             severity: str = ERROR, function: Optional[str] = None) -> None:
        line = getattr(node, "lineno", 0) + self.line_offset
        reason = self.waivers.reason_for(rule, line)
        self._findings.append(Finding(
            rule=rule, severity=severity, file=self.file, line=line,
            message=message,
            function=self.function if function is None else function,
            waived=reason is not None, waive_reason=reason or ""))

    def findings(self) -> List[Finding]:
        return list(self._findings)

    def report(self) -> Report:
        return Report(self._findings)
