"""Structured findings: the shared result model of every analysis pass.

A ``Finding`` is one rule violation at one source location (or graph
location, for composition lint): rule id, severity, ``file:line``, a
human message naming the culprit, and — when a waiver applies — the
waiver reason. A ``Report`` is a *deterministically ordered* collection
of findings: two runs over the same tree render byte-identical text,
which is what lets ``tools/det_lint.py`` and ``sdk.verify`` act as CI
gates without flaking.

Severity semantics (the contract every consumer shares):

  * ``error`` — violates a hard contract (purity / byte-identity);
    unwaived errors are *blocking*: strict mode raises, det-lint exits 1;
  * ``warn``  — probably a bug, statically unprovable (e.g. a retry
    policy on a COMM vertex whose payload methods are runtime data);
  * ``info``  — stylistic / informational (dangling output ports).

Waived findings stay in the report (auditable) but never block.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Tuple

ERROR, WARN, INFO = "error", "warn", "info"
SEVERITIES = (ERROR, WARN, INFO)

#: rule id -> one-line description; the catalog docs/ARCHITECTURE.md
#: documents and tests/test_analysis.py covers rule-by-rule.
RULES: Dict[str, str] = {
    # purity rules (compute-function bodies; all ERROR)
    "io": "file/network/subprocess/stdout I/O in a compute body",
    "wall-clock": "wall-clock or process-timer read (time.*, datetime.now)",
    "rng": "unseeded or global-state RNG (random.*, np.random.<fn>)",
    "global-mutation": "mutation of module globals or closed-over state",
    "set-iter": "iteration over a set (hash-ordered, PYTHONHASHSEED-unstable)",
    "builtin-hash": "builtin hash() (salted per process for str/bytes)",
    "source-unavailable": "payload source cannot be retrieved for analysis",
    # determinism-lint extras (simulator sources)
    "id-order": "id()-based ordering (sort key / heap entry)",
    "bad-waiver": "waiver pragma missing its reason= or rule list",
    # composition lint (graph-level)
    "graph-unreachable": "vertex unreachable from any composition input",
    "graph-dangling-output": "output set feeds no edge and no output binding",
    "graph-comm-retry": "RetryPolicy on a COMM vertex (idempotency is runtime data)",
    "graph-fanout-local": "each/key fan-out confined to one node (no crossnode)",
}


@dataclass(frozen=True)
class Finding:
    """One rule violation at one location."""

    rule: str
    severity: str
    file: str
    line: int
    message: str
    function: str = ""          # offending function / vertex, when known
    waived: bool = False
    waive_reason: str = ""

    def sort_key(self) -> Tuple:
        return (self.file, self.line, self.rule, self.function, self.message)

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        who = f" {self.function}:" if self.function else ""
        tail = f"  [waived: {self.waive_reason}]" if self.waived else ""
        return f"{loc}: {self.severity} [{self.rule}]{who} {self.message}{tail}"

    def waive(self, reason: str) -> "Finding":
        return replace(self, waived=True, waive_reason=reason)


class Report:
    """Deterministically ordered findings + blocking/ok semantics."""

    def __init__(self, findings: Iterable[Finding] = ()):
        self.findings: Tuple[Finding, ...] = tuple(
            sorted(findings, key=Finding.sort_key)
        )

    # ------------------------------------------------------------ views
    @property
    def unwaived(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived]

    @property
    def waived(self) -> List[Finding]:
        return [f for f in self.findings if f.waived]

    @property
    def blocking(self) -> List[Finding]:
        """Unwaived errors: what strict mode / det-lint fail on."""
        return [f for f in self.findings
                if not f.waived and f.severity == ERROR]

    @property
    def ok(self) -> bool:
        return not self.blocking

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    # ---------------------------------------------------------- render
    def render(self, *, show_waived: bool = True) -> str:
        shown = self.findings if show_waived else self.unwaived
        lines = [f.render() for f in shown]
        lines.append(
            f"{len(self.findings)} finding(s): "
            f"{len(self.blocking)} blocking, "
            f"{len(self.unwaived) - len(self.blocking)} advisory, "
            f"{len(self.waived)} waived"
        )
        return "\n".join(lines)

    def __len__(self) -> int:
        return len(self.findings)

    def __iter__(self):
        return iter(self.findings)

    def __repr__(self):
        return (f"Report({len(self.findings)} findings, "
                f"{len(self.blocking)} blocking)")


class PurityReport(Report):
    """``sdk.verify`` result: findings plus what was checked and which
    declarations opted out via ``pure_unsafe=True`` (recorded, per the
    escape-hatch contract)."""

    def __init__(self, findings: Iterable[Finding] = (), *,
                 checked: Iterable[str] = (), unsafe: Iterable[str] = ()):
        super().__init__(findings)
        self.checked: Tuple[str, ...] = tuple(checked)
        self.unsafe: Tuple[str, ...] = tuple(unsafe)

    def render(self, *, show_waived: bool = True) -> str:
        head = (f"verified {len(self.checked)} function(s)"
                + (f"; pure_unsafe: {', '.join(self.unsafe)}"
                   if self.unsafe else ""))
        return head + "\n" + super().render(show_waived=show_waived)

    def __repr__(self):
        return (f"PurityReport({len(self.checked)} checked, "
                f"{len(self.blocking)} blocking, "
                f"{len(self.unsafe)} pure_unsafe)")
