"""Determinism lint: the byte-identity rules over simulator sources.

The fig10–15 benchmark outputs are pinned byte-for-byte by
``tools/check_bench_identity.py`` — CSVs must not drift across runs,
processes, or knob settings. That invariant dies quietly when modeled
code reads the host clock, draws from a global RNG, orders by ``id()``,
or iterates a set into a journal/heap/timeline. This pass runs the
shared determinism rules (:data:`repro.analysis.rules.DETERMINISM_CHECKS`)
over whole modules under ``src/repro/`` so those hazards fail CI at
commit time instead of surfacing as benchmark diffs later.

Legitimately-real paths (real-exec engine timing, cold-start
measurement, calibration capture, CLI launchers) carry waiver pragmas
whose ``reason=`` names the contract:

    t0 = time.perf_counter()  # det-lint: waive[wall-clock] reason=real-exec path, not modeled

Scope handling mirrors Python's: each ``def``/``lambda`` is analyzed in
its own scope (so set-typed locals don't leak between functions), with
module-level set-typed names visible to all scopes.

CLI: ``python tools/det_lint.py [paths...]`` — exits nonzero on any
unwaived finding; the same entry is wired into ``benchmarks/run.py``'s
PASS/FAIL summary as a zero-cost gate.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from pathlib import Path
from typing import Iterable, Iterator, List, Tuple

from .findings import Finding, Report
from .rules import DETERMINISM_CHECKS, RuleContext
from .walker import (Analysis, ImportTable, collect_bindings, is_set_expr,
                     parent_map, parse_pragmas)

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _scope_body(scope: ast.AST) -> List[ast.AST]:
    if isinstance(scope, ast.Lambda):
        return [scope.body]
    return list(scope.body)        # Module / FunctionDef


def _scope_walk(scope: ast.AST) -> Iterator[ast.AST]:
    """Nodes belonging to ``scope``, not descending into nested defs."""
    stack = _scope_body(scope)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, _SCOPE_NODES):
            continue                 # nested scope: analyzed separately
        stack.extend(ast.iter_child_nodes(node))


def _direct_set_locals(scope: ast.AST) -> set:
    """Names assigned a set expression *in this scope only*."""
    out = set()
    for node in _scope_walk(scope):
        if isinstance(node, ast.Assign) and is_set_expr(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif (isinstance(node, ast.AnnAssign) and node.value is not None
              and is_set_expr(node.value)
              and isinstance(node.target, ast.Name)):
            out.add(node.target.id)
    return out


def _scopes(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """(qualified name, scope node) for the module and every def,
    recursing manually so nested names compose left-to-right."""
    yield "", tree
    stack: List[Tuple[str, ast.AST]] = [("", tree)]
    while stack:
        prefix, scope = stack.pop()
        body = _scope_body(scope)
        inner: List[ast.AST] = list(body)
        while inner:
            node = inner.pop()
            if isinstance(node, _SCOPE_NODES):
                name = getattr(node, "name", "<lambda>")
                qual = f"{prefix}.{name}" if prefix else name
                yield qual, node
                stack.append((qual, node))
            else:
                inner.extend(ast.iter_child_nodes(node))


def lint_source(text: str, display_path: str) -> List[Finding]:
    try:
        tree = ast.parse(text)
    except SyntaxError as exc:
        return [Finding(rule="source-unavailable", severity="info",
                        file=display_path, line=exc.lineno or 0,
                        message=f"not parseable: {exc.msg}")]
    waivers = parse_pragmas(text.splitlines())
    analysis = Analysis(display_path, waivers=waivers)
    imports = ImportTable.from_tree(tree)
    parents = parent_map(tree)
    module_sets = _direct_set_locals(tree)

    for qual, scope in _scopes(tree):
        analysis.function = qual
        set_locals = module_sets | _direct_set_locals(scope)
        ctx = RuleContext(
            analysis, imports, parents,
            local_names=frozenset(collect_bindings(scope))
            if qual else frozenset(),
            set_locals=frozenset(set_locals))
        for node in _scope_walk(scope):
            for check in DETERMINISM_CHECKS:
                check(node, ctx)
    return analysis.findings()


def _display(path: Path) -> str:
    try:
        rel = os.path.relpath(path)
    except ValueError:
        return str(path)
    return str(path) if rel.startswith("..") else rel


def iter_py_files(paths: Iterable[Path]) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts)
        elif p.suffix == ".py":
            yield p


def lint_paths(paths: Iterable[Path]) -> Report:
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(lint_source(path.read_text(), _display(path)))
    return Report(findings)


def main(argv: List[str] = None) -> int:
    default_root = Path(__file__).resolve().parents[1]   # src/repro
    ap = argparse.ArgumentParser(
        prog="det_lint",
        description="byte-identity determinism lint over simulator sources")
    ap.add_argument("paths", nargs="*", type=Path, default=[default_root],
                    help=f"files/dirs to lint (default: {default_root})")
    ap.add_argument("--show-waived", action="store_true",
                    help="include waived findings in the listing")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print nothing on success")
    ns = ap.parse_args(argv)

    report = lint_paths(ns.paths or [default_root])
    unwaived = report.unwaived
    if unwaived or not ns.quiet:
        print(report.render(show_waived=ns.show_waived), file=sys.stdout)
    return 1 if unwaived else 0


if __name__ == "__main__":
    raise SystemExit(main())
