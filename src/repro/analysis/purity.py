"""Purity verifier: static analysis of compute-function bodies.

Dandelion executes compute functions in lightweight sandboxes *because*
they are pure — no guest OS, no ambient authority, safe to memoize and
to re-execute on retry (PAPER.md §ideas; docs/ARCHITECTURE.md). This
pass checks a declared payload against that contract before it reaches
a registry:

  * ``io``              — file/network/subprocess/stdout I/O;
  * ``wall-clock``      — host-clock reads (``time.*``, ``datetime.now``);
  * ``rng``             — unseeded / global-state RNG;
  * ``global-mutation`` — writes to module globals or closed-over state;
  * ``set-iter``        — hash-ordered iteration feeding outputs;
  * ``builtin-hash``    — per-process salted ``hash()``.

Analysis is *source-based*: ``inspect.getsourcelines`` on the payload,
names resolved against the function's live ``__globals__`` and closure
(so ``import numpy as np`` cannot dodge the rng rule), and a bounded
recursion into same-package callees (a payload that calls a helper that
calls ``print`` is as impure as one that prints directly). Payloads
whose source cannot be retrieved (C extensions, ``exec``-built code)
get an advisory ``source-unavailable`` finding — never blocking, since
strictness must not reject code the analyzer simply cannot see.

Results are memoized by code object: fig10 deploys 100 apps sharing one
lambda code object and pays for one analysis.
"""
from __future__ import annotations

import ast
import functools
import inspect
import os
import textwrap
import types
from dataclasses import replace
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from .findings import Finding, INFO, PurityReport
from .rules import PURITY_CHECKS, RuleContext
from .walker import (Analysis, ImportTable, collect_bindings, dotted_name,
                     parent_map, parse_pragmas, set_typed_locals)

#: (code object, remaining recursion depth) -> findings
_MEMO: Dict[Tuple[types.CodeType, int], Tuple[Finding, ...]] = {}

#: how many levels of same-package callees to follow
DEFAULT_CALL_DEPTH = 2


def clear_cache() -> None:
    _MEMO.clear()


def _display_path(path: str) -> str:
    """Repo-relative when possible, for stable report text."""
    try:
        rel = os.path.relpath(path)
    except ValueError:
        return path
    return path if rel.startswith("..") else rel


def _namespace(fn: types.FunctionType) -> Dict[str, object]:
    """Live globals + closure cells, for canonical name resolution."""
    ns = dict(getattr(fn, "__globals__", {}) or {})
    code = getattr(fn, "__code__", None)
    closure = getattr(fn, "__closure__", None)
    if code is not None and closure:
        for var, cell in zip(code.co_freevars, closure):
            try:
                ns[var] = cell.cell_contents
            except ValueError:
                pass                      # empty cell
    return ns


def _locate(tree: ast.AST, fn: types.FunctionType,
            start: int) -> Optional[ast.AST]:
    """Find the def/lambda node for ``fn`` in its parsed source block."""
    name = fn.__name__
    if name != "<lambda>":
        for node in ast.walk(tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name == name):
                return node
        return None
    target = fn.__code__.co_firstlineno - start + 1
    lambdas = [n for n in ast.walk(tree) if isinstance(n, ast.Lambda)]
    exact = [n for n in lambdas if n.lineno == target]
    if exact:
        return exact[0]
    return min(lambdas, key=lambda n: abs(n.lineno - target), default=None)


def _callees(fn_node: ast.AST, fn: types.FunctionType,
             ns: Dict[str, object]) -> List[Tuple[str, types.FunctionType]]:
    """Same-package plain functions this body calls, for recursion."""
    fn_root = (getattr(fn, "__module__", "") or "").split(".")[0]
    out: List[Tuple[str, types.FunctionType]] = []
    seen: Set[types.CodeType] = set()
    for node in ast.walk(fn_node):
        if not isinstance(node, ast.Call):
            continue
        dotted = dotted_name(node.func)
        if dotted is None:
            continue
        root, _, rest = dotted.partition(".")
        obj = ns.get(root)
        if rest and isinstance(obj, types.ModuleType) and "." not in rest:
            obj = getattr(obj, rest, None)
        elif rest:
            continue
        if not isinstance(obj, types.FunctionType):
            continue
        if obj.__code__ is fn.__code__ or obj.__code__ in seen:
            continue
        callee_root = (getattr(obj, "__module__", "") or "").split(".")[0]
        if callee_root not in (fn_root, "__main__") and fn_root != "__main__":
            continue
        seen.add(obj.__code__)
        out.append((dotted, obj))
    return out


def _retag(f: Finding, canonical: str, name: str) -> Finding:
    """Re-address a memoized finding to the declared name.

    Findings are computed (and memoized) under the callable's own
    ``__name__``; a declaration site may register the same code object
    under many names (fig10 declares one lambda 100 times). Top-level
    findings get the declared name as ``function``; callee findings keep
    the callee's name but their call-chain message is rewritten.
    """
    if f.function == canonical:
        f = replace(f, function=name)
    needle = f"(called from {canonical})"
    if needle in f.message:
        f = replace(f, message=f.message.replace(
            needle, f"(called from {name})"))
    if canonical != "<lambda>" and repr(canonical) in f.message:
        f = replace(f, message=f.message.replace(
            repr(canonical), repr(name)))
    return f


def analyze_callable(fn, *, name: Optional[str] = None,
                     depth: int = DEFAULT_CALL_DEPTH,
                     _stack: Optional[FrozenSet[types.CodeType]] = None
                     ) -> List[Finding]:
    """All purity findings for one callable (and its callee chain).

    ``name`` is the *declared* name to report under (``sdk.declare``'s
    first argument); analysis itself runs under the callable's own
    ``__name__`` so the memo is shared across declarations."""
    canonical = getattr(fn, "__name__", repr(fn))
    if name is not None and name != canonical:
        return [_retag(f, canonical, name)
                for f in analyze_callable(fn, depth=depth, _stack=_stack)]
    name = canonical
    if isinstance(fn, functools.partial):
        return analyze_callable(fn.func, name=name, depth=depth,
                                _stack=_stack)
    code = getattr(fn, "__code__", None)
    if code is None or not isinstance(fn, types.FunctionType):
        return [Finding(rule="source-unavailable", severity=INFO,
                        file="<unknown>", line=0, function=name,
                        message=f"{name!r} is not a plain Python "
                                f"function; purity not analyzable")]
    stack = _stack or frozenset()
    if code in stack:
        return []                        # recursion cycle
    memo_key = (code, depth)
    if memo_key in _MEMO:
        return list(_MEMO[memo_key])

    try:
        path = inspect.getsourcefile(fn) or "<unknown>"
        lines, start = inspect.getsourcelines(fn)
        tree = ast.parse(textwrap.dedent("".join(lines)))
    except (OSError, TypeError, SyntaxError) as exc:
        findings = [Finding(
            rule="source-unavailable", severity=INFO, file="<unknown>",
            line=0, function=name,
            message=f"source for {name!r} unavailable ({exc})")]
        _MEMO[memo_key] = tuple(findings)
        return findings

    disp = _display_path(path)
    fn_node = _locate(tree, fn, start)
    if fn_node is None:
        findings = [Finding(
            rule="source-unavailable", severity=INFO, file=disp,
            line=start, function=name,
            message=f"could not locate the def/lambda for {name!r} in "
                    f"its source block")]
        _MEMO[memo_key] = tuple(findings)
        return findings

    ns = _namespace(fn)
    waivers = parse_pragmas("".join(lines).splitlines(), first_lineno=start)
    analysis = Analysis(disp, waivers=waivers, line_offset=start - 1,
                        function=name)
    imports = ImportTable.from_tree(tree, runtime=ns)
    ctx = RuleContext(
        analysis, imports, parent_map(fn_node),
        local_names=frozenset(collect_bindings(fn_node)),
        set_locals=frozenset(set_typed_locals(fn_node)))

    body = fn_node.body if isinstance(fn_node.body, list) else [fn_node.body]
    for stmt in body:
        for node in ast.walk(stmt):
            for check in PURITY_CHECKS:
                check(node, ctx)
    findings = analysis.findings()

    if depth > 0:
        for dotted, callee in _callees(fn_node, fn, ns):
            for f in analyze_callable(callee, name=callee.__name__,
                                      depth=depth - 1,
                                      _stack=stack | {code}):
                findings.append(replace(
                    f, message=f"in callee {dotted}() "
                               f"(called from {name}): {f.message}"))

    _MEMO[memo_key] = tuple(findings)
    return findings


def verify_functions(entries: Iterable[Tuple[str, object, bool]]
                     ) -> PurityReport:
    """Build a :class:`PurityReport` for ``(name, fn, pure_unsafe)``
    declarations. ``pure_unsafe=True`` waives every finding of that
    function (recorded in the report's ``unsafe`` list)."""
    findings: List[Finding] = []
    checked: List[str] = []
    unsafe: List[str] = []
    for name, fn, pure_unsafe in entries:
        checked.append(name)
        got = analyze_callable(fn, name=name)
        if pure_unsafe:
            unsafe.append(name)
            got = [f if f.waived else
                   f.waive("pure_unsafe=True on declaration")
                   for f in got]
        findings.extend(got)
    return PurityReport(findings, checked=sorted(set(checked)),
                        unsafe=sorted(set(unsafe)))
