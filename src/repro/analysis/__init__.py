"""Static analysis guarding Dandelion's two load-bearing contracts.

Three passes share one AST-walker core (:mod:`.walker`, :mod:`.rules`)
and one result model (:mod:`.findings`):

  * :mod:`.purity`    — the pure-function contract for compute payloads
    (``sdk.verify`` / ``Platform(verify=...)`` sit on top of this);
  * :mod:`.graphlint` — shape checks on the built Composition IR;
  * :mod:`.detlint`   — byte-identity hazards in the simulator's own
    sources (``tools/det_lint.py``).

This package imports only the standard library and ``repro.core`` —
never ``repro.sdk`` — so the SDK can layer verification on top without
an import cycle.
"""
from .findings import (ERROR, INFO, RULES, SEVERITIES, WARN, Finding,
                       PurityReport, Report)
from .graphlint import lint_composition, registration_lint_hook
from .purity import analyze_callable, clear_cache, verify_functions
from .detlint import lint_paths, lint_source

__all__ = [
    "ERROR", "INFO", "WARN", "SEVERITIES", "RULES",
    "Finding", "Report", "PurityReport",
    "analyze_callable", "verify_functions", "clear_cache",
    "lint_composition", "registration_lint_hook",
    "lint_paths", "lint_source",
]
