"""Assigned input-shape sets and (arch x shape) applicability rules."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.config.model import ModelConfig


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicability(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return None if (arch, shape) runs, else a skip reason string.

    Rules from the assignment:
      * ``long_500k`` needs sub-quadratic attention -> skip for pure
        full-attention archs, run for SSM / hybrid.
      * encoder-only archs have no decode step (none of ours are
        encoder-only; whisper is enc-dec and does decode).
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return (
            "full-attention arch: 524288-token context is quadratic; "
            "skipped per assignment (see DESIGN.md SS4)"
        )
    if shape.kind == "decode" and not cfg.has_decoder:
        return "encoder-only arch has no decode step"
    return None


def runnable_cells(cfgs):
    """Yield (cfg, shape, skip_reason) for the full 40-cell grid."""
    for cfg in cfgs:
        for name in SHAPE_ORDER:
            shape = SHAPES[name]
            yield cfg, shape, applicability(cfg, shape)
