"""Configuration system: model configs, shape sets, parallelism plans."""
from repro.config.model import FAMILIES, ModelConfig, validate
from repro.config.parallel import TPU_V5E, HardwareSpec, ParallelPlan
from repro.config.shapes import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPE_ORDER,
    SHAPES,
    TRAIN_4K,
    ShapeConfig,
    applicability,
    runnable_cells,
)

__all__ = [
    "FAMILIES",
    "ModelConfig",
    "validate",
    "ParallelPlan",
    "HardwareSpec",
    "TPU_V5E",
    "ShapeConfig",
    "SHAPES",
    "SHAPE_ORDER",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "applicability",
    "runnable_cells",
]
