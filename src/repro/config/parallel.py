"""Parallelism / run configuration.

The production meshes are fixed by the assignment:
  single-pod: (16, 16)      axes ("data", "model")
  multi-pod : (2, 16, 16)   axes ("pod", "data", "model")

``ParallelPlan`` describes how logical tensor axes map onto mesh axes; the
actual PartitionSpecs are derived in ``repro.sharding``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ParallelPlan:
    """Logical -> physical axis plan.

    Attributes:
      data_axes:    mesh axes used for batch data parallelism.
      fsdp_axes:    mesh axes over which parameters/optimizer state are
                    sharded ZeRO-3 style (all-gathered per layer on use).
      tensor_axes:  mesh axes for tensor (op) parallelism (heads / d_ff).
      expert_axes:  mesh axes for expert parallelism (MoE only).
      seq_axes:     mesh axes for sequence/context parallelism (long ctx).
      remat:        activation checkpoint policy: "none"|"full"|"dots".
      grad_accum:   microbatch count (1 = no accumulation).
      zero3:        shard params over fsdp_axes (else replicate over them).
      compress_grads: apply int8 error-feedback compression to the DP
                    gradient all-reduce (training only).
      overlap_weight_gather: double-buffer next-layer weight all-gather
                    inside the layer scan (ZeRO-3 prefetch).
    """

    data_axes: Tuple[str, ...] = ("pod", "data")
    fsdp_axes: Tuple[str, ...] = ("pod", "data")
    tensor_axes: Tuple[str, ...] = ("model",)
    expert_axes: Tuple[str, ...] = ("model",)
    seq_axes: Tuple[str, ...] = ("data",)
    remat: str = "full"
    grad_accum: int = 1
    zero3: bool = True
    compress_grads: bool = False
    overlap_weight_gather: bool = False

    def restrict_to(self, axis_names: Tuple[str, ...]) -> "ParallelPlan":
        """Drop mesh axes not present (e.g. no 'pod' on single-pod mesh)."""
        f = lambda axes: tuple(a for a in axes if a in axis_names)
        return ParallelPlan(
            data_axes=f(self.data_axes),
            fsdp_axes=f(self.fsdp_axes),
            tensor_axes=f(self.tensor_axes),
            expert_axes=f(self.expert_axes),
            seq_axes=f(self.seq_axes),
            remat=self.remat,
            grad_accum=self.grad_accum,
            zero3=self.zero3,
            compress_grads=self.compress_grads,
            overlap_weight_gather=self.overlap_weight_gather,
        )


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the target accelerator (TPU v5e defaults)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bandwidth: float = 819e9      # bytes/s per chip
    ici_bandwidth: float = 50e9       # bytes/s per link
    hbm_bytes: int = 16 * 1024**3     # capacity per chip
    vmem_bytes: int = 128 * 1024**2


TPU_V5E = HardwareSpec()
