"""Model configuration dataclasses for all supported architecture families.

Families:
  dense   -- decoder-only transformer (llama-style: RMSNorm, SwiGLU, RoPE, GQA)
  moe     -- dense skeleton with MoE FFN (top-k routing, EP-shardable experts)
  ssm     -- attention-free Mamba2 (SSD) stack
  hybrid  -- Hymba-style parallel attention + SSM heads per block
  encdec  -- Whisper-style encoder-decoder (conv frontend stubbed)
  vlm     -- InternVL-style: patch-embedding stub + decoder-only LM backbone
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description. All sizes are in elements, not bytes."""

    name: str
    family: str

    num_layers: int
    d_model: int
    num_heads: int            # query heads (0 for attention-free)
    num_kv_heads: int         # KV heads for GQA (== num_heads for MHA)
    d_ff: int                 # dense FFN hidden dim (per-expert dim for MoE)
    vocab_size: int

    head_dim: int = 0         # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # Some MoE models keep a shared dense FFN alongside experts; not used by
    # the two assigned MoE archs, but supported.
    shared_expert_d_ff: int = 0

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0        # per-head state dim N
    ssm_expand: int = 2       # d_inner = expand * d_model
    ssm_head_dim: int = 64
    ssm_conv_dim: int = 4     # depthwise conv kernel width
    ssm_chunk: int = 128      # SSD chunk length

    # --- hybrid (attention + SSM in parallel) ---
    sliding_window: int = 0   # 0 -> full attention
    global_attn_layers: tuple = ()  # layer indices using full attention

    # --- encoder-decoder ---
    encoder_layers: int = 0
    encoder_frames: int = 1500  # stub frontend output length (audio frames)

    # --- VLM ---
    num_patches: int = 0      # stub frontend output length (image patches)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        """SSM inner dim."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        if self.ssm_state == 0:
            return 0
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """True if decode memory/compute is sub-quadratic in context length.

        Pure-SSM archs compress context into O(1) state; hybrid archs bound
        attention KV by the sliding window except on a few global layers.
        """
        return self.family == "ssm" or (
            self.family == "hybrid" and self.sliding_window > 0
        )

    @property
    def has_decoder(self) -> bool:
        """Encoder-only archs have no decode step. All ours have decoders."""
        return True

    # ------------------------------------------------------------------
    # Parameter counting (used for MODEL_FLOPS = 6*N*D roofline term).
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        dh = self.resolved_head_dim
        if self.num_heads == 0:
            return 0
        q = self.d_model * self.num_heads * dh
        kv = 2 * self.d_model * self.num_kv_heads * dh
        o = self.num_heads * dh * self.d_model
        bias = (self.num_heads + 2 * self.num_kv_heads) * dh if self.qkv_bias else 0
        return q + kv + o + bias

    def _dense_ffn_params(self, d_ff: int) -> int:
        # SwiGLU: gate + up + down
        return 3 * self.d_model * d_ff

    def _moe_ffn_params(self) -> int:
        router = self.d_model * self.num_experts
        experts = self.num_experts * 3 * self.d_model * self.d_ff
        shared = (
            self._dense_ffn_params(self.shared_expert_d_ff)
            if self.shared_expert_d_ff
            else 0
        )
        return router + experts + shared

    def _ssm_params(self) -> int:
        d_in = self.d_inner
        nheads = self.ssm_heads
        ngroups = 1
        # in_proj -> [z, x, B, C, dt]
        in_proj = self.d_model * (2 * d_in + 2 * ngroups * self.ssm_state + nheads)
        conv = self.ssm_conv_dim * (d_in + 2 * ngroups * self.ssm_state)
        extras = 3 * nheads  # A_log, D, dt_bias
        norm = d_in
        out_proj = d_in * self.d_model
        return in_proj + conv + extras + norm + out_proj

    def layer_params(self, layer_idx: int = 0) -> int:
        """Parameters in one block (norms included)."""
        norms = 2 * self.d_model
        if self.family == "ssm":
            return self._ssm_params() + self.d_model  # single pre-norm
        if self.family == "hybrid":
            return (
                self._attn_params()
                + self._ssm_params()
                + self._dense_ffn_params(self.d_ff)
                + norms
                + 2 * self.d_model  # per-branch output norms
            )
        if self.family == "moe":
            return self._attn_params() + self._moe_ffn_params() + norms
        # dense / vlm backbone / encdec decoder block
        return self._attn_params() + self._dense_ffn_params(self.d_ff) + norms

    def num_params(self) -> int:
        """Total parameter count N."""
        embed = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        final_norm = self.d_model
        if self.family == "encdec":
            enc_block = (
                self._attn_params() + self._dense_ffn_params(self.d_ff) + 2 * self.d_model
            )
            # decoder block: self-attn + cross-attn + ffn + 3 norms
            dec_block = (
                2 * self._attn_params()
                + self._dense_ffn_params(self.d_ff)
                + 3 * self.d_model
            )
            total = (
                self.encoder_layers * enc_block
                + self.num_layers * dec_block
                + embed
                + head
                + 2 * final_norm
            )
            return total
        total = self.num_layers * self.layer_params() + embed + head + final_norm
        if self.family == "vlm":
            # stub patch projection into d_model
            total += self.d_model * self.d_model
        return total

    def num_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.num_params()
        embed = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        active_ffn = (
            self.d_model * self.num_experts  # router always runs
            + self.experts_per_token * 3 * self.d_model * self.d_ff
            + (self._dense_ffn_params(self.shared_expert_d_ff) if self.shared_expert_d_ff else 0)
        )
        block = self._attn_params() + active_ffn + 2 * self.d_model
        return self.num_layers * block + embed + head + self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def validate(cfg: ModelConfig) -> None:
    assert cfg.family in FAMILIES, f"unknown family {cfg.family}"
    if cfg.family != "ssm":
        assert cfg.num_heads > 0 and cfg.num_kv_heads > 0
        assert cfg.num_heads % cfg.num_kv_heads == 0, "GQA requires q%kv==0"
    if cfg.family == "moe":
        assert cfg.num_experts > 0 and cfg.experts_per_token > 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_state > 0
        assert cfg.d_inner % cfg.ssm_head_dim == 0
    if cfg.family == "encdec":
        assert cfg.encoder_layers > 0
