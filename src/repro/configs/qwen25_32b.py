"""qwen2.5-32b: dense LM with GQA and QKV bias [hf:Qwen/Qwen2.5].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.config import ModelConfig

ARCH_ID = "qwen2.5-32b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=80,
        num_heads=10,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=384,
        head_dim=8,
        qkv_bias=True,
    )
