"""internvl2-76b: VLM = InternViT frontend (STUB) + LM backbone
[arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 (llama-3-70b-style
backbone). The vision tower is a stub per the assignment: ``input_specs()``
provides precomputed patch embeddings [B, num_patches, d_model] which the
backbone consumes alongside token embeddings through a projection.
"""
from repro.config import ModelConfig

ARCH_ID = "internvl2-76b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        head_dim=128,
        rope_theta=500000.0,
        num_patches=256,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=320,
        vocab_size=512,
        head_dim=16,
        num_patches=8,
    )
