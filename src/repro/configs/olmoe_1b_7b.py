"""olmoe-1b-7b: MoE LM, 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (MHA kv=16) d_ff=1024 (per expert) vocab=50304.
"""
from repro.config import ModelConfig

ARCH_ID = "olmoe-1b-7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        head_dim=128,
        num_experts=64,
        experts_per_token=8,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=32,
        vocab_size=256,
        head_dim=16,
        num_experts=8,
        experts_per_token=2,
    )
