"""mamba2-130m: attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060].

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128. d_inner = 2*768 = 1536,
head_dim=64 -> 24 SSM heads. Decode state is O(1) in context length, so
``long_500k`` runs.
"""
from repro.config import ModelConfig

ARCH_ID = "mamba2-130m"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv_dim=4,
        ssm_chunk=128,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=256,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        ssm_conv_dim=4,
        ssm_chunk=16,
        tie_embeddings=True,
    )
