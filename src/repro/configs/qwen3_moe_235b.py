"""qwen3-moe-235b-a22b: MoE LM, 128 experts top-8 [hf:Qwen/Qwen3].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936.
head_dim=128 (decoupled from d_model/num_heads as in Qwen3).
"""
from repro.config import ModelConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        d_ff=1536,
        vocab_size=151936,
        head_dim=128,
        num_experts=128,
        experts_per_token=8,
        rope_theta=1000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=48,
        vocab_size=256,
        head_dim=16,
        num_experts=8,
        experts_per_token=2,
    )
