"""hymba-1.5b: hybrid parallel attention + Mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention everywhere except three global full-attention
layers (first / middle / last), as in the paper -- this keeps decode
sub-quadratic so ``long_500k`` runs.
"""
from repro.config import ModelConfig

ARCH_ID = "hymba-1.5b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        head_dim=64,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        sliding_window=1024,
        global_attn_layers=(0, 15, 31),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        num_layers=3,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=256,
        head_dim=32,
        ssm_state=8,
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=16,
        sliding_window=32,
        global_attn_layers=(0,),
        tie_embeddings=True,
    )
