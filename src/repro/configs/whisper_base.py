"""whisper-base: encoder-decoder audio transformer [arXiv:2212.04356].

6L d_model=512 8H d_ff=2048 vocab=51865. Conv frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
[B, frames, d_model]; the encoder transformer stack and the full decoder
(self-attn + cross-attn, KV cache) are real.
"""
from repro.config import ModelConfig

ARCH_ID = "whisper-base"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="encdec",
        num_layers=6,          # decoder layers
        encoder_layers=6,
        encoder_frames=1500,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        head_dim=64,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="encdec",
        num_layers=2,
        encoder_layers=2,
        encoder_frames=32,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        tie_embeddings=True,
    )
