"""granite-8b: dense llama-arch code LM [arXiv:2405.04324; hf].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.config import ModelConfig

ARCH_ID = "granite-8b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        head_dim=128,
        rope_theta=10000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        head_dim=8,
        tie_embeddings=True,
    )
