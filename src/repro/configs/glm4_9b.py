"""glm4-9b: dense LM with RoPE + aggressive GQA [hf:THUDM/glm-4-9b].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.config import ModelConfig

ARCH_ID = "glm4-9b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        head_dim=128,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=384,
        head_dim=12,
    )
