"""deepseek-67b: dense llama-arch LM [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.config import ModelConfig

ARCH_ID = "deepseek-67b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        head_dim=128,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=3,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=344,
        vocab_size=512,
        head_dim=16,
        rope_theta=10000.0,
    )
