"""The paper's own workload configs (SS7 microbenchmarks).

These are not LM architectures; they are the compute-function payloads used
by the Dandelion evaluation: the 128x128 int64 matmul (Fig. 2/6), the 1x1
matmul (Table 1 / Fig. 5), the fetch-and-reduce phase microbenchmark
(SS7.4), and the image-transform stand-in (SS7.6).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class MicroConfig:
    name: str
    matmul_n: int = 128          # square matmul dimension
    fetch_bytes: int = 64 * 1024  # SS7.4 phase fetch size
    phases: int = 8               # SS7.4 chain length
    image_kb: int = 18            # SS7.6 QOI image size


def matmul_1x1() -> MicroConfig:
    return MicroConfig(name="matmul_1x1", matmul_n=1)


def matmul_128() -> MicroConfig:
    return MicroConfig(name="matmul_128", matmul_n=128)


def fetch_compute(phases: int = 8) -> MicroConfig:
    return MicroConfig(name=f"fetch_compute_{phases}", phases=phases)


def image_compress() -> MicroConfig:
    return MicroConfig(name="image_compress")
