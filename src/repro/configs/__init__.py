"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

One module per assigned architecture; each exposes ``full()`` (the exact
published config) and ``smoke()`` (a reduced same-family config used by the
CPU smoke tests).
"""
from __future__ import annotations

from typing import Dict, List

from repro.config import ModelConfig, validate
from repro.configs import (
    deepseek_67b,
    glm4_9b,
    granite_8b,
    hymba_1p5b,
    internvl2_76b,
    mamba2_130m,
    olmoe_1b_7b,
    qwen25_32b,
    qwen3_moe_235b,
    whisper_base,
)

_MODULES = {
    deepseek_67b.ARCH_ID: deepseek_67b,
    glm4_9b.ARCH_ID: glm4_9b,
    qwen25_32b.ARCH_ID: qwen25_32b,
    granite_8b.ARCH_ID: granite_8b,
    whisper_base.ARCH_ID: whisper_base,
    hymba_1p5b.ARCH_ID: hymba_1p5b,
    internvl2_76b.ARCH_ID: internvl2_76b,
    mamba2_130m.ARCH_ID: mamba2_130m,
    olmoe_1b_7b.ARCH_ID: olmoe_1b_7b,
    qwen3_moe_235b.ARCH_ID: qwen3_moe_235b,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg = _MODULES[arch_id].full()
    validate(cfg)
    return cfg


def get_smoke(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg = _MODULES[arch_id].smoke()
    validate(cfg)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
