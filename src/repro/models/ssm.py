"""Mamba2 (SSD, state-space duality) block: chunked train/prefill + O(1) decode.

Follows the ssd_minimal discrete form of Dao & Gu (arXiv:2405.21060):
within a chunk the recurrence is evaluated in its quadratic "attention-like"
dual form (MXU-friendly 128x128 matmuls); across chunks a linear ``lax.scan``
carries the [H, P, N] state, so prefill is O(S) memory and decode is O(1) in
context length -- which is why ``long_500k`` runs for the SSM/hybrid archs.

The Pallas TPU kernel for the intra-chunk dual form lives in
``repro.kernels.ssd_scan`` and is validated against ``ssd_chunked`` here.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import ParamSpec
from repro.models.layers import rms_norm
from repro.sharding.constraints import shard_act

NEG_INF = -1e30


def param_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, d_in = cfg.d_model, cfg.d_inner
    h, n, wc = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv_dim
    conv_ch = d_in + 2 * n  # x, B, C channels (ngroups = 1)
    return {
        "in_proj": ParamSpec((d, 2 * d_in + 2 * n + h), ("embed", "ssm_in")),
        "conv_w": ParamSpec((wc, conv_ch), (None, "ssm_in")),
        "conv_b": ParamSpec((conv_ch,), ("ssm_in",), init="zeros"),
        "A_log": ParamSpec((h,), (None,), init="ssm_a", dtype="float32"),
        "D": ParamSpec((h,), (None,), init="ones", dtype="float32"),
        "dt_bias": ParamSpec((h,), (None,), init="zeros", dtype="float32"),
        "norm": ParamSpec((d_in,), ("ssm_in",), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("ssm_in", "embed")),
    }


class SSMState(NamedTuple):
    """Decode-time recurrent state (per layer stack).

    h          [L, B, H, P, N]  SSD state
    conv_buf   [L, B, wc-1, conv_ch]  trailing conv inputs
    """

    h: jax.Array
    conv_buf: jax.Array


def _segsum(a: jax.Array) -> jax.Array:
    """a [..., l] -> [..., l, l] with out[i, j] = sum_{j<k<=i} a_k (j<=i)."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    x: jax.Array,      # [B, S, H, P]  (already dt-weighted: x * dt)
    a: jax.Array,      # [B, S, H]     log-decay per step (dt * A, negative)
    b: jax.Array,      # [B, S, N]     input matrix (ngroups=1)
    c: jax.Array,      # [B, S, N]     output matrix
    chunk: int,
    h0: jax.Array = None,  # [B, H, P, N] initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y [B, S, H, P], final state [B, H, P, N]).

    The intra-chunk quadratic dual form is evaluated INSIDE the
    inter-chunk ``lax.scan``, so the working set is one chunk's
    [B, H, l, l] decay/score tensors rather than all ``nc`` chunks at
    once - the O(nc) memory reduction this buys is the dominant term of
    the hymba/mamba2 train cells (EXPERIMENTS.md SSPerf iteration 2; the
    Pallas ssd_scan kernel is the same structure with VMEM-resident
    tiles).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    l = chunk
    # chunk-major scan inputs
    xc = jnp.moveaxis(x.reshape(bsz, nc, l, h, p), 1, 0)   # [nc, B, l, H, P]
    ac = jnp.moveaxis(a.reshape(bsz, nc, l, h), 1, 0)      # [nc, B, l, H]
    bc = jnp.moveaxis(b.reshape(bsz, nc, l, n), 1, 0)      # [nc, B, l, N]
    cc = jnp.moveaxis(c.reshape(bsz, nc, l, n), 1, 0)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    tri = jnp.tril(jnp.ones((l, l), bool))

    def step(h_prev, inp):
        xl, al, bl, cl = inp
        xl = xl.astype(jnp.float32)                        # [B, l, H, P]
        af = al.astype(jnp.float32).transpose(0, 2, 1)     # [B, H, l]
        bf = bl.astype(jnp.float32)                        # [B, l, N]
        cf = cl.astype(jnp.float32)
        cum = jnp.cumsum(af, axis=-1)                      # [B, H, l]
        seg = cum[..., :, None] - cum[..., None, :]
        L = jnp.exp(jnp.where(tri, seg, NEG_INF))          # [B, H, l, l]
        scores = jnp.einsum("bln,bsn->bls", cf, bf)        # [B, l, l]
        y_diag = jnp.einsum("bhls,bls,bshp->blhp", L, scores, xl)
        y_off = jnp.einsum(
            "bln,bhpn,bhl->blhp", cf, h_prev, jnp.exp(cum)
        )
        decay_states = jnp.exp(cum[..., -1:] - cum)        # [B, H, l]
        state = jnp.einsum("bln,bhl,blhp->bhpn", bf, decay_states, xl)
        h_new = h_prev * jnp.exp(cum[..., -1])[..., None, None] + state
        return h_new, (y_diag + y_off).astype(x.dtype)

    final, ys = jax.lax.scan(step, h0.astype(jnp.float32), (xc, ac, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with kernel [wc, C]."""
    wc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (wc - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(wc):
        out = out + pad[:, i : i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n :]
    return z, xbc, dt


def apply_ssm(
    x_in: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    prompt_lens: jax.Array = None,
) -> Tuple[jax.Array, "SSMState"]:
    """Full-sequence SSM block body (train/prefill). x_in [B, S, D].

    Returns (y [B, S, D], final per-layer state) -- the state feeds decode.
    ``prompt_lens`` [B] (prefill with right-padding): positions >= the
    prompt length get dt = 0, so x*dt = 0 and log-decay = 0 -- the state
    passes through padding unchanged and the final state equals the state
    after exactly ``prompt_lens`` real tokens.
    """
    bsz, s, _ = x_in.shape
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    wc = cfg.ssm_conv_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x_in, p["in_proj"])
    z, xbc_raw, dt_raw = _split_proj(cfg, zxbcdt)

    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(bsz, s, h, ph)
    b_mat = xbc[..., d_in : d_in + n]
    c_mat = xbc[..., d_in + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    if prompt_lens is not None:
        valid = (jnp.arange(s)[None, :] < prompt_lens[:, None]).astype(jnp.float32)
        dt = dt * valid[..., None]
    a_neg = -jnp.exp(p["A_log"])  # [H]
    log_decay = dt * a_neg  # [B, S, H]

    # pad S to a chunk multiple: zero x*dt and zero log-decay (decay=1)
    # pass the state through padding untouched.
    # NOTE: a "bshp" P-dim sharding constraint here was tried and REVERTED:
    # it added resharding collectives without reducing HBM traffic
    # (EXPERIMENTS.md SSPerf, hymba iteration 3 - refuted).
    pad = (-s) % cfg.ssm_chunk
    xdt = xs * dt[..., None].astype(xs.dtype)
    ld, bm, cm = log_decay, b_mat, c_mat
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xdt, ld, bm, cm = zpad(xdt), zpad(ld), zpad(bm), zpad(cm)
    y, final = ssd_chunked(xdt, ld, bm, cm, min(cfg.ssm_chunk, xdt.shape[1]))
    if pad:
        y = y[:, :s]
    y = y + (p["D"][:, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])

    if prompt_lens is None:
        if s >= wc - 1:
            conv_buf = xbc_raw[:, s - (wc - 1) :]
        else:
            conv_buf = jnp.pad(xbc_raw, ((0, 0), (wc - 1 - s, 0), (0, 0)))
    else:
        # per-row trailing window: raw conv inputs at plen-(wc-1) .. plen-1
        idx = prompt_lens[:, None] - (wc - 1) + jnp.arange(wc - 1)[None, :]
        ok = idx >= 0
        idx = jnp.clip(idx, 0, s - 1)
        conv_buf = jnp.take_along_axis(xbc_raw, idx[..., None], axis=1)
        conv_buf = jnp.where(ok[..., None], conv_buf, 0)
    return out, SSMState(h=final, conv_buf=conv_buf)


def apply_ssm_decode(
    x_in: jax.Array,           # [B, D] single token
    state: SSMState,           # single-layer state: h [B,H,P,N], conv [B,wc-1,C]
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> Tuple[jax.Array, SSMState]:
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    wc = cfg.ssm_conv_dim

    zxbcdt = jnp.einsum("bd,de->be", x_in, p["in_proj"])
    z, xbc_raw, dt_raw = _split_proj(cfg, zxbcdt)

    # conv over [state..., new]
    hist = jnp.concatenate([state.conv_buf, xbc_raw[:, None]], axis=1)  # [B,wc,C]
    conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(x_in.dtype)

    xs = xbc[..., :d_in].reshape(-1, h, ph)
    b_mat = xbc[..., d_in : d_in + n].astype(jnp.float32)
    c_mat = xbc[..., d_in + n :].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B, H]
    decay = jnp.exp(dt * -jnp.exp(p["A_log"]))  # [B, H]

    dx = xs.astype(jnp.float32) * dt[..., None]  # [B, H, P]
    h_new = state.h * decay[..., None, None] + jnp.einsum("bhp,bn->bhpn", dx, b_mat)
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_mat)
    y = y + p["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(-1, d_in).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])

    conv_buf = jnp.concatenate([state.conv_buf[:, 1:], xbc_raw[:, None]], axis=1)
    return out, SSMState(h=h_new, conv_buf=conv_buf)


def init_state(cfg: ModelConfig, batch: int, num_layers: int = None) -> SSMState:
    """Zero decode state; if num_layers given, leaves are layer-stacked."""
    h = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
    cbuf = (cfg.ssm_conv_dim - 1, cfg.d_inner + 2 * cfg.ssm_state)
    if num_layers is None:
        return SSMState(
            h=jnp.zeros((batch,) + h, jnp.float32),
            conv_buf=jnp.zeros((batch,) + cbuf, jnp.bfloat16),
        )
    return SSMState(
        h=jnp.zeros((num_layers, batch) + h, jnp.float32),
        conv_buf=jnp.zeros((num_layers, batch) + cbuf, jnp.bfloat16),
    )
