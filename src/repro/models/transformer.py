"""Decoder-only LM assembly for the dense / moe / ssm / hybrid / vlm families.

Uniform stacks (dense, moe, ssm, vlm) scan over a layer-stacked parameter
tree -- the HLO stays O(1) in depth, which keeps the 95-layer dry-run
compileable -- with optional per-layer remat (ZeRO-3 FSDP all-gathers the
layer slice inside the scan).  Non-uniform stacks (hybrid: sliding +
global attention layers) unroll in Python.

Public entry points (all pure, jit-able):
  train_loss(params, batch, cfg, ...)            -> scalar loss
  prefill(params, tokens, prompt_lens, cfg, ...) -> (last_logits, cache)
  decode_step(params, cache, tokens, cfg, ...)   -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (
    cache_write_decode,
    chunked_attention,
    decode_attention,
)
from repro.models.common import ParamSpec
from repro.models.layers import (
    apply_rope,
    chunked_softmax_xent,
    embed_tokens,
    rms_norm,
    swiglu,
)
from repro.sharding.constraints import shard_act

# ---------------------------------------------------------------------------
# Parameter templates
# ---------------------------------------------------------------------------
def attn_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hk = cfg.num_heads, cfg.num_kv_heads
    t = {
        "wq": ParamSpec((d, hq * dh), ("embed", "heads")),
        "wk": ParamSpec((d, hk * dh), ("embed", "kv_heads")),
        "wv": ParamSpec((d, hk * dh), ("embed", "kv_heads")),
        "wo": ParamSpec((hq * dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((hq * dh,), ("heads",), init="zeros")
        t["bk"] = ParamSpec((hk * dh,), ("kv_heads",), init="zeros")
        t["bv"] = ParamSpec((hk * dh,), ("kv_heads",), init="zeros")
    return t


def mlp_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ffn")),
        "w_up": ParamSpec((d, f), ("embed", "ffn")),
        "w_down": ParamSpec((f, d), ("ffn", "embed")),
    }


def block_template(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family == "ssm":
        return {
            "norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
            "ssm": ssm_lib.param_template(cfg),
        }
    t: Dict[str, Any] = {
        "norm1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_template(cfg),
        "norm2": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if cfg.family == "moe":
        t["moe"] = moe_lib.param_template(cfg)
    else:
        t["mlp"] = mlp_template(cfg)
    if cfg.family == "hybrid":
        t["ssm"] = ssm_lib.param_template(cfg)
        t["attn_out_norm"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
        t["ssm_out_norm"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
    return t


def uses_scan(cfg: ModelConfig) -> bool:
    """All decoder families scan over a layer-stacked parameter tree.

    The hybrid (Hymba) stack is structurally uniform - every block has the
    attention + SSM + MLP branches - only the sliding ``window`` differs
    per layer, which rides the scan as a per-layer scalar (dynamic mask in
    chunked_attention). This keeps the 95-layer / 32-layer full-size HLOs
    O(1) in depth; prefill/decode for hybrid slice the stacked tree per
    layer instead (their caches are shape-inhomogeneous).
    """
    return cfg.family in ("dense", "moe", "ssm", "vlm", "hybrid")


def layer_slice(blocks, i: int):
    """Layer ``i`` of a stacked block tree."""
    return jax.tree_util.tree_map(lambda x: x[i], blocks)


def param_template(cfg: ModelConfig) -> Dict[str, Any]:
    blk = block_template(cfg)
    if uses_scan(cfg):
        blocks = jax.tree_util.tree_map(
            lambda s: s.with_layers(cfg.num_layers),
            blk,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
    else:
        blocks = [block_template(cfg) for _ in range(cfg.num_layers)]
    t: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="embed"),
        "blocks": blocks,
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.family == "vlm":
        t["patch_proj"] = ParamSpec((cfg.d_model, cfg.d_model), ("embed", None))
    return t


def lm_head_weight(params: Dict[str, Any], cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# Attention block bodies
# ---------------------------------------------------------------------------
def _qkv(x, ap, cfg):
    b = x.shape[:-1]
    dh = cfg.resolved_head_dim
    q = jnp.einsum("...d,de->...e", x, ap["wq"])
    k = jnp.einsum("...d,de->...e", x, ap["wk"])
    v = jnp.einsum("...d,de->...e", x, ap["wv"])
    if cfg.qkv_bias:
        q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
    q = q.reshape(*b, cfg.num_heads, dh)
    k = k.reshape(*b, cfg.num_kv_heads, dh)
    v = v.reshape(*b, cfg.num_kv_heads, dh)
    if len(b) == 2:  # [B, S, H, dh] full-sequence path
        q, k, v = (shard_act(t, "bshd") for t in (q, k, v))
    else:            # [B, H, dh] decode path
        q, k, v = (shard_act(t, "bhd") for t in (q, k, v))
    return q, k, v


def attn_full(x, ap, cfg, *, window: int = 0, positions=None):
    """Full-sequence attention. x [B,S,D] -> (out [B,S,D], k, v rotated)."""
    bsz, s, _ = x.shape
    q, k, v = _qkv(x, ap, cfg)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=True, window=window)
    out = jnp.einsum("...e,ed->...d", out.reshape(bsz, s, -1), ap["wo"])
    return out, k, v


def attn_decode(x, ap, cfg, kc, vc, sp, pos, *, window: int = 0, ring: bool = False):
    """One-token attention. x [B,D]; kc/vc [B,S,K,dh]; sp [B,S]; pos [B]."""
    q, k, v = _qkv(x, ap, cfg)  # [B, H, dh] / [B, K, dh]
    q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    kc, vc, sp = cache_write_decode(kc, vc, sp, k, v, pos, ring)
    out = decode_attention(q, kc, vc, sp, pos, window=window)
    out = jnp.einsum("be,ed->bd", out.reshape(out.shape[0], -1), ap["wo"])
    return out, kc, vc, sp


# ---------------------------------------------------------------------------
# Block bodies (full-sequence)
# ---------------------------------------------------------------------------
def block_full(h, bp, cfg, *, layer_window=0, prompt_lens=None):
    """Returns (h, per-layer cache pieces dict, aux loss)."""
    h = shard_act(h, "bsd")
    aux = jnp.float32(0.0)
    cache: Dict[str, Any] = {}
    if cfg.family == "ssm":
        y, state = ssm_lib.apply_ssm(
            rms_norm(h, bp["norm"], cfg.norm_eps), bp["ssm"], cfg, prompt_lens)
        cache["ssm"] = state
        return h + y, cache, aux

    x = rms_norm(h, bp["norm1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a_out, k, v = attn_full(x, bp["attn"], cfg, window=layer_window)
        s_out, state = ssm_lib.apply_ssm(x, bp["ssm"], cfg, prompt_lens)
        a_out = rms_norm(a_out, bp["attn_out_norm"], cfg.norm_eps)
        s_out = rms_norm(s_out, bp["ssm_out_norm"], cfg.norm_eps)
        h = h + 0.5 * (a_out + s_out)
        cache["ssm"] = state
    else:
        a_out, k, v = attn_full(x, bp["attn"], cfg)
        h = h + a_out
    cache["k"], cache["v"] = k, v

    x2 = rms_norm(h, bp["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_lib.apply_moe(x2, bp["moe"], cfg)
    else:
        y = swiglu(x2, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
    return h + y, cache, aux


def block_decode(h, bp, cfg, layer_cache, pos, *, layer_window: int = 0, ring: bool = False):
    """h [B,D]; layer_cache dict of single-layer cache arrays."""
    out_cache: Dict[str, Any] = {}
    if cfg.family == "ssm":
        y, state = ssm_lib.apply_ssm_decode(
            rms_norm(h, bp["norm"], cfg.norm_eps), layer_cache["ssm"], bp["ssm"], cfg
        )
        out_cache["ssm"] = state
        return h + y, out_cache

    x = rms_norm(h, bp["norm1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a_out, kc, vc, sp = attn_decode(
            x, bp["attn"], cfg, layer_cache["k"], layer_cache["v"],
            layer_cache["slot_pos"], pos, window=layer_window, ring=ring,
        )
        s_out, state = ssm_lib.apply_ssm_decode(x, layer_cache["ssm"], bp["ssm"], cfg)
        a_out = rms_norm(a_out, bp["attn_out_norm"], cfg.norm_eps)
        s_out = rms_norm(s_out, bp["ssm_out_norm"], cfg.norm_eps)
        h = h + 0.5 * (a_out + s_out)
        out_cache["ssm"] = state
    else:
        a_out, kc, vc, sp = attn_decode(
            x, bp["attn"], cfg, layer_cache["k"], layer_cache["v"],
            layer_cache["slot_pos"], pos, ring=ring,
        )
        h = h + a_out
    out_cache.update(k=kc, v=vc, slot_pos=sp)

    x2 = rms_norm(h, bp["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = moe_lib.apply_moe(x2[:, None, :], bp["moe"], cfg, group_size=x2.shape[0])
        y = y[:, 0]
    else:
        y = swiglu(x2, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
    return h + y, out_cache


def _layer_window(cfg: ModelConfig, idx: int) -> int:
    if cfg.family == "hybrid" and cfg.sliding_window:
        return 0 if idx in cfg.global_attn_layers else cfg.sliding_window
    return 0


# ---------------------------------------------------------------------------
# Full-model forward (hidden states)
# ---------------------------------------------------------------------------
def forward_hidden(
    params, tokens, cfg: ModelConfig, *, remat: str = "none",
    collect_cache: bool = False, patches=None, prompt_lens=None,
) -> Tuple[jax.Array, Any, jax.Array]:
    """tokens [B,S_text] -> (h [B,S,D], caches, aux). For vlm, ``patches``
    [B,P,D] are projected and prepended (S = P + S_text)."""
    h = embed_tokens(tokens, params["embed"])
    if cfg.family == "vlm":
        assert patches is not None, "vlm needs patch embeddings"
        pe = jnp.einsum("bpd,de->bpe", patches.astype(h.dtype), params["patch_proj"])
        h = jnp.concatenate([pe, h], axis=1)

    # hybrid prefill collects shape-inhomogeneous caches (sliding vs
    # global) -> slice the stacked tree per layer; everything else scans.
    scan_ok = uses_scan(cfg) and not (cfg.family == "hybrid" and collect_cache)
    if scan_ok:
        windows = None
        if cfg.family == "hybrid":
            windows = jnp.asarray(
                [_layer_window(cfg, i) for i in range(cfg.num_layers)],
                jnp.int32,
            )

        def body(carry, xs):
            hh, aux = carry
            bp, win = xs if windows is not None else (xs, 0)
            hh, cache, a = block_full(
                hh, bp, cfg, layer_window=win, prompt_lens=prompt_lens)
            out = cache if collect_cache else None
            return (hh, aux + a), out

        wrapped = body
        if remat == "full":
            wrapped = jax.checkpoint(body)
        elif remat == "dots":
            wrapped = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            )
        xs = (params["blocks"], windows) if windows is not None else params["blocks"]
        (h, aux), caches = jax.lax.scan(wrapped, (h, jnp.float32(0.0)), xs)
        aux = aux / cfg.num_layers
    else:
        caches = []
        aux = jnp.float32(0.0)
        stacked = uses_scan(cfg)
        for i in range(cfg.num_layers):
            bp = layer_slice(params["blocks"], i) if stacked else params["blocks"][i]
            fn = functools.partial(
                block_full, cfg=cfg, layer_window=_layer_window(cfg, i),
                prompt_lens=prompt_lens)
            if remat in ("full", "dots"):
                fn = jax.checkpoint(fn)
            h, cache, a = fn(h, bp)
            aux = aux + a / cfg.num_layers
            if collect_cache:
                caches.append(cache)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, caches, aux


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------
def train_loss(
    params, batch: Dict[str, jax.Array], cfg: ModelConfig,
    *, remat: str = "full", loss_chunk: int = 0, aux_weight: float = 0.01,
) -> jax.Array:
    """batch: tokens [B,S], targets [B,S], optional mask [B,S], patches."""
    tokens = batch["tokens"]
    patches = batch.get("patches")
    h, _, aux = forward_hidden(params, tokens, cfg, remat=remat, patches=patches)
    targets, mask = batch["targets"], batch.get("mask")
    if cfg.family == "vlm":
        # loss only over the text region; hidden includes patch prefix
        p = patches.shape[1]
        h = h[:, p:] if p else h
        # align: h[:, i] predicts targets[:, i]
    if loss_chunk <= 0:
        loss_chunk = 128 if cfg.vocab_size % 16 else 512
        loss_chunk = min(loss_chunk, h.shape[1])
    loss = chunked_softmax_xent(h, lm_head_weight(params, cfg), targets, mask, loss_chunk)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Decode cache construction / templates
# ---------------------------------------------------------------------------
def cache_spec(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    """ParamSpec tree describing the decode cache (for input_specs/dry-run).

    Logical axes: "batch" (data-sharded), "cache_seq" (model-sharded when
    batch is too small), "kv_heads", "window".
    """
    dh = cfg.resolved_head_dim
    k = cfg.num_kv_heads
    spec: Dict[str, Any] = {
        "pos": ParamSpec((batch,), ("batch",), dtype="int32"),
    }
    kv = lambda s, seq_ax: {
        "k": ParamSpec((cfg.num_layers, batch, s, k, dh), ("layers", "batch", seq_ax, "kv_heads", None)),
        "v": ParamSpec((cfg.num_layers, batch, s, k, dh), ("layers", "batch", seq_ax, "kv_heads", None)),
        "slot_pos": ParamSpec((cfg.num_layers, batch, s), ("layers", "batch", seq_ax), dtype="int32"),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        spec["attn"] = kv(cache_len, "cache_seq")
    elif cfg.family == "ssm":
        spec["ssm"] = {
            "h": ParamSpec(
                (cfg.num_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                ("layers", "batch", None, None, "ssm_state"), dtype="float32",
            ),
            "conv_buf": ParamSpec(
                (cfg.num_layers, batch, cfg.ssm_conv_dim - 1, cfg.d_inner + 2 * cfg.ssm_state),
                ("layers", "batch", None, None),
            ),
        }
    elif cfg.family == "hybrid":
        n_glob = len(cfg.global_attn_layers)
        n_slide = cfg.num_layers - n_glob
        w = min(cfg.sliding_window, cache_len)
        g = kv(cache_len, "cache_seq")
        s = kv(w, "window")
        spec["attn_global"] = jax.tree_util.tree_map(
            lambda ps: ParamSpec((n_glob,) + ps.shape[1:], ps.axes, ps.init, ps.dtype),
            g, is_leaf=lambda x: isinstance(x, ParamSpec))
        spec["attn_sliding"] = jax.tree_util.tree_map(
            lambda ps: ParamSpec((n_slide,) + ps.shape[1:], ps.axes, ps.init, ps.dtype),
            s, is_leaf=lambda x: isinstance(x, ParamSpec))
        spec["ssm"] = {
            "h": ParamSpec(
                (cfg.num_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                ("layers", "batch", None, None, "ssm_state"), dtype="float32",
            ),
            "conv_buf": ParamSpec(
                (cfg.num_layers, batch, cfg.ssm_conv_dim - 1, cfg.d_inner + 2 * cfg.ssm_state),
                ("layers", "batch", None, None),
            ),
        }
    return spec


def empty_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Materialized zero/empty cache (slot_pos = -1)."""
    from repro.models.common import abstract_params, is_spec

    spec = cache_spec(cfg, batch, cache_len)

    def mk(s: ParamSpec):
        dt = jnp.dtype(s.dtype or "bfloat16")
        if s.dtype == "int32":
            fill = -1 if len(s.shape) >= 3 else 0  # slot_pos=-1, pos=0
            return jnp.full(s.shape, fill, dt)
        return jnp.zeros(s.shape, dt)

    return jax.tree_util.tree_map(mk, spec, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
def prefill(
    params, tokens, prompt_lens, cfg: ModelConfig, *, patches=None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Forward the prompt, build the decode cache, return last-token logits.

    tokens [B, S] padded to S; prompt_lens [B] actual lengths (<= S).
    Cache length == S (the serving layer chooses padding = cache size).
    """
    bsz, s = tokens.shape
    h, caches, _ = forward_hidden(
        params, tokens, cfg, collect_cache=True, patches=patches,
        prompt_lens=prompt_lens)
    total = s + (patches.shape[1] if (cfg.family == "vlm" and patches is not None) else 0)

    last = jnp.maximum(prompt_lens - 1, 0)
    if cfg.family == "vlm" and patches is not None:
        last = last + patches.shape[1]
    h_last = jnp.take_along_axis(h, last[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = jnp.einsum("bd,dv->bv", h_last, lm_head_weight(params, cfg)).astype(jnp.float32)

    valid = jnp.arange(total)[None, :] < (
        prompt_lens[:, None]
        + (patches.shape[1] if (cfg.family == "vlm" and patches is not None) else 0)
    )
    slot_pos = jnp.where(valid, jnp.arange(total)[None, :], -1).astype(jnp.int32)

    cache: Dict[str, Any] = {"pos": prompt_lens.astype(jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        cache["attn"] = {
            "k": caches["k"], "v": caches["v"],
            "slot_pos": jnp.broadcast_to(slot_pos[None], (cfg.num_layers,) + slot_pos.shape),
        }
    elif cfg.family == "ssm":
        cache["ssm"] = {"h": caches["ssm"].h, "conv_buf": caches["ssm"].conv_buf}
    elif cfg.family == "hybrid":
        glob, slide = [], []
        ssm_h, ssm_c = [], []
        w = min(cfg.sliding_window, s)
        for i, c in enumerate(caches):
            ssm_h.append(c["ssm"].h)
            ssm_c.append(c["ssm"].conv_buf)
            if i in cfg.global_attn_layers:
                glob.append((c["k"], c["v"], slot_pos))
            else:
                # keep trailing window, ring-ordered by absolute position % w
                kk, vv = c["k"][:, -w:], c["v"][:, -w:]
                pos_tail = jnp.arange(s - w, s)
                ring_idx = jnp.argsort(pos_tail % w)
                sp = jnp.where(
                    pos_tail[ring_idx][None, :] < prompt_lens[:, None],
                    pos_tail[ring_idx][None, :], -1).astype(jnp.int32)
                slide.append((kk[:, ring_idx], vv[:, ring_idx], sp))
        stack = lambda xs: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *xs)
        if glob:
            g = stack(glob)
            cache["attn_global"] = {"k": g[0], "v": g[1], "slot_pos": g[2]}
        if slide:
            sl = stack(slide)
            cache["attn_sliding"] = {"k": sl[0], "v": sl[1], "slot_pos": sl[2]}
        cache["ssm"] = {"h": jnp.stack(ssm_h), "conv_buf": jnp.stack(ssm_c)}
    return logits, cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------
def decode_step(
    params, cache: Dict[str, Any], tokens: jax.Array, cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. tokens [B] -> (logits [B,V], updated cache)."""
    pos = cache["pos"]
    h = embed_tokens(tokens, params["embed"])

    new_cache = dict(cache)
    if cfg.family in ("dense", "moe", "vlm"):
        att = cache["attn"]

        def body(hh, xs):
            bp, kc, vc, sp = xs
            hh, oc = block_decode(hh, bp, cfg, {"k": kc, "v": vc, "slot_pos": sp}, pos)
            return hh, (oc["k"], oc["v"], oc["slot_pos"])

        h, (k2, v2, sp2) = jax.lax.scan(
            body, h, (params["blocks"], att["k"], att["v"], att["slot_pos"])
        )
        new_cache["attn"] = {"k": k2, "v": v2, "slot_pos": sp2}
    elif cfg.family == "ssm":
        st = cache["ssm"]

        def body(hh, xs):
            bp, sh, sc = xs
            hh, oc = block_decode(hh, bp, cfg, {"ssm": ssm_lib.SSMState(sh, sc)}, pos)
            return hh, (oc["ssm"].h, oc["ssm"].conv_buf)

        h, (h2, c2) = jax.lax.scan(body, h, (params["blocks"], st["h"], st["conv_buf"]))
        new_cache["ssm"] = {"h": h2, "conv_buf": c2}
    else:  # hybrid: unrolled over layer slices of the stacked tree
        gi = si = 0
        glob_out, slide_out, ssm_out = [], [], []
        for i in range(cfg.num_layers):
            bp = layer_slice(params["blocks"], i)
            lw = _layer_window(cfg, i)
            lc = {"ssm": ssm_lib.SSMState(cache["ssm"]["h"][i], cache["ssm"]["conv_buf"][i])}
            if lw:
                src, j, ring = cache["attn_sliding"], si, True
            else:
                src, j, ring = cache["attn_global"], gi, False
            lc.update(k=src["k"][j], v=src["v"][j], slot_pos=src["slot_pos"][j])
            h, oc = block_decode(h, bp, cfg, lc, pos, layer_window=lw, ring=ring)
            ssm_out.append(oc["ssm"])
            if lw:
                slide_out.append((oc["k"], oc["v"], oc["slot_pos"])); si += 1
            else:
                glob_out.append((oc["k"], oc["v"], oc["slot_pos"])); gi += 1
        stack = lambda xs: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *xs)
        if glob_out:
            g = stack(glob_out)
            new_cache["attn_global"] = {"k": g[0], "v": g[1], "slot_pos": g[2]}
        if slide_out:
            sl = stack(slide_out)
            new_cache["attn_sliding"] = {"k": sl[0], "v": sl[1], "slot_pos": sl[2]}
        st = stack(ssm_out)
        new_cache["ssm"] = {"h": st.h, "conv_buf": st.conv_buf}

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h, lm_head_weight(params, cfg)).astype(jnp.float32)
    new_cache["pos"] = pos + 1
    return logits, new_cache
