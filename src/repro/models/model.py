"""Unified model API: one factory for every architecture family.

``build(cfg)`` returns a ``ModelApi`` whose members are pure functions
suitable for jit/pjit.  Parameters are never materialized unless
``init_params`` is called -- the dry-run uses ``abstract_params`` only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import common, encdec, transformer
from repro.models.common import ParamSpec


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    param_template: Dict[str, Any]
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    cache_spec: Callable  # (batch, cache_len) -> ParamSpec tree

    def abstract_params(self):
        return common.abstract_params(self.param_template, self.cfg.dtype)

    def init_params(self, rng: jax.Array):
        return common.init_params(self.param_template, rng, self.cfg.dtype)

    def logical_axes(self):
        return common.logical_axes(self.param_template)

    def abstract_cache(self, batch: int, cache_len: int):
        return common.abstract_params(self.cache_spec(batch, cache_len), self.cfg.dtype)

    def cache_logical_axes(self, batch: int, cache_len: int):
        return common.logical_axes(self.cache_spec(batch, cache_len))

    def init_cache(self, batch: int, cache_len: int):
        if self.cfg.family == "encdec":
            spec = self.cache_spec(batch, cache_len)

            def mk(s: ParamSpec):
                dt = jnp.dtype(s.dtype or self.cfg.dtype)
                if s.dtype == "int32":
                    fill = -1 if len(s.shape) >= 3 else 0
                    return jnp.full(s.shape, fill, dt)
                return jnp.zeros(s.shape, dt)

            return jax.tree_util.tree_map(mk, spec, is_leaf=common.is_spec)
        return transformer.empty_cache(self.cfg, batch, cache_len)

    def param_count(self) -> int:
        return common.param_count(self.param_template)

    def param_bytes(self) -> int:
        return common.param_bytes(self.param_template, self.cfg.dtype)


def build(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "encdec":
        return ModelApi(
            cfg=cfg,
            param_template=encdec.param_template(cfg),
            train_loss=lambda p, b, **kw: encdec.train_loss(p, b, cfg, **kw),
            prefill=lambda p, t, pl, **kw: encdec.prefill(p, t, pl, cfg, **kw),
            decode_step=lambda p, c, t, **kw: encdec.decode_step(p, c, t, cfg, **kw),
            cache_spec=lambda batch, cache_len: encdec.cache_spec(cfg, batch, cache_len),
        )
    return ModelApi(
        cfg=cfg,
        param_template=transformer.param_template(cfg),
        train_loss=lambda p, b, **kw: transformer.train_loss(p, b, cfg, **kw),
        prefill=lambda p, t, pl, **kw: transformer.prefill(p, t, pl, cfg, **kw),
        decode_step=lambda p, c, t, **kw: transformer.decode_step(p, c, t, cfg, **kw),
        cache_spec=lambda batch, cache_len: transformer.cache_spec(cfg, batch, cache_len),
    )
