"""Primitive layers: norms, MLPs, embeddings, rotary embeddings.

All computations that are numerically sensitive (norm statistics, softmax)
run in float32 regardless of the parameter/activation dtype.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.constraints import shard_act


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    if g.ndim == 3:
        g, u = shard_act(g, "bsf"), shard_act(u, "bsf")
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(
    x: jax.Array,
    w_in: jax.Array,
    b_in: jax.Array,
    w_out: jax.Array,
    b_out: jax.Array,
) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w_in) + b_in
    h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies [head_dim//2], float32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` [..., seq, heads, head_dim] by ``positions`` [..., seq].

    Uses the split-half convention (rotate_half), matching llama.
    """
    dt = x.dtype
    half = x.shape[-1] // 2
    inv = rope_freqs(x.shape[-1], theta)  # [half]
    ang = positions.astype(jnp.float32)[..., None] * inv  # [..., seq, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(length: int, d_model: int) -> jax.Array:
    """Classic transformer sinusoidal embeddings [length, d_model], f32."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Vocabulary / loss
# ---------------------------------------------------------------------------
def embed_tokens(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def chunked_softmax_xent(
    h: jax.Array,
    lm_head: jax.Array,
    targets: jax.Array,
    mask: Optional[jax.Array] = None,
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing [B, S, V] logits.

    Scans over sequence chunks; per-chunk logits are [B, chunk, V], which is
    bounded, and the sum-loss is accumulated in f32. ``mask`` (if given) is
    [B, S] with 1.0 for counted tokens.

    Returns mean loss over counted tokens.
    """
    b, s, d = h.shape
    assert s % chunk == 0, f"seq {s} not divisible by loss chunk {chunk}"
    n_chunks = s // chunk
    hs = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    if mask is None:
        ms = jnp.ones((n_chunks, b, chunk), jnp.float32)
    else:
        ms = mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2).astype(jnp.float32)

    def body(carry, xs):
        loss_sum, count = carry
        hc, tc, mc = xs
        logits = jnp.einsum("bsd,dv->bsv", hc, lm_head).astype(jnp.float32)
        logits = shard_act(logits, "bsv")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        loss_sum = loss_sum + jnp.sum((logz - gold) * mc)
        count = count + jnp.sum(mc)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ts, ms)
    )
    return loss_sum / jnp.maximum(count, 1.0)
