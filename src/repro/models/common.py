"""Shared parameter-template machinery.

Models declare their parameters as trees of ``ParamSpec`` (shape, dtype,
logical axes, initializer). From one template we derive:

  * ``abstract_params``  -- ShapeDtypeStruct tree (dry-run, no allocation)
  * ``init_params``      -- materialized arrays (smoke tests / examples)
  * ``logical_axes``     -- logical-axis tree consumed by repro.sharding

Logical axis vocabulary (mapped to mesh axes in ``repro.sharding.rules``):
  "layers"  -- stacked layer dim (scan dim; never mesh-sharded)
  "vocab"   -- vocabulary dim
  "embed"   -- d_model dim
  "heads"   -- attention head dim (q heads * head_dim fused)
  "kv_heads"-- kv head dim
  "ffn"     -- FFN hidden dim
  "experts" -- MoE expert dim
  "ssm_in"  -- SSM inner channel dim
  None      -- replicated / unsharded dim
"""
from __future__ import annotations

import math
import zlib
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | scaled | ssm_a | embed
    dtype: Optional[str] = None  # None -> model default

    def with_layers(self, num_layers: int) -> "ParamSpec":
        return ParamSpec(
            (num_layers,) + self.shape,
            ("layers",) + self.axes,
            self.init,
            self.dtype,
        )


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], template):
    return jax.tree_util.tree_map(fn, template, is_leaf=is_spec)


def abstract_params(template, default_dtype: str = "bfloat16"):
    def to_sds(s: ParamSpec):
        dt = jnp.dtype(s.dtype or default_dtype)
        return jax.ShapeDtypeStruct(s.shape, dt)

    return tree_map_specs(to_sds, template)


def logical_axes(template):
    return tree_map_specs(lambda s: s.axes, template)


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 1:
        return shape[0]
    # treat all but the last dim as fan-in (matches our [in, out] convention)
    return int(np.prod(shape[:-1]))


def init_params(template, rng: jax.Array, default_dtype: str = "bfloat16"):
    """Materialize the template. Deterministic given ``rng``.

    Each leaf gets an independent key derived from its tree path, so adding
    parameters does not perturb the init of existing ones.
    """
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=is_spec
    )[0]
    treedef = jax.tree_util.tree_structure(template, is_leaf=is_spec)

    out = []
    for path, spec in leaves_with_paths:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        # crc32, not hash(): builtin str hashing is salted per process
        # (PYTHONHASHSEED), which would give each process different inits
        key = jax.random.fold_in(rng, zlib.crc32(name.encode()) % (2**31))
        dt = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dt)
        elif spec.init == "ssm_a":
            # A_log init: log of uniform [1, 16] (mamba2 convention)
            u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
            arr = jnp.log(u).astype(dt)
        elif spec.init == "embed":
            arr = (jax.random.normal(key, spec.shape, jnp.float32) * 0.02).astype(dt)
        else:  # normal / scaled: truncated-normal fan-in scaled
            scale = 1.0 / math.sqrt(max(1, _fan_in(spec.shape)))
            arr = (
                jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
                * scale
            ).astype(dt)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def param_bytes(template, default_dtype: str = "bfloat16") -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(template, is_leaf=is_spec):
        dt = jnp.dtype(s.dtype or default_dtype)
        total += int(np.prod(s.shape)) * dt.itemsize
    return total


def param_count(template) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree_util.tree_leaves(template, is_leaf=is_spec)
    )
