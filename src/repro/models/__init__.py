"""Model zoo: all assigned architecture families as pure-JAX modules."""
from repro.models.model import ModelApi, build

__all__ = ["ModelApi", "build"]
