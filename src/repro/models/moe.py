"""Mixture-of-Experts FFN with top-k routing.

Two dispatch strategies, one math (identical outputs up to token dropping):

  * ``dispatch="einsum"``  -- classic capacity-based one-hot dispatch
    (Switch/Mesh-TF style).  Robust under SPMD at any mesh size; the
    baseline for the dry-run.  Cost: the dispatch/combine einsums add
    O(S*E*C*D) FLOPs and an [S, E, C] mask -- this is the dominant
    compute-waste term the MoE hillclimb removes (see EXPERIMENTS.md §Perf).

  * ``dispatch="sort"``    -- sort-based scatter dispatch: token-assignments
    are sorted by expert id, placed into [E, C, D] buffers by rank-in-expert,
    and combined by gather.  No S*E*C one-hot tensor, no dispatch matmul;
    ~2x fewer MoE-block FLOPs at top-8.  Beyond-paper optimization.

Routing: softmax over expert logits, top-k, renormalized combine weights
(OLMoE/Qwen3 convention).  Tokens above expert capacity are dropped
(contribute zero) -- standard for capacity-based MoE.

Expert parallelism: the expert dim of all expert weights carries logical
axis "experts" (mapped to the "model" mesh axis), so expert FFN matmuls are
local and dispatch/combine lower to all-to-all style collectives.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import ParamSpec


def param_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    t = {
        "router": ParamSpec((d, e), ("embed", None), dtype="float32"),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "w_down": ParamSpec((e, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.shared_expert_d_ff:
        fs = cfg.shared_expert_d_ff
        t["shared_gate"] = ParamSpec((d, fs), ("embed", "ffn"))
        t["shared_up"] = ParamSpec((d, fs), ("embed", "ffn"))
        t["shared_down"] = ParamSpec((fs, d), ("ffn", "embed"))
    return t


def expert_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    cap = int(
        math.ceil(
            cfg.moe_capacity_factor
            * tokens_per_group
            * cfg.experts_per_token
            / cfg.num_experts
        )
    )
    # MXU-friendly multiple of 8; at least k so tiny smoke configs route.
    return max(cfg.experts_per_token, ((cap + 7) // 8) * 8)


def _route(x: jax.Array, router: jax.Array, k: int):
    """Return (expert_idx [T,k] int32, combine_w [T,k] f32, aux_loss f32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss.
    e = router.shape[1]
    density = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=jnp.float32).sum(axis=1), axis=0
    )
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * mean_prob)
    return top_i.astype(jnp.int32), top_w, aux


def _expert_ffn(xe: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    """xe [E, C, D] -> [E, C, D], per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])


def _moe_einsum(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig, cap: int):
    """Capacity one-hot dispatch. x [T, D] -> [T, D]."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    idx, w, aux = _route(x, p["router"].astype(jnp.float32), k)

    onehot_e = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # [T, k, E]
    # rank of each (token, k) within its expert = exclusive cumsum over tokens
    pos_in_e = jnp.cumsum(onehot_e.reshape(t * k, e), axis=0) - 1.0
    pos_in_e = pos_in_e.reshape(t, k, e)
    rank = jnp.sum(onehot_e * pos_in_e, axis=-1)  # [T, k] float
    keep = (rank < cap).astype(jnp.float32)

    onehot_c = jax.nn.one_hot(rank.astype(jnp.int32), cap, dtype=jnp.float32)
    # dispatch [T, E, C] (bf16 to halve the bandwidth of the big mask)
    dispatch = jnp.einsum("tke,tkc->tec", onehot_e, onehot_c * keep[..., None])
    combine = jnp.einsum("tke,tkc,tk->tec", onehot_e, onehot_c, w * keep)

    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), x)
    ye = _expert_ffn(xe, p)
    y = jnp.einsum("tec,ecd->td", combine.astype(ye.dtype), ye)
    return y, aux


def _moe_sort(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig, cap: int):
    """Sort-based scatter dispatch. x [T, D] -> [T, D].

    Token-assignments [T*k] are sorted by expert id; rank-in-expert comes
    from the sorted order minus the expert's start offset (a tiny cumsum
    over E), so no [T, E] one-hot or [T, E, C] mask is ever built.
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    idx, w, aux = _route(x, p["router"].astype(jnp.float32), k)

    flat_e = idx.reshape(-1)  # [T*k]
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    counts = jnp.bincount(flat_e, length=e)  # [E]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = rank < cap
    slot = se * cap + jnp.where(keep, rank, 0)  # flattened [E*C] slot

    xe = jnp.zeros((e * cap, d), x.dtype)
    gathered = jnp.take(x, stok, axis=0)
    xe = xe.at[slot].set(jnp.where(keep[:, None], gathered, 0))
    ye = _expert_ffn(xe.reshape(e, cap, d), p).reshape(e * cap, d)

    contrib = jnp.take(ye, slot, axis=0) * (sw * keep)[:, None].astype(ye.dtype)
    y = jnp.zeros((t, d), ye.dtype).at[stok].add(contrib)
    return y, aux


def apply_moe(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    dispatch: str = "einsum",
    group_size: int = 1024,
) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN over x [..., S, D]; returns (y, aux_loss).

    Tokens are processed in routing groups of ``group_size`` (capacity is per
    group) to bound the dispatch-mask footprint; groups vmap over the leading
    dim, which SPMD shards over the data axes.
    """
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    gs = min(group_size, t)
    assert t % gs == 0, f"tokens {t} not divisible by moe group {gs}"
    cap = expert_capacity(cfg, gs)
    xg = xt.reshape(t // gs, gs, d)

    fn = _moe_sort if dispatch == "sort" else _moe_einsum
    yg, aux = jax.vmap(lambda g: fn(g, p, cfg, cap))(xg)

    if cfg.shared_expert_d_ff:
        from repro.models.layers import swiglu

        yg = yg + swiglu(xg, p["shared_gate"], p["shared_up"], p["shared_down"])
    return yg.reshape(shape), jnp.mean(aux)
