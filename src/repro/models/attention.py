"""Attention: GQA with RoPE, chunked (flash-style) prefill, cache decode.

Three implementations with one math:

  * ``naive_attention``   -- O(S^2) memory; oracle for tests, tiny shapes.
  * ``chunked_attention`` -- nested-scan online-softmax (flash in jnp);
                             O(block^2) score memory; used by train/prefill
                             on CPU and as the lowering-friendly path.
  * ``kernels.ops.flash_attention`` -- Pallas TPU kernel (selected via
                             ``use_pallas``; validated against these).

Shape conventions:
  q        [B, Sq, Hq, dh]
  k, v     [B, Sk, Hkv, dh]      (Hq % Hkv == 0; G = Hq // Hkv)
  output   [B, Sq, Hq, dh]
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _gqa_split(q: jax.Array, num_kv: int) -> jax.Array:
    b, s, hq, dh = q.shape
    return q.reshape(b, s, num_kv, hq // num_kv, dh)


def _window_mask(qpos, kpos, window):
    """Sliding-window mask that accepts a static int OR a traced scalar.

    A dynamic window (e.g. a per-layer value scanned over a hybrid stack)
    uses window <= 0 to mean "full attention"."""
    if isinstance(window, (int, np.integer)):
        if window == 0:
            return None
        return qpos - kpos < window
    return (window <= 0) | (qpos - kpos < window)


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=0,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference attention. Materializes [B, Hkv, G, Sq, Sk] scores."""
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    scale = scale if scale is not None else dh**-0.5
    qg = _gqa_split(q, hkv)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    wm = _window_mask(qpos, kpos, window)
    if wm is not None:
        mask &= wm
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, hq, dh)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp.

    Outer ``lax.map`` over query blocks, inner ``lax.scan`` over KV blocks
    carrying (running max, running denominator, accumulator). Never
    materializes more than [B, Hkv, G, q_block, kv_block] scores, so the
    compiled HLO stays O(S) in memory at 32k/500k context.
    """
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else dh**-0.5
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    # pad to block multiples (e.g. whisper's 1500 encoder frames, vlm's
    # 4096+256 patch-prefixed rows); padded kv columns are masked below,
    # padded q rows are sliced away at the end
    sq_orig, sk_orig = sq, sk
    pad_q = (-sq) % q_block
    pad_k = (-sk) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        sk += pad_k
    nq, nk = sq // q_block, sk // kv_block

    qg = _gqa_split(q, hkv).reshape(b, nq, q_block, hkv, g, dh)
    qg = jnp.moveaxis(qg, 1, 0)  # [nq, b, qb, k, g, dh]
    ks = jnp.moveaxis(k.reshape(b, nk, kv_block, hkv, dh), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kv_block, hkv, dh), 1, 0)

    def q_step(qi_qblk):
        qi, qblk = qi_qblk  # qblk [b, qb, k, g, dh]
        qpos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, kj_kv):
            m, l, acc = carry
            kj, kblk, vblk = kj_kv
            kpos = kj * kv_block + jnp.arange(kv_block)
            s = (
                jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk).astype(jnp.float32)
                * scale
            )
            mask = jnp.broadcast_to(kpos[None, :] < sk_orig, (q_block, kv_block))
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            wm = _window_mask(qpos[:, None], kpos[None, :], window)
            if wm is not None:
                mask &= wm
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # [b, k, g, qb, dh] -> [b, qb, k, g, dh]
        return jnp.moveaxis(out, 3, 1)

    outs = jax.lax.map(q_step, (jnp.arange(nq), qg))  # [nq, b, qb, k, g, dh]
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, dh)
    if pad_q:
        out = out[:, :sq_orig]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    slot_pos: jax.Array,
    cur_pos: jax.Array,
    *,
    window=0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q        [B, Hq, dh]       query for the new token
    k_cache  [B, S, Hkv, dh]   keys, already rotated at their write position
    v_cache  [B, S, Hkv, dh]
    slot_pos [B, S] int32      absolute position stored in each slot; -1 empty
    cur_pos  [B]    int32      position of the query token
    """
    b, hq, dh = q.shape
    _, s, hkv, _ = k_cache.shape
    scale = scale if scale is not None else dh**-0.5
    qg = q.reshape(b, hkv, hq // hkv, dh)
    scores = (
        jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    )
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos[:, None])
    if window:
        valid &= cur_pos[:, None] - slot_pos < window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, hq, dh)


class KVCache(NamedTuple):
    """Per-layer-stacked KV cache with slot-position bookkeeping.

    k, v      [L, B, S, Hkv, dh]
    slot_pos  [L, B, S] int32 (-1 = empty). For ring (sliding-window) caches
              S == window and slots are written at ``pos % S``.
    """

    k: jax.Array
    v: jax.Array
    slot_pos: jax.Array

    @property
    def size(self) -> int:
        return self.k.shape[2]


def cache_write_prefill(
    cache_k: jax.Array,
    cache_v: jax.Array,
    slot_pos: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    ring: bool,
) -> tuple:
    """Write a full prefill segment [B, S_new, ...] into a single-layer cache
    [B, S_cache, ...] starting at position 0. If ``ring`` and S_new exceeds
    the cache, keep the trailing window."""
    b, s_new = k_new.shape[0], k_new.shape[1]
    s_cache = cache_k.shape[1]
    if s_new >= s_cache:
        start = s_new - s_cache
        kw = jax.lax.dynamic_slice_in_dim(k_new, start, s_cache, axis=1)
        vw = jax.lax.dynamic_slice_in_dim(v_new, start, s_cache, axis=1)
        pos = start + jnp.arange(s_cache)
        if ring:
            # place entry with absolute position p at slot p % S
            idx = pos % s_cache
            order = jnp.argsort(idx)
            kw, vw, pos = kw[:, order], vw[:, order], pos[order]
        new_pos = jnp.broadcast_to(pos[None, :], (b, s_cache)).astype(jnp.int32)
        return kw, vw, new_pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, 0, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, 0, axis=1)
    pos = jnp.concatenate(
        [jnp.arange(s_new), jnp.full((s_cache - s_new,), -1, jnp.int32)]
    ).astype(jnp.int32)
    sp = jnp.broadcast_to(pos[None, :], (b, s_cache))
    return ck, cv, sp


def cache_write_decode(
    cache_k: jax.Array,
    cache_v: jax.Array,
    slot_pos: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    pos: jax.Array,
    ring: bool,
) -> tuple:
    """Write one token [B, Hkv, dh] at position ``pos`` [B] (ring -> pos % S).

    Uses scatter (``.at[].set``) so only the touched rows move through HBM --
    a one-hot blend would rewrite the entire cache every decode step and
    double the memory-roofline term.
    """
    b, s = slot_pos.shape
    slot = pos % s if ring else jnp.minimum(pos, s - 1)
    bidx = jnp.arange(b)
    ck = cache_k.at[bidx, slot].set(k_new.astype(cache_k.dtype))
    cv = cache_v.at[bidx, slot].set(v_new.astype(cache_v.dtype))
    sp = slot_pos.at[bidx, slot].set(pos.astype(jnp.int32))
    return ck, cv, sp
