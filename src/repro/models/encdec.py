"""Whisper-style encoder-decoder backbone.

The conv frontend is a STUB per the assignment: inputs are precomputed frame
embeddings [B, F, d_model].  Everything downstream is real: sinusoidal
positions, bidirectional encoder self-attention, causal decoder self-attention
with KV cache, cross-attention with a prefill-computed cross KV cache, and
tied-embedding logits.  Whisper convention: LayerNorm (with bias) + GELU MLP;
no RoPE (positions are additive).  We use sinusoidal positions for the
decoder as well so decode_32k's synthetic 32k-token stress shape is
mechanically supported (learned 448-entry tables would not cover it; noted
in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.attention import (
    cache_write_decode,
    chunked_attention,
    decode_attention,
)
from repro.models.common import ParamSpec
from repro.models.layers import (
    chunked_softmax_xent,
    embed_tokens,
    gelu_mlp,
    layer_norm,
    sinusoidal_positions,
)


def _ln(d):
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def _attn_t(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h = cfg.num_heads
    return {
        "wq": ParamSpec((d, h * dh), ("embed", "heads")),
        "bq": ParamSpec((h * dh,), ("heads",), init="zeros"),
        "wk": ParamSpec((d, h * dh), ("embed", "heads")),
        "wv": ParamSpec((d, h * dh), ("embed", "heads")),
        "bv": ParamSpec((h * dh,), ("heads",), init="zeros"),
        "wo": ParamSpec((h * dh, d), ("heads", "embed")),
        "bo": ParamSpec((d,), ("embed",), init="zeros"),
    }


def _mlp_t(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_in": ParamSpec((d, f), ("embed", "ffn")),
        "b_in": ParamSpec((f,), ("ffn",), init="zeros"),
        "w_out": ParamSpec((f, d), ("ffn", "embed")),
        "b_out": ParamSpec((d,), ("embed",), init="zeros"),
    }


def param_template(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    enc_block = lambda: {"ln1": _ln(d), "attn": _attn_t(cfg), "ln2": _ln(d), "mlp": _mlp_t(cfg)}
    dec_block = lambda: {
        "ln1": _ln(d), "self_attn": _attn_t(cfg),
        "ln2": _ln(d), "cross_attn": _attn_t(cfg),
        "ln3": _ln(d), "mlp": _mlp_t(cfg),
    }
    return {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
        "enc_blocks": [enc_block() for _ in range(cfg.encoder_layers)],
        "enc_final": _ln(d),
        "dec_blocks": [dec_block() for _ in range(cfg.num_layers)],
        "dec_final": _ln(d),
    }


def _proj_qkv(x, ap, cfg, kv_from=None):
    """Project q from x, k/v from kv_from (defaults to x)."""
    dh = cfg.resolved_head_dim
    h = cfg.num_heads
    src = x if kv_from is None else kv_from
    q = (jnp.einsum("...d,de->...e", x, ap["wq"]) + ap["bq"]).reshape(*x.shape[:-1], h, dh)
    k = jnp.einsum("...d,de->...e", src, ap["wk"]).reshape(*src.shape[:-1], h, dh)
    v = (jnp.einsum("...d,de->...e", src, ap["wv"]) + ap["bv"]).reshape(*src.shape[:-1], h, dh)
    return q, k, v


def _out(o, ap):
    return jnp.einsum("...e,ed->...d", o.reshape(*o.shape[:-2], -1), ap["wo"]) + ap["bo"]


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames [B,F,D] (stub frontend output) -> encoder states [B,F,D]."""
    f = frames.shape[1]
    h = frames + sinusoidal_positions(f, cfg.d_model).astype(frames.dtype)[None]
    for bp in params["enc_blocks"]:
        x = layer_norm(h, bp["ln1"]["scale"], bp["ln1"]["bias"], cfg.norm_eps)
        q, k, v = _proj_qkv(x, bp["attn"], cfg)
        o = chunked_attention(q, k, v, causal=False)
        h = h + _out(o, bp["attn"])
        x2 = layer_norm(h, bp["ln2"]["scale"], bp["ln2"]["bias"], cfg.norm_eps)
        h = h + gelu_mlp(x2, bp["mlp"]["w_in"], bp["mlp"]["b_in"], bp["mlp"]["w_out"], bp["mlp"]["b_out"])
    return layer_norm(h, params["enc_final"]["scale"], params["enc_final"]["bias"], cfg.norm_eps)


def _decoder_full(params, tokens, enc_out, cfg, collect_cache=False):
    b, s = tokens.shape
    h = embed_tokens(tokens, params["embed"])
    h = h + sinusoidal_positions(s, cfg.d_model).astype(h.dtype)[None]
    caches = []
    for bp in params["dec_blocks"]:
        x = layer_norm(h, bp["ln1"]["scale"], bp["ln1"]["bias"], cfg.norm_eps)
        q, k, v = _proj_qkv(x, bp["self_attn"], cfg)
        o = chunked_attention(q, k, v, causal=True)
        h = h + _out(o, bp["self_attn"])

        x2 = layer_norm(h, bp["ln2"]["scale"], bp["ln2"]["bias"], cfg.norm_eps)
        qc, kc, vc = _proj_qkv(x2, bp["cross_attn"], cfg, kv_from=enc_out)
        oc = chunked_attention(qc, kc, vc, causal=False)
        h = h + _out(oc, bp["cross_attn"])

        x3 = layer_norm(h, bp["ln3"]["scale"], bp["ln3"]["bias"], cfg.norm_eps)
        h = h + gelu_mlp(x3, bp["mlp"]["w_in"], bp["mlp"]["b_in"], bp["mlp"]["w_out"], bp["mlp"]["b_out"])
        if collect_cache:
            caches.append({"k": k, "v": v, "cross_k": kc, "cross_v": vc})
    h = layer_norm(h, params["dec_final"]["scale"], params["dec_final"]["bias"], cfg.norm_eps)
    return h, caches


def train_loss(params, batch, cfg: ModelConfig, *, remat: str = "none",
               loss_chunk: int = 0, aux_weight: float = 0.0) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg)
    h, _ = _decoder_full(params, batch["tokens"], enc_out, cfg)
    if loss_chunk <= 0:
        loss_chunk = 128 if cfg.vocab_size % 16 else 512
        loss_chunk = min(loss_chunk, h.shape[1])
    return chunked_softmax_xent(
        h, params["embed"].T, batch["targets"], batch.get("mask"), loss_chunk
    )


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    dh = cfg.resolved_head_dim
    hh, L, f = cfg.num_heads, cfg.num_layers, cfg.encoder_frames
    return {
        "pos": ParamSpec((batch,), ("batch",), dtype="int32"),
        "attn": {
            "k": ParamSpec((L, batch, cache_len, hh, dh), ("layers", "batch", "cache_seq", "kv_heads", None)),
            "v": ParamSpec((L, batch, cache_len, hh, dh), ("layers", "batch", "cache_seq", "kv_heads", None)),
            "slot_pos": ParamSpec((L, batch, cache_len), ("layers", "batch", "cache_seq"), dtype="int32"),
        },
        "cross": {
            "k": ParamSpec((L, batch, f, hh, dh), ("layers", "batch", None, "kv_heads", None)),
            "v": ParamSpec((L, batch, f, hh, dh), ("layers", "batch", None, "kv_heads", None)),
        },
    }


def prefill(params, tokens, prompt_lens, cfg: ModelConfig, *, frames=None):
    """Encoder + decoder prompt pass; returns (last logits, decode cache)."""
    assert frames is not None, "encdec prefill needs frame embeddings"
    b, s = tokens.shape
    enc_out = encode(params, frames, cfg)
    h, caches = _decoder_full(params, tokens, enc_out, cfg, collect_cache=True)
    last = jnp.maximum(prompt_lens - 1, 0)
    h_last = jnp.take_along_axis(h, last[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = jnp.einsum("bd,dv->bv", h_last, params["embed"].T).astype(jnp.float32)

    slot = jnp.where(jnp.arange(s)[None] < prompt_lens[:, None], jnp.arange(s)[None], -1)
    stack = lambda key: jnp.stack([c[key] for c in caches])
    cache = {
        "pos": prompt_lens.astype(jnp.int32),
        "attn": {
            "k": stack("k"), "v": stack("v"),
            "slot_pos": jnp.broadcast_to(slot[None].astype(jnp.int32), (cfg.num_layers, b, s)),
        },
        "cross": {"k": stack("cross_k"), "v": stack("cross_v")},
    }
    return logits, cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    pos = cache["pos"]
    b = tokens.shape[0]
    h = embed_tokens(tokens, params["embed"])
    # position embedding for the current position, gathered per batch row
    f = cache["attn"]["k"].shape[2]
    pe_table = sinusoidal_positions(f, cfg.d_model)
    h = h + jnp.take(pe_table, jnp.minimum(pos, f - 1), axis=0).astype(h.dtype)

    ks, vs, sps = [], [], []
    for i, bp in enumerate(params["dec_blocks"]):
        x = layer_norm(h, bp["ln1"]["scale"], bp["ln1"]["bias"], cfg.norm_eps)
        q, k, v = _proj_qkv(x[:, None], bp["self_attn"], cfg)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        kc, vc, sp = cache_write_decode(
            cache["attn"]["k"][i], cache["attn"]["v"][i], cache["attn"]["slot_pos"][i],
            k, v, pos, ring=False,
        )
        o = decode_attention(q, kc, vc, sp, pos)
        h = h + _out(o, bp["self_attn"])
        ks.append(kc); vs.append(vc); sps.append(sp)

        x2 = layer_norm(h, bp["ln2"]["scale"], bp["ln2"]["bias"], cfg.norm_eps)
        qc = (jnp.einsum("bd,de->be", x2, bp["cross_attn"]["wq"]) + bp["cross_attn"]["bq"])
        qc = qc.reshape(b, cfg.num_heads, cfg.resolved_head_dim)
        ck, cv = cache["cross"]["k"][i], cache["cross"]["v"][i]
        valid = jnp.zeros((b, ck.shape[1]), jnp.int32)  # all-valid cross slots
        oc = decode_attention(qc, ck, cv, valid, jnp.zeros((b,), jnp.int32))
        h = h + _out(oc, bp["cross_attn"])

        x3 = layer_norm(h, bp["ln3"]["scale"], bp["ln3"]["bias"], cfg.norm_eps)
        h = h + gelu_mlp(x3, bp["mlp"]["w_in"], bp["mlp"]["b_in"], bp["mlp"]["w_out"], bp["mlp"]["b_out"])

    h = layer_norm(h, params["dec_final"]["scale"], params["dec_final"]["bias"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h, params["embed"].T).astype(jnp.float32)
    new_cache = {
        "pos": pos + 1,
        "attn": {"k": jnp.stack(ks), "v": jnp.stack(vs), "slot_pos": jnp.stack(sps)},
        "cross": cache["cross"],
    }
    return logits, new_cache
