"""Dandelion core: the paper's contribution as a composable platform.

Programming model (SS4): ``Composition`` DAGs of pure compute functions +
platform communication functions, with all/each/key edge fan-out.

Execution system (SS5-6): memory contexts, dispatcher, compute/comm
engines, PI control plane, cold-start backends, cluster manager.
"""
from repro.core.artifacts import (
    Artifact,
    ArtifactCatalog,
    P2PDistributor,
    PrefetchConfig,
)
from repro.core.cluster import ClusterManager, CrossNodePlacer, KeepWarmPlatform
from repro.core.coldstart import (
    BACKENDS,
    CodeCache,
    ColdStartBreakdown,
    ColdStartProfile,
    TransferProfile,
    cold_start,
    measure,
    profile_from_measurement,
)
from repro.core.control_plane import (
    BatchRouter,
    BurstPredictor,
    ControlPlaneConfig,
    ElasticControlPlane,
    PredictorConfig,
    ReplicaAutoscaler,
    ReplicaConfig,
    composition_batch_units,
    composition_functions,
)
from repro.core.context import MemoryContext, MemoryTracker
from repro.core.dag import Composition, Edge, PortRef, Vertex
from repro.core.dispatcher import Dispatcher, InvocationRun
from repro.core.engines import EngineSet, Task
from repro.core.http import (
    HttpRequest,
    HttpResponse,
    SanitizationError,
    ServiceRegistry,
    sanitize,
)
from repro.core.items import Item, ItemSet, SetDict, fingerprint_sets, make_set
from repro.core.node import WorkerNode
from repro.core.registry import FunctionRegistry, PayloadMemo
from repro.core.sim import EventLoop, ShardedEventLoop, Timeline, merged_peak
from repro.core.tracing import (
    LatencyStats,
    LinkCounters,
    NodeCounters,
    RoutingStats,
    ThroughputStats,
    TransferStats,
)
from repro.core.workloads import BatchStepModel, WeightStore

__all__ = [
    "Artifact",
    "ArtifactCatalog",
    "BACKENDS",
    "BatchRouter",
    "BatchStepModel",
    "BurstPredictor",
    "ClusterManager",
    "CodeCache",
    "ColdStartBreakdown",
    "ColdStartProfile",
    "Composition",
    "ControlPlaneConfig",
    "CrossNodePlacer",
    "ElasticControlPlane",
    "Dispatcher",
    "Edge",
    "EngineSet",
    "EventLoop",
    "ShardedEventLoop",
    "FunctionRegistry",
    "HttpRequest",
    "HttpResponse",
    "InvocationRun",
    "Item",
    "ItemSet",
    "KeepWarmPlatform",
    "LatencyStats",
    "ReplicaAutoscaler",
    "ReplicaConfig",
    "LinkCounters",
    "MemoryContext",
    "MemoryTracker",
    "NodeCounters",
    "P2PDistributor",
    "PayloadMemo",
    "PortRef",
    "PredictorConfig",
    "PrefetchConfig",
    "RoutingStats",
    "ThroughputStats",
    "SanitizationError",
    "ServiceRegistry",
    "SetDict",
    "Task",
    "Timeline",
    "TransferProfile",
    "TransferStats",
    "Vertex",
    "WeightStore",
    "WorkerNode",
    "cold_start",
    "composition_batch_units",
    "composition_functions",
    "fingerprint_sets",
    "make_set",
    "measure",
    "merged_peak",
    "profile_from_measurement",
    "sanitize",
]
