"""Composition IR: the paper's programming model (SS4.1).

A composition is a DAG G=(V,E). Vertices are (i) user compute functions,
(ii) platform communication functions, or (iii) nested compositions.
Edges carry a metadata descriptor: which output set of V1 feeds which
input set of V2 and a distribution keyword:

    all   -- every instance of V2 receives the whole item set
    each  -- one V2 instance per item
    key   -- one V2 instance per distinct item key

At most one 'each'/'key' edge may target a vertex (it determines the
instance count); 'all' edges broadcast to every instance.

The builder doubles as the composition DSL (SS4.1 "composition language").
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

MODES = ("all", "each", "key")

COMPUTE, COMM, SUBGRAPH = "compute", "comm", "composition"

# ------------------------------------------------------------------ hooks
# Registration hooks: callables invoked with each Composition as a
# FunctionRegistry accepts it — the seam the static-analysis layer
# (repro.analysis.graphlint.registration_lint_hook) plugs into without
# the IR importing the analyzer. The empty-list common case costs one
# truthiness check on the per-request register path.
_REGISTRATION_HOOKS: List[Callable[["Composition"], None]] = []


def add_registration_hook(hook) -> Callable[["Composition"], None]:
    """Install ``hook(comp)`` to run on every composition registration.
    Returns the hook (usable as a decorator)."""
    _REGISTRATION_HOOKS.append(hook)
    return hook


def remove_registration_hook(hook) -> None:
    """Uninstall a previously added hook (no-op if absent)."""
    try:
        _REGISTRATION_HOOKS.remove(hook)
    except ValueError:
        pass


def fire_registration_hooks(comp: "Composition") -> None:
    """Invoke all installed hooks (snapshot, so a hook may uninstall
    itself). Exceptions propagate: a strict lint hook is *supposed* to
    reject the registration."""
    if _REGISTRATION_HOOKS:
        for hook in tuple(_REGISTRATION_HOOKS):
            hook(comp)


@dataclass(frozen=True)
class RetryPolicy:
    """Per-vertex failure handling (SS6.1: pure functions are idempotent,
    so the platform restarts lost work transparently).

    ``max_retries`` resubmissions after the first attempt; each retry
    waits ``base_backoff_s * 2**attempts`` capped at ``max_backoff_s``
    (the astraflow RunOrchestrator schedule). Zero backoff resubmits
    synchronously from the failure callback — the historical behavior,
    and the byte-identity default. Failure classes: generic task errors
    ("error", e.g. comm sanitization) are always retryable within
    budget; "timeout" only when ``retry_timeouts`` is set;
    "node_failure" and "cancelled" are never retried at task level (the
    cluster restart path and the canceller own those)."""

    max_retries: int = 2
    base_backoff_s: float = 0.0
    max_backoff_s: float = 30.0
    retry_timeouts: bool = False

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff durations must be >= 0")
        if self.base_backoff_s > self.max_backoff_s:
            raise ValueError(
                f"base_backoff_s ({self.base_backoff_s}) exceeds "
                f"max_backoff_s ({self.max_backoff_s})"
            )

    def retryable(self, kind: str) -> bool:
        if kind == "timeout":
            return self.retry_timeouts
        return kind == "error"

    def backoff_s(self, attempts_done: int) -> float:
        """Delay before the next resubmission after ``attempts_done``
        attempts have failed: capped exponential, deterministic."""
        if self.base_backoff_s <= 0.0:
            return 0.0
        return min(self.base_backoff_s * (2.0 ** attempts_done),
                   self.max_backoff_s)


@dataclass(frozen=True)
class PortRef:
    vertex: str
    set_name: str


@dataclass
class Vertex:
    name: str
    kind: str                      # compute | comm | composition
    function: str = ""             # registry name (compute) / protocol (comm)
    inputs: Tuple[str, ...] = ()
    outputs: Tuple[str, ...] = ()
    subgraph: Optional["Composition"] = None
    context_bytes: int = 1 << 20   # user-declared memory requirement
    timeout_s: float = 60.0
    retry: Optional[RetryPolicy] = None   # None -> dispatcher default
    # units of a coalesced BATCH step this vertex occupies when its
    # function is batchable (chunked prefill spans several; default 1)
    batch_units: int = 1

    def __getitem__(self, set_name: str) -> PortRef:
        if set_name not in self.inputs and set_name not in self.outputs:
            raise KeyError(f"{self.name}: unknown set {set_name!r}")
        return PortRef(self.name, set_name)


@dataclass(frozen=True)
class Edge:
    src: PortRef
    dst: PortRef
    mode: str = "all"


@dataclass
class Composition:
    """DAG of compute/communication functions (+ nested compositions)."""

    name: str
    vertices: Dict[str, Vertex] = field(default_factory=dict)
    edges: List[Edge] = field(default_factory=list)
    input_bindings: Dict[str, PortRef] = field(default_factory=dict)
    output_bindings: Dict[str, PortRef] = field(default_factory=dict)
    # adjacency cache: per-vertex in/out edge lists in edge-append order.
    # ``edges`` is append-only through the DSL; ``edge()`` maintains the
    # cache incrementally, and direct appends by legacy callers are
    # detected by length and trigger a full rebuild. Direct *non-append*
    # mutation of ``edges`` (element replacement, removal) is outside
    # the contract — undetectable at O(1) unless a later ``edge()`` call
    # notices the length mismatch; no caller does it.
    _in_adj: Dict[str, List[Edge]] = field(
        default_factory=dict, init=False, repr=False, compare=False)
    _out_adj: Dict[str, List[Edge]] = field(
        default_factory=dict, init=False, repr=False, compare=False)
    _adj_edges_n: int = field(default=0, init=False, repr=False, compare=False)

    # ------------------------------------------------------------- DSL
    def _add(self, v: Vertex) -> Vertex:
        if v.name in self.vertices:
            raise ValueError(f"duplicate vertex {v.name!r}")
        self.vertices[v.name] = v
        return v

    def compute(
        self,
        name: str,
        function: str,
        inputs: Tuple[str, ...],
        outputs: Tuple[str, ...],
        context_bytes: int = 1 << 20,
        timeout_s: float = 60.0,
        retry: Optional[RetryPolicy] = None,
    ) -> Vertex:
        return self._add(Vertex(
            name, COMPUTE, function, tuple(inputs), tuple(outputs),
            context_bytes=context_bytes, timeout_s=timeout_s, retry=retry,
        ))

    def http(self, name: str, context_bytes: int = 1 << 20) -> Vertex:
        """The platform HTTP communication function (trusted, SS6.3)."""
        return self._add(Vertex(
            name, COMM, "http", ("requests",), ("responses",),
            context_bytes=context_bytes,
        ))

    def subgraph(self, name: str, comp: "Composition") -> Vertex:
        return self._add(Vertex(
            name, SUBGRAPH, comp.name,
            tuple(comp.input_bindings), tuple(comp.output_bindings),
            subgraph=comp,
        ))

    def edge(self, src: PortRef, dst: PortRef, mode: str = "all") -> None:
        if mode not in MODES:
            raise ValueError(f"edge mode {mode!r} not in {MODES}")
        sv, dv = self.vertices.get(src.vertex), self.vertices.get(dst.vertex)
        if sv is None or dv is None:
            raise ValueError("edge references unknown vertex")
        if src.set_name not in sv.outputs:
            raise ValueError(f"{src.vertex} has no output set {src.set_name!r}")
        if dst.set_name not in dv.inputs:
            raise ValueError(f"{dst.vertex} has no input set {dst.set_name!r}")
        e = Edge(src, dst, mode)
        if self._adj_edges_n == len(self.edges):   # cache fresh: extend it
            self._out_adj.setdefault(e.src.vertex, []).append(e)
            self._in_adj.setdefault(e.dst.vertex, []).append(e)
            self._adj_edges_n += 1
        else:
            # edges was mutated behind the DSL; appending now could make
            # the lengths coincide again, so force the next query to
            # rebuild instead of trusting the stale cache
            self._adj_edges_n = -1
        self.edges.append(e)

    def bind_input(self, name: str, dst: PortRef) -> None:
        self.input_bindings[name] = dst

    def bind_output(self, name: str, src: PortRef) -> None:
        self.output_bindings[name] = src

    # ------------------------------------------------------ validation
    def _refresh_adjacency(self) -> None:
        if self._adj_edges_n == len(self.edges):
            return
        self._in_adj, self._out_adj = {}, {}
        for e in self.edges:
            self._out_adj.setdefault(e.src.vertex, []).append(e)
            self._in_adj.setdefault(e.dst.vertex, []).append(e)
        self._adj_edges_n = len(self.edges)

    def in_edges(self, vertex: str) -> List[Edge]:
        """Edges targeting ``vertex``, in edge-append order. O(1) via the
        adjacency cache; treat the returned list as read-only."""
        self._refresh_adjacency()
        row = self._in_adj.get(vertex)
        return row if row is not None else []

    def out_edges(self, vertex: str) -> List[Edge]:
        """Edges leaving ``vertex``, in edge-append order. O(1) via the
        adjacency cache; treat the returned list as read-only."""
        self._refresh_adjacency()
        row = self._out_adj.get(vertex)
        return row if row is not None else []

    def validate(self) -> None:
        # acyclic
        order = self.topo_order()
        if len(order) != len(self.vertices):
            # every vertex on a cycle is stuck, but so is anything
            # downstream of one — name them as unorderable, not "the"
            # cycle
            stuck = sorted(set(self.vertices) - set(order))
            raise ValueError(
                f"{self.name}: composition graph has a cycle; vertices "
                f"not topologically orderable: {stuck}"
            )
        for v in self.vertices.values():
            fan = [e for e in self.in_edges(v.name) if e.mode in ("each", "key")]
            if len(fan) > 1:
                raise ValueError(
                    f"{v.name}: at most one 'each'/'key' edge may target a vertex"
                )
            # every input set must be fed by an edge or a composition input
            fed = {e.dst.set_name for e in self.in_edges(v.name)}
            fed |= {
                p.set_name for p in self.input_bindings.values()
                if p.vertex == v.name
            }
            missing = set(v.inputs) - fed
            if missing:
                raise ValueError(f"{v.name}: unfed input sets {sorted(missing)}")
            if v.kind == SUBGRAPH:
                v.subgraph.validate()
        for name, p in self.output_bindings.items():
            v = self.vertices.get(p.vertex)
            if v is None or p.set_name not in v.outputs:
                raise ValueError(f"output binding {name!r} invalid")

    def topo_order(self) -> List[str]:
        """Kahn's algorithm with a min-heap ready set: the lexicographic
        tie-break of the old sorted-list/pop(0) implementation at
        O((V+E) log V) instead of re-sorting per pop."""
        indeg = {v: 0 for v in self.vertices}
        for e in self.edges:
            indeg[e.dst.vertex] += 1
        ready = [v for v, d in indeg.items() if d == 0]
        heapq.heapify(ready)
        order: List[str] = []
        while ready:
            v = heapq.heappop(ready)
            order.append(v)
            for e in self.out_edges(v):
                indeg[e.dst.vertex] -= 1
                if indeg[e.dst.vertex] == 0:
                    heapq.heappush(ready, e.dst.vertex)
        return order

    def io_intensity(self) -> float:
        """Fraction of vertices that are communication functions - the
        signal the control plane uses for initial core allocation (SS3)."""
        if not self.vertices:
            return 0.0
        comm = sum(1 for v in self.vertices.values() if v.kind == COMM)
        return comm / len(self.vertices)
