"""Communication functions: HTTP protocol handling + service models.

The container is offline, so remote services are in-process handlers
behind deterministic latency/bandwidth models (DESIGN.md SS2). The
*protocol* work is real: requests are parsed and sanitized exactly as the
paper's communication engine does (SS6.3) - method and version checked
against fixed sets, host extracted and validated - and handlers produce
real payloads that flow on through the composition.
"""
from __future__ import annotations

import re
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.items import Item, ItemSet, SetDict

METHODS = ("GET", "PUT", "POST", "DELETE", "HEAD", "PATCH")
# floor on modeled protocol-handling CPU per request (the old measured
# path clamped real perf_counter deltas to this)
MIN_COMM_CPU_S = 2e-6
IDEMPOTENT_METHODS = ("GET", "PUT", "DELETE", "HEAD")
_VERSIONS = ("HTTP/1.0", "HTTP/1.1", "HTTP/2")
_HOST_RE = re.compile(
    r"^(?:[a-zA-Z0-9](?:[a-zA-Z0-9\-]{0,61}[a-zA-Z0-9])?)"
    r"(?:\.[a-zA-Z0-9](?:[a-zA-Z0-9\-]{0,61}[a-zA-Z0-9])?)*$"
)
_IP_RE = re.compile(r"^\d{1,3}(?:\.\d{1,3}){3}$")


@dataclass(frozen=True)
class HttpRequest:
    method: str
    url: str
    body: Any = b""

    @property
    def host(self) -> str:
        m = re.match(r"^https?://([^/:]+)", self.url)
        return m.group(1) if m else ""

    @property
    def nbytes(self) -> int:
        if isinstance(self.body, (bytes, bytearray)):
            return len(self.body)
        if hasattr(self.body, "nbytes"):
            return int(self.body.nbytes)
        return len(str(self.body).encode())


@dataclass(frozen=True)
class HttpResponse:
    status: int
    body: Any = b""

    @property
    def nbytes(self) -> int:
        if isinstance(self.body, (bytes, bytearray)):
            return len(self.body)
        if hasattr(self.body, "nbytes"):
            return int(self.body.nbytes)
        return len(str(self.body).encode())


class SanitizationError(ValueError):
    pass


def sanitize(req: Any) -> HttpRequest:
    """Untrusted-input validation (SS6.3): method + version from fixed
    sets; host must be a valid name or IPv4 literal."""
    if isinstance(req, HttpRequest):
        method, url = req.method, req.url
        parsed = req
    elif isinstance(req, (str, bytes)):
        text = req.decode() if isinstance(req, bytes) else req
        first = text.split("\r\n", 1)[0].split("\n", 1)[0]
        parts = first.strip().split()
        if len(parts) == 3:
            method, url, version = parts
            if version not in _VERSIONS:
                raise SanitizationError(f"bad HTTP version {version!r}")
        elif len(parts) == 2:
            method, url = parts
        else:
            raise SanitizationError(f"malformed request line {first!r}")
        body = text.split("\r\n\r\n", 1)[1] if "\r\n\r\n" in text else b""
        parsed = HttpRequest(method, url, body)
    else:
        raise SanitizationError(f"unsupported request type {type(req).__name__}")
    if method not in METHODS:
        raise SanitizationError(f"method {method!r} not allowed")
    host = parsed.host
    if not host or not (_HOST_RE.match(host) or _IP_RE.match(host)):
        raise SanitizationError(f"invalid host {host!r}")
    return parsed


@dataclass
class ServiceModel:
    """One remote endpoint: handler + latency/bandwidth/CPU models.

    Protocol-handling CPU is *modeled*, not measured: real wall-clock
    timing of the in-process handler leaked host jitter into virtual
    time, making comm-task durations vary run to run. The model is a
    per-service base cost (seeded deterministically from the host name
    at registration) plus a parse/copy cost per wire byte."""

    handler: Callable[[HttpRequest], HttpResponse]
    base_latency_s: float = 0.5e-3
    bandwidth_bps: float = 1.25e9  # 10 Gb/s
    cpu_base_s: float = MIN_COMM_CPU_S
    cpu_per_byte_s: float = 0.2e-9  # ~5 GB/s header/body parse + memcpy

    def io_time(self, req: HttpRequest, resp: HttpResponse) -> float:
        wire = req.nbytes + resp.nbytes
        return self.base_latency_s + wire / self.bandwidth_bps

    def cpu_time(self, req: HttpRequest, resp: HttpResponse) -> float:
        wire = req.nbytes + resp.nbytes
        return max(self.cpu_base_s + wire * self.cpu_per_byte_s,
                   MIN_COMM_CPU_S)


def _service_cpu_base(host: str) -> float:
    """Deterministic per-service protocol CPU base cost: +/-25% around
    MIN_COMM_CPU_S*2, seeded from the host name (stable across runs and
    processes, unlike hash())."""
    u = (zlib.crc32(host.encode()) % 1024) / 1024.0
    return 2 * MIN_COMM_CPU_S * (0.75 + 0.5 * u)


class ServiceRegistry:
    """host -> ServiceModel. Shared by all communication engines."""

    def __init__(self):
        self.services: Dict[str, ServiceModel] = {}

    def register(
        self,
        host: str,
        handler: Callable[[HttpRequest], HttpResponse],
        *,
        base_latency_s: float = 0.5e-3,
        bandwidth_bps: float = 1.25e9,
        cpu_base_s: Optional[float] = None,
    ) -> None:
        self.services[host] = ServiceModel(
            handler, base_latency_s, bandwidth_bps,
            cpu_base_s=_service_cpu_base(host) if cpu_base_s is None
            else cpu_base_s,
        )

    def perform(self, req: HttpRequest) -> Tuple[HttpResponse, float, float]:
        """Execute the request. Returns (response, modeled io seconds,
        modeled protocol-handling cpu seconds)."""
        svc = self.services.get(req.host)
        if svc is None:
            return HttpResponse(502, b"no route to host"), 1e-3, MIN_COMM_CPU_S
        resp = svc.handler(req)
        return resp, svc.io_time(req, resp), svc.cpu_time(req, resp)


def http_function(
    services: ServiceRegistry, inputs: SetDict
) -> Tuple[SetDict, float, float, bool]:
    """The platform HTTP communication function body.

    Sanitizes every request item, performs them (serially within one
    instance - parallelism is expressed with 'each' fan-out in the DAG),
    and returns (outputs, total io seconds, total modeled cpu seconds,
    idempotent_all). CPU cost is modeled per service so comm-task virtual
    durations are deterministic run to run.
    """
    responses: ItemSet = []
    io_total = 0.0
    cpu_total = 0.0
    idempotent = True
    for it in inputs.get("requests", []):
        req = sanitize(it.data)  # raises SanitizationError on bad input
        idempotent &= req.method in IDEMPOTENT_METHODS
        resp, io_s, cpu_s = services.perform(req)
        io_total += io_s
        cpu_total += cpu_s
        responses.append(Item(resp, key=it.key))
    return {"responses": responses}, io_total, max(cpu_total, MIN_COMM_CPU_S), idempotent
