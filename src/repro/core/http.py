"""Communication functions: HTTP protocol handling + service models.

The container is offline, so remote services are in-process handlers
behind deterministic latency/bandwidth models (DESIGN.md SS2). The
*protocol* work is real: requests are parsed and sanitized exactly as the
paper's communication engine does (SS6.3) - method and version checked
against fixed sets, host extracted and validated - and handlers produce
real payloads that flow on through the composition.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.items import Item, ItemSet, SetDict

METHODS = ("GET", "PUT", "POST", "DELETE", "HEAD", "PATCH")
IDEMPOTENT_METHODS = ("GET", "PUT", "DELETE", "HEAD")
_VERSIONS = ("HTTP/1.0", "HTTP/1.1", "HTTP/2")
_HOST_RE = re.compile(
    r"^(?:[a-zA-Z0-9](?:[a-zA-Z0-9\-]{0,61}[a-zA-Z0-9])?)"
    r"(?:\.[a-zA-Z0-9](?:[a-zA-Z0-9\-]{0,61}[a-zA-Z0-9])?)*$"
)
_IP_RE = re.compile(r"^\d{1,3}(?:\.\d{1,3}){3}$")


@dataclass(frozen=True)
class HttpRequest:
    method: str
    url: str
    body: Any = b""

    @property
    def host(self) -> str:
        m = re.match(r"^https?://([^/:]+)", self.url)
        return m.group(1) if m else ""

    @property
    def nbytes(self) -> int:
        if isinstance(self.body, (bytes, bytearray)):
            return len(self.body)
        if hasattr(self.body, "nbytes"):
            return int(self.body.nbytes)
        return len(str(self.body).encode())


@dataclass(frozen=True)
class HttpResponse:
    status: int
    body: Any = b""

    @property
    def nbytes(self) -> int:
        if isinstance(self.body, (bytes, bytearray)):
            return len(self.body)
        if hasattr(self.body, "nbytes"):
            return int(self.body.nbytes)
        return len(str(self.body).encode())


class SanitizationError(ValueError):
    pass


def sanitize(req: Any) -> HttpRequest:
    """Untrusted-input validation (SS6.3): method + version from fixed
    sets; host must be a valid name or IPv4 literal."""
    if isinstance(req, HttpRequest):
        method, url = req.method, req.url
        parsed = req
    elif isinstance(req, (str, bytes)):
        text = req.decode() if isinstance(req, bytes) else req
        first = text.split("\r\n", 1)[0].split("\n", 1)[0]
        parts = first.strip().split()
        if len(parts) == 3:
            method, url, version = parts
            if version not in _VERSIONS:
                raise SanitizationError(f"bad HTTP version {version!r}")
        elif len(parts) == 2:
            method, url = parts
        else:
            raise SanitizationError(f"malformed request line {first!r}")
        body = text.split("\r\n\r\n", 1)[1] if "\r\n\r\n" in text else b""
        parsed = HttpRequest(method, url, body)
    else:
        raise SanitizationError(f"unsupported request type {type(req).__name__}")
    if method not in METHODS:
        raise SanitizationError(f"method {method!r} not allowed")
    host = parsed.host
    if not host or not (_HOST_RE.match(host) or _IP_RE.match(host)):
        raise SanitizationError(f"invalid host {host!r}")
    return parsed


@dataclass
class ServiceModel:
    """One remote endpoint: handler + latency/bandwidth model."""

    handler: Callable[[HttpRequest], HttpResponse]
    base_latency_s: float = 0.5e-3
    bandwidth_bps: float = 1.25e9  # 10 Gb/s

    def io_time(self, req: HttpRequest, resp: HttpResponse) -> float:
        wire = req.nbytes + resp.nbytes
        return self.base_latency_s + wire / self.bandwidth_bps


class ServiceRegistry:
    """host -> ServiceModel. Shared by all communication engines."""

    def __init__(self):
        self.services: Dict[str, ServiceModel] = {}

    def register(
        self,
        host: str,
        handler: Callable[[HttpRequest], HttpResponse],
        *,
        base_latency_s: float = 0.5e-3,
        bandwidth_bps: float = 1.25e9,
    ) -> None:
        self.services[host] = ServiceModel(handler, base_latency_s, bandwidth_bps)

    def perform(self, req: HttpRequest) -> Tuple[HttpResponse, float]:
        """Execute the request. Returns (response, modeled io seconds)."""
        svc = self.services.get(req.host)
        if svc is None:
            return HttpResponse(502, b"no route to host"), 1e-3
        resp = svc.handler(req)
        return resp, svc.io_time(req, resp)


def http_function(
    services: ServiceRegistry, inputs: SetDict
) -> Tuple[SetDict, float, bool]:
    """The platform HTTP communication function body.

    Sanitizes every request item, performs them (serially within one
    instance - parallelism is expressed with 'each' fan-out in the DAG),
    and returns (outputs, total io seconds, idempotent_all).
    """
    responses: ItemSet = []
    io_total = 0.0
    idempotent = True
    for it in inputs.get("requests", []):
        req = sanitize(it.data)  # raises SanitizationError on bad input
        idempotent &= req.method in IDEMPOTENT_METHODS
        resp, io_s = services.perform(req)
        io_total += io_s
        responses.append(Item(resp, key=it.key))
    return {"responses": responses}, io_total, idempotent
