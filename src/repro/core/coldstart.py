# det-lint: file waive[wall-clock] reason=real-exec cold-start measurement; wall time here IS the measurement, not a model
"""Cold-start backends: three real code paths with Table-1-style phases.

The paper's four isolation backends (CHERI/rWasm/process/KVM) are CPU
hardware mechanisms with no TPU analogue (DESIGN.md SS2). What *does*
transfer is the cold-start cost structure, which we reproduce with real
work on this platform:

  dandelion  -- Dandelion's own path: bind a memory context + load the
                function binary from the RAM code cache (disk on a cache
                miss) + set up the I/O descriptor structure. No compile,
                no deserialize: this is the 100s-of-us path.
  snapshot   -- Firecracker-snapshot analogue: the function's AOT-compiled
                executable is deserialized from its serialized snapshot on
                every cold start (jax serialize_executable round trip).
                ms-scale.
  microvm    -- Firecracker full-boot analogue: trace+lower+compile the
                function on the critical path. 100ms-scale.

Phases mirror Table 1: marshal requests / load from disk / transfer input
/ execute(-setup) / get+send output. ``measure`` runs the real path k
times and returns median phase durations; the virtual-time engines then
consume these profiles (with seeded lognormal jitter) so thousand-RPS
sweeps stay faithful to measured costs.
"""
from __future__ import annotations

import pickle
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.context import MemoryContext
from repro.core.items import Item, SetDict
from repro.core.registry import ComputeFunction, FunctionRegistry

BACKENDS = ("dandelion", "snapshot", "microvm")


@dataclass
class ColdStartBreakdown:
    """Per-phase seconds (Table 1 rows)."""

    marshal: float = 0.0
    load: float = 0.0
    transfer: float = 0.0
    execute_setup: float = 0.0
    output: float = 0.0

    @property
    def total(self) -> float:
        return self.marshal + self.load + self.transfer + self.execute_setup + self.output

    def us(self) -> Dict[str, float]:
        return {
            "marshal_us": self.marshal * 1e6,
            "load_us": self.load * 1e6,
            "transfer_us": self.transfer * 1e6,
            "execute_setup_us": self.execute_setup * 1e6,
            "output_us": self.output * 1e6,
            "total_us": self.total * 1e6,
        }


class _AotCache:
    """Serialized-executable store for the snapshot/microvm backends."""

    def __init__(self):
        self._snapshots: Dict[str, bytes] = {}

    def snapshot_blob(self, cf: ComputeFunction) -> bytes:
        if cf.name in self._snapshots:
            return self._snapshots[cf.name]
        if cf.jax_fn is None:
            raise ValueError(f"{cf.name}: snapshot backend needs a jax payload")
        import jax
        from jax.experimental import serialize_executable

        compiled = jax.jit(cf.jax_fn).lower(*cf.abstract_args).compile()
        blob = serialize_executable.serialize(compiled)
        self._snapshots[cf.name] = pickle.dumps(blob)
        return self._snapshots[cf.name]


_AOT = _AotCache()


def _marshal(inputs: SetDict) -> Dict[str, Any]:
    """Build the low-level descriptor structure the function sees (SS4.1)."""
    return {
        name: [(it.key, it.nbytes) for it in items]
        for name, items in inputs.items()
    }


def cold_start(
    registry: FunctionRegistry,
    name: str,
    inputs: SetDict,
    *,
    backend: str = "dandelion",
    cached: bool = True,
    tracker=None,
    modeled: bool = False,
) -> Tuple[MemoryContext, ColdStartBreakdown, Callable[[], SetDict]]:
    """Run the real cold-start path. Returns (context, phases, run_fn).

    ``run_fn()`` executes the function body against the prepared context
    and writes outputs back into it (timed separately by the caller).

    ``modeled=True`` is the simulator fast path for tasks whose durations
    come from a calibrated ``ColdStartProfile``: the phase breakdown is
    not consumed, so the real disk read / AOT deserialize / compile work
    is skipped (memory is committed by size, page-identical), and the
    payload executes through the registry's content-addressed memo —
    each distinct ``(fn, input digest)`` body runs once, repeated trace
    events reuse the outputs. Dataflow and committed-memory accounting
    stay byte-identical with the measured path.
    """
    cf = registry.get(name)
    bd = ColdStartBreakdown()

    if modeled:
        ctx = MemoryContext(capacity=cf.context_bytes, tracker=tracker)
        # code + input-set pages commit as one collapsed tracker record
        # (accounting-identical; see MemoryContext.bulk_load)
        ctx.bulk_load(len(cf.code), inputs)
        memo = registry.memo

        def run_modeled() -> SetDict:
            out = memo.run(cf, ctx.inputs) if memo is not None else cf.fn(ctx.inputs)
            ctx.write_sets_bulk(out, into="outputs")
            return out

        return ctx, bd, run_modeled

    t0 = time.perf_counter()
    desc = _marshal(inputs)
    bd.marshal = time.perf_counter() - t0

    t0 = time.perf_counter()
    code = registry.load_code(name, cached=cached)
    bd.load = time.perf_counter() - t0

    t0 = time.perf_counter()
    ctx = MemoryContext(capacity=cf.context_bytes, tracker=tracker)
    ctx.load_code(code)
    for set_name, items in inputs.items():
        ctx.write_set(set_name, items)
    bd.transfer = time.perf_counter() - t0

    t0 = time.perf_counter()
    runner: Callable[[], SetDict]
    if backend == "dandelion":
        fn = cf.fn
        runner = lambda: fn(ctx.inputs)
    elif backend == "snapshot":
        blob = _AOT.snapshot_blob(cf)
        from jax.experimental import serialize_executable

        compiled = serialize_executable.deserialize_and_load(
            *pickle.loads(blob)
        )
        runner = _jax_runner(cf, compiled, ctx)
    elif backend == "microvm":
        if cf.jax_fn is None:
            raise ValueError(f"{name}: microvm backend needs a jax payload")
        import jax

        # fresh closure per boot: defeats the jit cache, so every cold
        # start really pays trace + lower + compile (the full-boot analogue)
        payload = cf.jax_fn
        fresh = lambda *a: payload(*a)  # noqa: E731
        compiled = jax.jit(fresh).lower(*cf.abstract_args).compile()
        runner = _jax_runner(cf, compiled, ctx)
    else:
        raise ValueError(f"unknown backend {backend!r}; known {BACKENDS}")
    bd.execute_setup = time.perf_counter() - t0

    def run_and_collect() -> SetDict:
        out = runner()
        t1 = time.perf_counter()
        for sname, items in out.items():
            ctx.write_set(sname, items, into="outputs")
        bd.output = time.perf_counter() - t1
        return out

    return ctx, bd, run_and_collect


def _jax_runner(cf: ComputeFunction, compiled, ctx: MemoryContext):
    """Adapt an AOT-compiled jax payload to the SetDict interface: arrays
    are taken positionally from the first input set."""

    def run() -> SetDict:
        args = []
        for items in ctx.inputs.values():
            for it in items:
                if hasattr(it.data, "shape"):
                    args.append(it.data)
        args = args[: len(cf.abstract_args)]
        result = compiled(*args)
        leaves = result if isinstance(result, (tuple, list)) else [result]
        return {"out": [Item(np.asarray(x)) for x in leaves]}

    return run


def measure(
    registry: FunctionRegistry,
    name: str,
    inputs: SetDict,
    *,
    backend: str = "dandelion",
    cached: bool = True,
    samples: int = 7,
    execute: bool = True,
) -> Tuple[ColdStartBreakdown, float]:
    """Median phase breakdown over ``samples`` real runs.

    Returns (breakdown, execute_seconds). Set ``execute=False`` to measure
    only sandbox creation (Fig. 5's workload).
    """
    phases = []
    exec_times = []
    for _ in range(samples):
        ctx, bd, run = cold_start(
            registry, name, inputs, backend=backend, cached=cached
        )
        if execute:
            t0 = time.perf_counter()
            run()
            exec_times.append(time.perf_counter() - t0 - bd.output)
        phases.append(bd)
        ctx.free()
    med = lambda xs: float(np.median(xs))
    out = ColdStartBreakdown(
        marshal=med([p.marshal for p in phases]),
        load=med([p.load for p in phases]),
        transfer=med([p.transfer for p in phases]),
        execute_setup=med([p.execute_setup for p in phases]),
        output=med([p.output for p in phases]),
    )
    return out, (med(exec_times) if exec_times else 0.0)


class CodeCache:
    """Per-node RAM code-cache residency model (SS5 two-level code store).

    The ``FunctionRegistry`` owns the *global* disk/RAM store; this class
    models which function binaries are resident in ONE worker node's RAM,
    which is what locality-aware routing cares about: a request lands
    "warm" only on a node that has already loaded the composition's
    functions. LRU over a bounded number of entries, with hit/miss
    counters the control plane exports through ``tracing.RoutingStats``.
    """

    def __init__(self, capacity_entries: int = 256):
        if capacity_entries <= 0:
            raise ValueError("code cache needs capacity >= 1 entry")
        self.capacity_entries = capacity_entries
        self._lru: "OrderedDict[str, None]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def resident(self, fn_name: str) -> bool:
        return fn_name in self._lru

    def warm_fraction(self, fn_names) -> float:
        """Fraction of ``fn_names`` resident — the routing affinity score."""
        names = list(fn_names)
        if not names:
            return 0.0
        return sum(1 for n in names if n in self._lru) / len(names)

    def warm(self, fn_name: str) -> None:
        """Seed residency without counting a hit or a miss.

        Used by P2P artifact prefetch (``core.artifacts``): the binary
        arrived over a modeled transfer, not a disk load, so the next
        ``touch`` must be a warm hit and hit/miss rates must reflect only
        real request traffic.
        """
        already = fn_name in self._lru
        self._lru[fn_name] = None
        self._lru.move_to_end(fn_name)
        if not already:
            while len(self._lru) > self.capacity_entries:
                self._lru.popitem(last=False)
                self.evictions += 1

    def touch(self, fn_name: str) -> bool:
        """Record a code load; returns True on a RAM hit (no disk read)."""
        hit = fn_name in self._lru
        if hit:
            self._lru.move_to_end(fn_name)
            self.hits += 1
        else:
            self.misses += 1
            self._lru[fn_name] = None
            while len(self._lru) > self.capacity_entries:
                self._lru.popitem(last=False)
                self.evictions += 1
        return hit

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._lru)


@dataclass
class ColdStartProfile:
    """Calibrated per-(function, backend) profile consumed by the
    virtual-time engines: deterministic base + seeded lognormal jitter.

    ``cold_setup_s`` is the extra, deliberately jitter-free setup charged
    when the task runs without resident state (``Task.cold_setup`` set by
    the dispatcher: a weight-store miss, or — for functions no store
    handles — a code-residency miss): for ordinary functions a disk code
    load, for serving functions the model-weight load + compile term
    priced from the HLO cost models
    (``repro.launch.hlo_analysis.weight_coldstart_estimate``). Zero by
    default, so existing profiles and their RNG draw order are untouched
    (the cross-PR byte-identity contract)."""

    setup_s: float            # marshal+load+transfer+execute_setup+output
    execute_s: float
    jitter_sigma: float = 0.08
    cold_setup_s: float = 0.0  # added when the task is not cached/resident

    def sample(self, rng: np.random.Generator) -> Tuple[float, float]:
        j1 = float(rng.lognormal(0.0, self.jitter_sigma))
        j2 = float(rng.lognormal(0.0, self.jitter_sigma))
        return self.setup_s * j1, self.execute_s * j2


@dataclass(frozen=True)
class TransferProfile:
    """Deterministic per-link model for cross-node data movement.

    When a composition vertex is placed on a different node than one of
    its producers (cross-node scheduling, ``cluster.CrossNodePlacer``),
    the producing node's comm engine is charged one transfer task per
    crossing edge. ``charge(nbytes)`` splits the cost into

      * ``cpu_s`` — protocol/copy CPU that occupies the sender's comm
        slot (cooperative, like HTTP protocol handling);
      * ``io_s`` — wire time (link latency + bytes/bandwidth) during
        which the slot is free for other green tasks.

    Deliberately jitter-free: given the same placements and payload
    bytes, transfer durations are byte-stable run to run (the same
    determinism contract as the modeled comm-protocol CPU)."""

    latency_s: float = 100e-6       # per-message link latency
    bandwidth_bps: float = 1.25e9   # wire rate in bytes/sec (~10 GbE)
    cpu_per_byte_s: float = 1e-10   # sender-side protocol/copy CPU
    min_cpu_s: float = 2e-6         # floor, matches http.MIN_COMM_CPU_S

    def charge(self, nbytes: int) -> Tuple[float, float]:
        """(cpu_s, io_s) for moving ``nbytes`` over this link."""
        cpu_s = self.min_cpu_s + nbytes * self.cpu_per_byte_s
        io_s = self.latency_s + nbytes / self.bandwidth_bps
        return cpu_s, io_s


def profile_from_measurement(
    registry: FunctionRegistry,
    name: str,
    inputs: SetDict,
    backend: str = "dandelion",
    cached: bool = True,
) -> ColdStartProfile:
    bd, exec_s = measure(registry, name, inputs, backend=backend, cached=cached)
    return ColdStartProfile(setup_s=bd.total, execute_s=exec_s)
