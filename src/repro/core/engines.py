"""Compute and communication engines (SS5, SS6.2-6.3).

Engines abstract compute resources. Each engine slot corresponds to a CPU
core; the control plane re-types slots between "compute" and "comm"
(repro.core.controller). Compute slots run exactly one task to completion
(run-to-completion, no interleaving). Comm slots are cooperative: the CPU
cost of protocol handling occupies the slot, while I/O wait does not -
one slot multiplexes up to ``max_inflight`` green tasks.

Service durations: every task actually executes its payload (real outputs
flow through the DAG); *virtual-time* durations come from the task's
calibrated ColdStartProfile when present, else from the real measured
execution. This keeps thousand-RPS sweeps faithful AND deterministic.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.coldstart import ColdStartProfile, cold_start
from repro.core.context import MemoryContext, MemoryTracker
from repro.core.http import SanitizationError, http_function
from repro.core.items import SetDict, sets_bytes
from repro.core.registry import FunctionRegistry
from repro.core.sim import EventLoop

COMPUTE, COMM = "compute", "comm"


@dataclass
class Task:
    kind: str                       # compute | comm
    fn_name: str                    # registry name (compute) / "http" (comm)
    inputs: SetDict
    context_bytes: int = 1 << 20
    profile: Optional[ColdStartProfile] = None  # None -> measure real run
    warm_context: Optional[MemoryContext] = None  # keep-warm platforms
    cached: bool = True             # code in RAM cache?
    timeout_s: float = 60.0
    attempts: int = 0
    cancelled: bool = False
    enqueue_t: float = 0.0
    meta: Dict[str, Any] = field(default_factory=dict)
    on_complete: Optional[Callable[["Task", SetDict, MemoryContext], None]] = None
    on_failed: Optional[Callable[["Task", str], None]] = None


class EngineSlot:
    def __init__(self, node: "EngineSet", slot_id: int, kind: str):
        self.node = node
        self.slot_id = slot_id
        self.kind = kind
        self.busy = False
        self.retype_to: Optional[str] = None
        self.inflight = 0           # comm green tasks in flight
        self.max_inflight = 128

    # ------------------------------------------------------------------
    def maybe_dispatch(self):
        if self.busy:
            return
        if self.retype_to and self.inflight == 0:
            self.kind = self.retype_to
            self.retype_to = None
        q = self.node.queue(self.kind)
        while q and q[0].cancelled:
            q.popleft()
        if not q:
            return
        if self.kind == COMM and self.inflight >= self.max_inflight:
            return
        task = q.popleft()
        self.node.note_queue_delay(self.kind, self.node.loop.now - task.enqueue_t)
        if self.kind == COMPUTE:
            self._serve_compute(task)
        else:
            self._serve_comm(task)

    # ------------------------------------------------------------------
    def _serve_compute(self, task: Task):
        node = self.node
        loop = node.loop
        self.busy = True
        node.inflight_tasks.add(id(task))

        if task.warm_context is not None:
            # keep-warm platforms: sandbox already booted; execute only
            ctx = task.warm_context
            setup_s = 0.0
            outputs, exec_s = node.execute_payload(task, ctx)
        else:
            ctx, bd, run = cold_start(
                node.registry,
                task.fn_name,
                task.inputs,
                backend=node.backend,
                cached=task.cached,
                tracker=node.tracker,
            )
            if task.profile is not None:
                setup_s, exec_s = task.profile.sample(node.rng)
                outputs = run()  # real outputs, modeled duration
            else:
                t0 = time.perf_counter()
                outputs = run()
                exec_s = time.perf_counter() - t0
                setup_s = bd.total

        total = setup_s + exec_s
        timed_out = total > task.timeout_s
        total = min(total, task.timeout_s)
        node.stats_busy(COMPUTE, total)

        def finish():
            self.busy = False
            node.inflight_tasks.discard(id(task))
            if timed_out:
                ctx.free()
                if task.on_failed:
                    task.on_failed(task, "timeout")
            elif task.cancelled:
                ctx.free()
            else:
                for name, items in outputs.items():
                    if name not in ctx.outputs:
                        ctx.write_set(name, items, into="outputs")
                if task.on_complete:
                    task.on_complete(task, outputs, ctx)
            self.maybe_dispatch()
            node.poke()

        loop.after(total, finish)

    # ------------------------------------------------------------------
    def _serve_comm(self, task: Task):
        node = self.node
        loop = node.loop
        self.busy = True
        self.inflight += 1
        node.inflight_tasks.add(id(task))

        t0 = time.perf_counter()
        try:
            outputs, io_s, idempotent = http_function(node.services, task.inputs)
            err = None
        except SanitizationError as e:
            outputs, io_s, idempotent = {}, 0.0, True
            err = f"sanitization: {e}"
        cpu_s = max(time.perf_counter() - t0 - 0.0, 2e-6)
        task.meta["idempotent"] = idempotent
        node.stats_busy(COMM, cpu_s)

        def cpu_done():
            # cooperative: slot is free for the next green task while this
            # one waits on I/O
            self.busy = False
            self.maybe_dispatch()
            node.poke()

        def io_done():
            self.inflight -= 1
            node.inflight_tasks.discard(id(task))
            if task.cancelled:
                pass
            elif err is not None:
                if task.on_failed:
                    task.on_failed(task, err)
            else:
                ctx = MemoryContext(task.context_bytes, tracker=node.tracker)
                for name, items in task.inputs.items():
                    ctx.write_set(name, items)
                for name, items in outputs.items():
                    ctx.write_set(name, items, into="outputs")
                if task.on_complete:
                    task.on_complete(task, outputs, ctx)
            self.maybe_dispatch()
            node.poke()

        loop.after(cpu_s, cpu_done)
        loop.after(cpu_s + io_s, io_done)


class EngineSet:
    """All engine slots of one worker node + the two typed queues."""

    def __init__(
        self,
        loop: EventLoop,
        registry: FunctionRegistry,
        services,
        *,
        num_slots: int = 8,
        comm_slots: int = 1,
        backend: str = "dandelion",
        tracker: Optional[MemoryTracker] = None,
        seed: int = 0,
    ):
        self.loop = loop
        self.registry = registry
        self.services = services
        self.backend = backend
        self.tracker = tracker or MemoryTracker(loop)
        self.rng = np.random.default_rng(seed)
        self.compute_q: deque = deque()
        self.comm_q: deque = deque()
        self.slots: List[EngineSlot] = []
        for i in range(num_slots):
            kind = COMM if i < comm_slots else COMPUTE
            self.slots.append(EngineSlot(self, i, kind))
        self.busy_s = {COMPUTE: 0.0, COMM: 0.0}
        self._arrivals = {COMPUTE: 0, COMM: 0}
        self.inflight_tasks: set = set()
        # EWMA of time tasks sat queued before a slot picked them up - the
        # signal the elastic control plane scales on (Dirigent-style)
        self.queue_delay_ewma = {COMPUTE: 0.0, COMM: 0.0}
        self._qdelay_alpha = 0.2

    # ------------------------------------------------------------------
    def queue(self, kind: str) -> deque:
        return self.compute_q if kind == COMPUTE else self.comm_q

    def submit(self, task: Task):
        task.enqueue_t = self.loop.now
        self.queue(task.kind).append(task)
        self._arrivals[task.kind] += 1
        self.poke()

    def poke(self):
        for s in self.slots:
            s.maybe_dispatch()

    def stats_busy(self, kind: str, seconds: float):
        self.busy_s[kind] += seconds

    def note_queue_delay(self, kind: str, delay_s: float):
        a = self._qdelay_alpha
        self.queue_delay_ewma[kind] = (
            (1 - a) * self.queue_delay_ewma[kind] + a * max(0.0, delay_s)
        )

    # ----------------------------------------------------- controller API
    def counts(self) -> Dict[str, int]:
        return {
            COMPUTE: sum(1 for s in self.slots if s.kind == COMPUTE and not s.retype_to),
            COMM: sum(1 for s in self.slots if s.kind == COMM and not s.retype_to),
        }

    def queue_lengths(self) -> Dict[str, int]:
        return {COMPUTE: len(self.compute_q), COMM: len(self.comm_q)}

    def retype_one(self, frm: str, to: str) -> bool:
        """Move one slot between engine types (finishes current task first)."""
        counts = self.counts()
        if counts[frm] <= 1:
            return False
        for s in self.slots:
            if s.kind == frm and not s.retype_to:
                if s.busy or s.inflight:
                    s.retype_to = to
                else:
                    s.kind = to
                self.poke()
                return True
        return False

    def execute_payload(self, task: Task, ctx: MemoryContext):
        """Warm-start execution (no cold-start phases)."""
        cf = self.registry.get(task.fn_name)
        for name, items in task.inputs.items():
            ctx.write_set(name, items)
        if task.profile is not None:
            _, exec_s = task.profile.sample(self.rng)
            outputs = cf.fn(task.inputs)
        else:
            t0 = time.perf_counter()
            outputs = cf.fn(task.inputs)
            exec_s = time.perf_counter() - t0
        return outputs, exec_s
