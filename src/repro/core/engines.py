"""Compute and communication engines (SS5, SS6.2-6.3).

Engines abstract compute resources. Each engine slot corresponds to a CPU
core; the control plane re-types slots between "compute" and "comm"
(repro.core.controller). Compute slots run exactly one task to completion
(run-to-completion, no interleaving). Comm slots are cooperative: the CPU
cost of protocol handling occupies the slot, while I/O wait does not -
one slot multiplexes up to ``max_inflight`` green tasks.

Service durations: every distinct task body actually executes its payload
(real outputs flow through the DAG); *virtual-time* durations come from
the task's calibrated ColdStartProfile when present, else from the real
measured execution. Profiled tasks take the modeled fast path: payload
execution is content-addressed-memoized (repro.core.registry.PayloadMemo)
and no real disk/compile work runs, keeping full-trace sweeps faithful
AND deterministic AND cheap.

Scheduling is event-driven via per-kind idle free-lists: a submit hands
the task straight to an idle slot of that kind, and a finishing slot pulls
the next queued task directly - no O(slots) rescan per event.

Contract / determinism invariants:

  * FIFO-per-kind: tasks of one kind are served in submission order; the
    free-list heap always hands out the lowest-numbered idle slot — the
    same pairing the pre-PR-2 full scan produced (bit-stable benchmarks);
  * incremental ``counts()`` equals a full slot scan at every instant,
    across retypes (pinned by tests/test_sim_fastpath.py);
  * modeled durations are the only time source on the fast path: comm
    protocol CPU is derived per service, transfer cost per link
    (``coldstart.TransferProfile``) — no ``perf_counter`` on modeled
    paths, so virtual timelines are byte-stable run to run.

Cross-node scheduling adds a third task kind, ``TRANSFER``: a modeled
inter-node byte movement charged to the *sending* node's comm slots.
Like HTTP comm tasks it is cooperative — the protocol/copy CPU occupies
the slot, the wire time does not.

Serving workloads add a fourth kind, ``BATCH`` (continuous batching at
the platform layer): a batch slot models one accelerator/model replica
and coalesces every queued batchable task (up to ``max_batch``) into ONE
modeled step whose duration comes from the node's
``workloads.BatchStepModel`` roofline — the per-step weight read
amortizes over co-resident sequences, so ``step_s(8) << 8 * step_s(1)``.
Each coalesced task still executes its own payload (real token streams
flow through the DAG, identical with batching on or off); only the
virtual duration is shared. Batch slots exist only when
``batch_slots > 0`` and never retype — but unlike CPU slots they can be
*added* (``add_batch_slot``, autoscaler scale-up, optionally committing
a per-replica activation arena) and *retired* with drain-before-retire
(``retire_batch_slot``: a draining replica finishes its in-flight step
and never pulls new work — pinned by tests/test_fleet_serving.py). A
step coalesces tasks of ONE function only (multiplexed models never
share a step) and admits up to ``max_batch`` *units* — a chunked
prefill counts ``task.batch_units`` slots of the step — priced by the
per-function ``batch_models`` entry when present, else the node-level
``batch_model``. All of this collapses to the original single-model
behavior when every task has ``batch_units == 1`` and no per-function
model is registered (the byte-identity contract).
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.coldstart import ColdStartProfile, cold_start
from repro.core.context import MemoryContext, MemoryTracker
from repro.core.http import MIN_COMM_CPU_S, SanitizationError, http_function
from repro.core.items import SetDict
from repro.core.registry import FunctionRegistry
from repro.core.sim import EventLoop

COMPUTE, COMM = "compute", "comm"
TRANSFER = "transfer"   # modeled inter-node byte movement (comm slots)
BATCH = "batch"         # coalesced serving steps (model-replica slots)
RETIRED = "retired"     # a drained batch replica's slot id (never serves)


@dataclass(slots=True)
class Task:
    kind: str                       # compute | comm | transfer
    fn_name: str                    # registry name (compute) / "http" (comm)
    inputs: SetDict
    context_bytes: int = 1 << 20
    profile: Optional[ColdStartProfile] = None  # None -> measure real run
    warm_context: Optional[MemoryContext] = None  # keep-warm platforms
    cached: bool = True             # code in RAM cache?
    # charge the profile's cold_setup_s (non-resident state: a weight-
    # store miss, or — when no store handles the function — a code-
    # residency miss). Kept separate from ``cached`` so a code-cache
    # miss can never bill a weight load the WeightStore says is resident
    cold_setup: bool = False
    # BATCH tasks: units of the coalesced step this task occupies (a
    # chunked prefill spans several; plain decode steps span one)
    batch_units: int = 1
    timeout_s: float = 60.0
    attempts: int = 0
    cancelled: bool = False
    enqueue_t: float = 0.0
    # TRANSFER tasks: precomputed deterministic link charge
    # (TransferProfile.charge on the payload bytes)
    transfer_bytes: int = 0
    transfer_cpu_s: float = 0.0
    transfer_io_s: float = 0.0
    meta: Dict[str, Any] = field(default_factory=dict)
    on_complete: Optional[Callable[["Task", SetDict, MemoryContext], None]] = None
    on_failed: Optional[Callable[["Task", str], None]] = None


def release_task_weights(task: Task) -> None:
    """Balance a ``WeightStore.touch`` made at instance submit. Called on
    the task's single completion/failure callback, or by whoever cancels
    a task whose callbacks will never fire (``WorkerNode.fail``,
    ``Dispatcher.cancel``, the failed-invocation queue flush) — exactly
    once per submitted task (idempotent via the meta pop), so weight
    inflight counts return to zero with the invocations."""
    ws = task.meta.pop("wstore", None)
    if ws is not None:
        ws.task_done(task.fn_name)


class EngineSlot:
    def __init__(self, node: "EngineSet", slot_id: int, kind: str):
        self.node = node
        self.slot_id = slot_id
        self.kind = kind
        self.busy = False
        self.retype_to: Optional[str] = None
        self.inflight = 0           # comm green tasks in flight
        self.max_inflight = 128
        self.in_idle = False        # present (live) in node's idle list
        self.draining = False       # batch replica: retire after this step

    # ------------------------------------------------------------------
    def _serve_compute(self, task: Task):
        node = self.node
        loop = node.loop
        self.busy = True
        node.inflight_tasks.add(id(task))

        if task.warm_context is not None:
            # keep-warm platforms: sandbox already booted; execute only
            ctx = task.warm_context
            setup_s = 0.0
            outputs, exec_s = node.execute_payload(task, ctx)
        elif task.profile is not None:
            # modeled fast path, inlined from cold_start(modeled=True):
            # same context binding, same collapsed bulk commits, same
            # memoized payload execution, same record/draw order — minus
            # the breakdown object and runner closure per task
            reg = node.registry
            cf = reg.functions.get(task.fn_name) or reg.get(task.fn_name)
            ctx = MemoryContext(capacity=cf.context_bytes, tracker=node.tracker)
            ctx.bulk_load(len(cf.code), task.inputs)
            setup_s, exec_s = task.profile.sample(node.rng)
            if task.cold_setup:
                # non-resident state (model weights / code): the
                # deterministic cold term on top of the jittered base
                setup_s += task.profile.cold_setup_s
            memo = reg.memo
            outputs = memo.run(cf, ctx.inputs) if memo is not None else cf.fn(ctx.inputs)
            ctx.write_sets_bulk(outputs, into="outputs")
        else:
            ctx, bd, run = cold_start(
                node.registry,
                task.fn_name,
                task.inputs,
                backend=node.backend,
                cached=task.cached,
                tracker=node.tracker,
            )
            # det-lint: waive[wall-clock] reason=real-exec path; this branch times actual payload execution, not a model
            t0 = time.perf_counter()
            outputs = run()
            # det-lint: waive[wall-clock] reason=real-exec path; this branch times actual payload execution, not a model
            exec_s = time.perf_counter() - t0
            setup_s = bd.total

        total = setup_s + exec_s
        timed_out = total > task.timeout_s
        total = min(total, task.timeout_s)
        node.stats_busy(COMPUTE, total)

        def finish():
            self.busy = False
            node.inflight_tasks.discard(id(task))
            if timed_out:
                ctx.free()
                if task.on_failed:
                    task.on_failed(task, "timeout")
            elif task.cancelled:
                ctx.free()
            else:
                for name, items in outputs.items():
                    if name not in ctx.outputs:
                        ctx.write_set(name, items, into="outputs")
                if task.on_complete:
                    task.on_complete(task, outputs, ctx)
            node.slot_available(self)

        loop.after(total, finish)

    # ------------------------------------------------------------------
    def _serve_comm(self, task: Task):
        node = self.node
        loop = node.loop
        self.busy = True
        self.inflight += 1
        node.inflight_tasks.add(id(task))

        try:
            outputs, io_s, cpu_s, idempotent = http_function(
                node.services, task.inputs
            )
            err = None
        except SanitizationError as e:
            outputs, io_s, cpu_s, idempotent = {}, 0.0, MIN_COMM_CPU_S, True
            err = f"sanitization: {e}"
        task.meta["idempotent"] = idempotent
        node.stats_busy(COMM, cpu_s)

        def cpu_done():
            # cooperative: slot is free for the next green task while this
            # one waits on I/O
            self.busy = False
            node.slot_available(self)

        def io_done():
            self.inflight -= 1
            node.inflight_tasks.discard(id(task))
            if task.cancelled:
                pass
            elif err is not None:
                if task.on_failed:
                    task.on_failed(task, err)
            else:
                ctx = MemoryContext(task.context_bytes, tracker=node.tracker)
                for name, items in task.inputs.items():
                    ctx.write_set(name, items)
                for name, items in outputs.items():
                    ctx.write_set(name, items, into="outputs")
                if task.on_complete:
                    task.on_complete(task, outputs, ctx)
            node.slot_available(self)

        loop.after(cpu_s, cpu_done)
        loop.after(cpu_s + io_s, io_done)

    # ------------------------------------------------------------------
    def _serve_transfer(self, task: Task):
        """Modeled cross-node transfer on the sending node's comm slot:
        protocol/copy CPU occupies the slot, wire time is I/O (the slot
        multiplexes other green tasks meanwhile). Durations were computed
        by the placer from the link's ``TransferProfile`` — deterministic,
        no RNG draw."""
        node = self.node
        loop = node.loop
        self.busy = True
        self.inflight += 1
        node.inflight_tasks.add(id(task))
        cpu_s, io_s = task.transfer_cpu_s, task.transfer_io_s
        node.stats_busy(COMM, cpu_s)

        def cpu_done():
            self.busy = False
            node.slot_available(self)

        def io_done():
            self.inflight -= 1
            node.inflight_tasks.discard(id(task))
            if not task.cancelled and task.on_complete:
                task.on_complete(task, {}, None)
            node.slot_available(self)

        loop.after(cpu_s, cpu_done)
        loop.after(cpu_s + io_s, io_done)

    # ------------------------------------------------------------------
    def _serve_batch(self, tasks: List[Task]):
        """One coalesced serving step over co-resident batchable tasks.

        Every task runs its own cold-start bind + payload (real outputs,
        per-task contexts, per-task setup jitter), but the execute phase
        is shared: ONE roofline step of ``batch_model.step_s(n)`` replaces
        ``n`` independent execute durations. All tasks in the step
        complete at the same virtual instant — iteration-level continuous
        batching, where a new request waits at most one step."""
        node = self.node
        loop = node.loop
        self.busy = True
        served = []
        setup_span = 0.0
        # Vectorized jitter: when every modeled task in the step shares one
        # jitter sigma (the common case — a batch coalesces instances of
        # one function), ONE numpy call draws all 2n factors the scalar
        # path would. Generator.lognormal(size=2n) is draw-for-draw
        # identical to 2n scalar calls including the final generator state
        # (pinned by tests/test_perf_identity.py), so the fast path cannot
        # perturb byte-identity; mixed-sigma steps fall back to per-task
        # sampling in the exact original order.
        n_modeled = 0
        sigma = None
        uniform = True
        for t in tasks:
            if t.profile is not None:
                n_modeled += 1
                if sigma is None:
                    sigma = t.profile.jitter_sigma
                elif t.profile.jitter_sigma != sigma:
                    uniform = False
        draws = (
            node.rng.lognormal(0.0, sigma, 2 * n_modeled)
            if uniform and n_modeled > 1
            else None
        )
        di = 0
        reg = node.registry
        memo = reg.memo
        fns = reg.functions
        tracker = node.tracker
        for task in tasks:
            node.inflight_tasks.add(id(task))
            if task.profile is not None:
                # modeled fast path, inlined from cold_start(modeled=True)
                # — identical binding/commit/draw order (see _serve_compute)
                cf = fns.get(task.fn_name) or reg.get(task.fn_name)
                ctx = MemoryContext(capacity=cf.context_bytes, tracker=tracker)
                ctx.bulk_load(len(cf.code), task.inputs)
                if draws is not None:
                    setup_s = task.profile.setup_s * float(draws[di])
                    di += 2
                else:
                    setup_s, _ = task.profile.sample(node.rng)
                if task.cold_setup:
                    setup_s += task.profile.cold_setup_s
                outputs = memo.run(cf, ctx.inputs) if memo is not None else cf.fn(ctx.inputs)
                ctx.write_sets_bulk(outputs, into="outputs")
            else:
                ctx, bd, run = cold_start(
                    node.registry,
                    task.fn_name,
                    task.inputs,
                    backend=node.backend,
                    cached=task.cached,
                    tracker=node.tracker,
                )
                setup_s = bd.total
                outputs = run()
            served.append((task, ctx, outputs, setup_s))
            if setup_s > setup_span:
                setup_span = setup_s

        units = 0
        for task in tasks:
            units += task.batch_units
        model = node.batch_models.get(tasks[0].fn_name) or node.batch_model
        step_s = model.step_s(units)
        node.batch_inflight_units += units
        total = setup_span + step_s
        node.stats_busy(BATCH, total)

        def finish():
            self.busy = False
            node.batch_inflight_units -= units
            for task, ctx, outputs, setup_s in served:
                node.inflight_tasks.discard(id(task))
                # same timeout contract as the compute path (a task whose
                # own setup + the shared step exceed its budget fails);
                # the callback fires at batch end rather than at the
                # timeout instant — the outcome, not the timing, is what
                # the batching-on/off invariant guarantees
                if setup_s + step_s > task.timeout_s:
                    ctx.free()
                    if task.on_failed:
                        task.on_failed(task, "timeout")
                elif task.cancelled:
                    ctx.free()
                else:
                    for name, items in outputs.items():
                        if name not in ctx.outputs:
                            ctx.write_set(name, items, into="outputs")
                    if task.on_complete:
                        task.on_complete(task, outputs, ctx)
            node.slot_available(self)

        loop.after(total, finish)


class EngineSet:
    """All engine slots of one worker node + the two typed queues.

    Idle-slot scheduling: per-kind free-lists give O(1) submit->slot
    handoff and finish->next-task pull, with incremental slot-kind
    counters for the controller (no per-tick O(slots) scans)."""

    def __init__(
        self,
        loop: EventLoop,
        registry: FunctionRegistry,
        services,
        *,
        num_slots: int = 8,
        comm_slots: int = 1,
        backend: str = "dandelion",
        tracker: Optional[MemoryTracker] = None,
        seed: int = 0,
        batch_slots: int = 0,
        batch_model=None,            # workloads.BatchStepModel (required
                                     # when batch_slots > 0)
        batch_models=None,           # per-fn {fn_name: BatchStepModel}
                                     # overrides for multiplexed models
        max_batch: int = 32,
        replica_bytes: int = 0,      # per-replica activation arena,
                                     # committed while the replica is up
    ):
        self.loop = loop
        self.registry = registry
        self.services = services
        self.backend = backend
        self.tracker = tracker or MemoryTracker(loop)
        self.rng = np.random.default_rng(seed)
        self.compute_q: deque = deque()
        self.comm_q: deque = deque()
        self.batch_q: deque = deque()
        if batch_slots > 0 and batch_model is None and not batch_models:
            raise ValueError("batch slots need a BatchStepModel")
        self.batch_slots = batch_slots
        self.batch_model = batch_model
        self.batch_models: Dict[str, Any] = dict(batch_models or {})
        self.max_batch = max_batch
        self.replica_bytes = replica_bytes
        self.batch_inflight_units = 0   # units inside in-flight steps
        self._batch_draining = 0        # replicas marked, not yet retired
        # liveness hook (set by ReplicaAutoscaler.start): called
        # synchronously when batchable work queues with ZERO active
        # replicas, so the scale-up boot — a non-daemon event — keeps
        # the loop alive instead of stranding the task behind a tick
        # that only fires while something else is scheduled
        self.on_batch_starved: Optional[Callable[[], None]] = None
        self.replicas_added = 0
        self.replicas_retired = 0
        self.slots: List[EngineSlot] = []
        # per-kind idle free-lists: min-heaps of slot ids, so dispatch
        # always picks the lowest-numbered idle slot (the same assignment
        # the old full scan produced, kept for bit-stable benchmarks)
        self._idle: Dict[str, List[int]] = {COMPUTE: [], COMM: [], BATCH: []}
        self._counts: Dict[str, int] = {COMPUTE: 0, COMM: 0, BATCH: 0}
        for i in range(num_slots):
            kind = COMM if i < comm_slots else COMPUTE
            s = EngineSlot(self, i, kind)
            self.slots.append(s)
            self._counts[kind] += 1
            s.in_idle = True
            self._idle[kind].append(i)
        # batch slots (model replicas) come AFTER the CPU slots so the
        # compute/comm slot numbering — and therefore every existing
        # benchmark's slot pairing — is untouched; they never retype
        for i in range(num_slots, num_slots + batch_slots):
            s = EngineSlot(self, i, BATCH)
            self.slots.append(s)
            self._counts[BATCH] += 1
            s.in_idle = True
            self._idle[BATCH].append(i)
        if replica_bytes and batch_slots:
            self.tracker.commit(replica_bytes * batch_slots)
        self.busy_s = {COMPUTE: 0.0, COMM: 0.0, BATCH: 0.0}
        self._arrivals = {COMPUTE: 0, COMM: 0, BATCH: 0}
        self.inflight_tasks: set = set()
        # EWMA of time tasks sat queued before a slot picked them up - the
        # signal the elastic control plane scales on (Dirigent-style)
        self.queue_delay_ewma = {COMPUTE: 0.0, COMM: 0.0, BATCH: 0.0}
        self._qdelay_alpha = 0.2

    # ------------------------------------------------------------------
    def queue(self, kind: str) -> deque:
        """Queue serving ``kind``; TRANSFER shares the comm queue (and
        therefore comm slots and FIFO order with HTTP tasks)."""
        if kind == COMPUTE:
            return self.compute_q
        if kind == BATCH:
            return self.batch_q
        return self.comm_q

    def submit(self, task: Task):
        task.enqueue_t = self.loop.now
        if task.kind == COMPUTE:
            slot_kind = COMPUTE
        elif task.kind == BATCH:
            slot_kind = BATCH
        else:
            slot_kind = COMM
        self.queue(slot_kind).append(task)
        self._arrivals[slot_kind] += 1
        self._dispatch(slot_kind)

    # ----------------------------------------------------- idle-slot core
    def _pop_idle(self, kind: str) -> Optional[EngineSlot]:
        idle = self._idle[kind]
        while idle:
            s = self.slots[heapq.heappop(idle)]
            if not s.in_idle or s.kind != kind or s.busy:
                continue  # stale entry left behind by a slot retype
            s.in_idle = False
            return s
        return None

    def _serve(self, slot: EngineSlot, kind: str, task: Task):
        self.note_queue_delay(kind, self.loop.now - task.enqueue_t)
        if task.kind == COMPUTE:
            slot._serve_compute(task)
        elif task.kind == TRANSFER:
            slot._serve_transfer(task)
        else:
            slot._serve_comm(task)

    def _dispatch(self, kind: str):
        """Pair queued tasks of ``kind`` with idle slots (FIFO on both)."""
        q = self.queue(kind)
        while q:
            if q[0].cancelled:
                q.popleft()
                continue
            slot = self._pop_idle(kind)
            if slot is None:
                if (kind == BATCH and self.on_batch_starved is not None
                        and self.active_batch_slots() == 0):
                    self.on_batch_starved()
                return
            if kind == BATCH:
                self._serve_batch_slot(slot)
            else:
                self._serve(slot, kind, q.popleft())

    def _serve_batch_slot(self, slot: EngineSlot):
        """Coalesce the FIFO prefix of same-function queued tasks (up to
        ``max_batch`` units) into one modeled step on ``slot``. Tasks of
        a different function stay queued for the next step — multiplexed
        models never share one accelerator step. With one model and
        unit tasks this selects exactly the original FIFO prefix."""
        q = self.batch_q
        tasks: List[Task] = []
        units = 0
        key = None
        while q and units < self.max_batch:
            task = q[0]
            if task.cancelled:
                q.popleft()
                continue
            if key is None:
                key = task.fn_name
            elif task.fn_name != key:
                break
            if tasks and units + task.batch_units > self.max_batch:
                break       # next task would overflow the step
            q.popleft()
            self.note_queue_delay(BATCH, self.loop.now - task.enqueue_t)
            tasks.append(task)
            units += task.batch_units
        if not tasks:       # everything queued had been cancelled
            slot.in_idle = True
            heapq.heappush(self._idle[BATCH], slot.slot_id)
            return
        slot._serve_batch(tasks)

    def slot_available(self, slot: EngineSlot):
        """A slot finished (or freed its CPU phase): apply any pending
        retype, then pull the next queued task directly, else go idle."""
        if slot.busy:
            return
        if slot.draining:
            # drain-before-retire: the replica's last step just finished
            # (or it was idle); it leaves the pool without pulling work
            self._finish_retire(slot)
            return
        if slot.retype_to and slot.inflight == 0:
            # the slot may sit in its old kind's free-list (idle comm slot
            # with I/O in flight); logically remove that entry or the
            # in_idle guard below would keep the slot out of the new pool
            slot.in_idle = False
            slot.kind = slot.retype_to
            slot.retype_to = None
            self._counts[slot.kind] += 1
        kind = slot.kind
        if kind == COMM and slot.inflight >= slot.max_inflight:
            return
        q = self.queue(kind)
        while q and q[0].cancelled:
            q.popleft()
        if q and kind == BATCH:
            self._serve_batch_slot(slot)
        elif q:
            self._serve(slot, kind, q.popleft())
        elif not slot.in_idle:
            slot.in_idle = True
            heapq.heappush(self._idle[kind], slot.slot_id)

    def _models_batching(self) -> bool:
        """Whether this node models a batching engine at all. Per-fn
        ``batch_models`` declares elastic capability — it stays True while
        the replica pool is scaled to zero (slot count is load state, not
        capability), so batchable work queues where the autoscaler sees
        it. The legacy single ``batch_model`` only batches while slots
        exist (byte-identity: such nodes historically fell back to the
        COMPUTE engine at ``batch_slots=0``)."""
        return self.batch_slots > 0 or bool(self.batch_models)

    def poke(self):
        """Re-sync queues with idle slots (O(1) when queues are empty)."""
        self._dispatch(COMPUTE)
        self._dispatch(COMM)
        if self.batch_slots:
            self._dispatch(BATCH)

    def stats_busy(self, kind: str, seconds: float):
        self.busy_s[kind] += seconds

    def note_queue_delay(self, kind: str, delay_s: float):
        a = self._qdelay_alpha
        self.queue_delay_ewma[kind] = (
            (1 - a) * self.queue_delay_ewma[kind] + a * max(0.0, delay_s)
        )

    # ----------------------------------------------------- controller API
    def counts(self) -> Dict[str, int]:
        """Slots per kind (excluding retype-pending), maintained
        incrementally - the controller ticks every 30ms. The BATCH entry
        appears only on nodes that model a batching engine, so platforms
        without one keep their pre-serving dict shape."""
        c = {COMPUTE: self._counts[COMPUTE], COMM: self._counts[COMM]}
        if self._models_batching():
            c[BATCH] = self._counts[BATCH]
        return c

    def queue_lengths(self) -> Dict[str, int]:
        q = {COMPUTE: len(self.compute_q), COMM: len(self.comm_q)}
        if self._models_batching():
            q[BATCH] = len(self.batch_q)
        return q

    def retype_one(self, frm: str, to: str) -> bool:
        """Move one slot between engine types (finishes current task first)."""
        if self._counts[frm] <= 1:
            return False
        for s in self.slots:
            if s.kind == frm and not s.retype_to:
                self._counts[frm] -= 1
                if s.busy or s.inflight:
                    s.retype_to = to
                else:
                    # idle slot: logically leave the old free-list (its
                    # entry goes stale), flip kind, join the new pool
                    s.in_idle = False
                    s.kind = to
                    self._counts[to] += 1
                    self.slot_available(s)
                return True
        return False

    # ----------------------------------------------- replica lifecycle
    def add_batch_slot(self) -> int:
        """Bring one more BATCH replica online (autoscaler scale-up).
        The new slot takes the next slot id, so CPU slot numbering —
        and every static benchmark's slot pairing — is untouched. Any
        ``replica_bytes`` activation arena commits while the replica is
        up. Queued batch work dispatches to it immediately."""
        if self.batch_model is None and not self.batch_models:
            raise ValueError("batch replicas need a BatchStepModel")
        i = len(self.slots)
        s = EngineSlot(self, i, BATCH)
        self.slots.append(s)
        self._counts[BATCH] += 1
        self.batch_slots += 1
        self.replicas_added += 1
        if self.replica_bytes:
            self.tracker.commit(self.replica_bytes)
        s.in_idle = True
        heapq.heappush(self._idle[BATCH], i)
        self._dispatch(BATCH)
        return i

    def retire_batch_slot(self) -> bool:
        """Drain-before-retire one BATCH replica. An idle replica leaves
        immediately; a busy one finishes its in-flight coalesced step
        and then leaves — a draining replica never pulls new work.
        Prefers an idle replica, highest slot id first (LIFO, mirroring
        scale-up order). Returns False when no replica is retirable."""
        idle_pick = busy_pick = None
        for s in reversed(self.slots):
            if s.kind != BATCH or s.draining:
                continue
            if not s.busy:
                if idle_pick is None:
                    idle_pick = s
            elif busy_pick is None:
                busy_pick = s
        s = idle_pick or busy_pick
        if s is None:
            return False
        s.draining = True
        self._batch_draining += 1
        if not s.busy:
            self._finish_retire(s)
        return True

    def _finish_retire(self, s: EngineSlot):
        # the slot id stays allocated (stable numbering; stale free-list
        # entries are skipped by the _pop_idle kind check) but the slot
        # can never serve again
        s.draining = False
        s.in_idle = False
        s.kind = RETIRED
        self._batch_draining -= 1
        self._counts[BATCH] -= 1
        self.batch_slots -= 1
        self.replicas_retired += 1
        if self.replica_bytes:
            self.tracker.release(self.replica_bytes)
        # the pool may have drained to zero with work still queued (the
        # retired replica was the last): same liveness kick as _dispatch
        if (self.on_batch_starved is not None
                and self.active_batch_slots() == 0
                and any(not t.cancelled for t in self.batch_q)):
            self.on_batch_starved()

    def active_batch_slots(self) -> int:
        """BATCH replicas that will still pull new work (not draining)."""
        return self._counts[BATCH] - self._batch_draining

    def batch_queued_units(self) -> int:
        """Live units waiting in the batch queue (the autoscaler's and
        router's backlog signal; O(queue), called per tick not per event)."""
        return sum(t.batch_units for t in self.batch_q if not t.cancelled)

    def execute_payload(self, task: Task, ctx: MemoryContext):
        """Warm-start execution (no cold-start phases)."""
        cf = self.registry.get(task.fn_name)
        for name, items in task.inputs.items():
            ctx.write_set(name, items)
        if task.profile is not None:
            _, exec_s = task.profile.sample(self.rng)
            outputs = self.registry.run_payload(task.fn_name, task.inputs)
        else:
            # det-lint: waive[wall-clock] reason=real-exec path; unprofiled payloads run for real and are timed
            t0 = time.perf_counter()
            outputs = cf.fn(task.inputs)
            # det-lint: waive[wall-clock] reason=real-exec path; unprofiled payloads run for real and are timed
            exec_s = time.perf_counter() - t0
        return outputs, exec_s
