"""Cluster manager + cross-node placement + keep-warm baseline platform.

``ClusterManager`` plays Dirigent's role (SS5): it load-balances
composition invocations over Dandelion worker nodes, injects/handles node
failures (pure functions are idempotent, so lost invocations restart on a
surviving node), supports elastic node add/remove, and aggregates memory /
latency accounting.

``CrossNodePlacer`` is the cross-node composition scheduler (default-off,
enabled with ``crossnode=True`` or the ``CROSSNODE=1`` environment knob):
the dispatcher of the routed *home* node exports each ready DAG vertex
back to the cluster layer, which may place it on a different node —
vertex-granular elasticity instead of whole-request pinning. Placement
policy is ``ElasticControlPlane.place_vertex`` (code-cache affinity +
p2c, journaled) when a control plane owns the pool, else a deterministic
warmest-then-least-loaded scan over the static node list. Every edge
whose producer executed on a different node than the consumer is charged
exactly one modeled transfer task (``engines.TRANSFER``) on the
*producing* node's comm engine, sized from the edge payload's item bytes
with latency/bandwidth from the per-link ``coldstart.TransferProfile``;
the in-flight bytes are staged in a ``MemoryContext`` whose ownership
moves from sender to receiver tracker when the wire time elapses.

``KeepWarmPlatform`` is the baseline execution model (Firecracker/
Knative): single-function requests served by a per-function sandbox pool.
Two modes:
  * forced ``hot_ratio`` (the paper's 97%-hot microbenchmark setting);
  * ``autoscale=True``: Knative-style concurrency autoscaler with panic
    window + keep-alive reaping (the Azure-trace experiment).
Sandboxes commit context + guest-OS memory while alive - the
over-provisioning Figures 1/10 quantify.

Contract / determinism invariants:

  * with cross-node placement disabled (the default) no placer is
    attached and the dispatch path is byte-identical to the single-node
    platform — fig10/fig11 outputs do not move;
  * transfer durations are deterministic (``TransferProfile.charge``, no
    jitter), so cross-node runs are byte-stable given seed + workload;
  * staging contexts ride the dispatcher's freed-exactly-once lifecycle
    (they join ``VertexRun.contexts``), including on failure mid-flight
    (pinned by tests/test_crossnode.py);
  * node failure stays whole-invocation: a dying node fails its own
    homed invocations (``WorkerNode.fail``) AND — via
    ``CrossNodePlacer.on_node_failure`` — every live invocation homed
    elsewhere that placed vertices or in-flight transfers on it; the
    cluster restart path re-executes them on survivors. Use
    ``ClusterManager.fail_node_at`` (not ``WorkerNode.fail`` directly)
    in cross-node runs so the placer is notified.
"""
from __future__ import annotations

import itertools
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.coldstart import ColdStartProfile, TransferProfile
from repro.core.context import MemoryContext, MemoryTracker
from repro.core.dag import COMPUTE, Composition
from repro.core.dispatcher import (
    FAIL_CANCELLED, FAIL_NODE, Dispatcher, InvocationRun, VertexRun,
)
from repro.core.engines import TRANSFER, Task
from repro.core.items import SetDict, set_bytes
from repro.core.node import WorkerNode
from repro.core.sim import EventLoop
from repro.core.tracing import LatencyStats, TransferStats


class CrossNodePlacer:
    """Vertex-granular cluster scheduler (the paper's SS4/SS5 elasticity
    claim taken past whole-request granularity).

    Attached to every worker node's dispatcher; ``place`` is called once
    per ready vertex. Compute vertices may be placed on any alive node;
    comm vertices and nested subgraphs stay on the home node (their
    engines multiplex I/O, so moving them buys nothing but transfers).
    Remote placement wires up:

      * the vertex's instances run on the target node's engines and warm
        the *target* node's code cache;
      * one ``TRANSFER`` task per crossing in-edge (and per composition
        input binding feeding a remotely placed root vertex), charged to
        the producing node's comm engine with deterministic durations
        from the link's ``TransferProfile``;
      * a staging ``MemoryContext`` per transfer holding the in-flight
        items: committed on the sender while on the wire, ownership
        transferred to the receiver on arrival, freed through the
        consumer vertex's normal context lifecycle;
      * a remote-input barrier: the vertex launches only when all its
        inbound transfers have landed (``Dispatcher.launch_placed``).
    """

    def __init__(
        self,
        cluster: "ClusterManager",
        *,
        links: Optional[Dict[Tuple[str, str], TransferProfile]] = None,
        default_link: Optional[TransferProfile] = None,
        spread_instances: bool = False,
    ):
        self.cluster = cluster
        self.links = dict(links or {})
        self.default_link = default_link or TransferProfile()
        self.spread_instances = spread_instances
        self.stats = TransferStats()
        self._home: Dict[int, WorkerNode] = {}   # dispatcher id -> node
        self._vload: Dict[int, int] = {}         # node id -> placed vertices
        # node id -> {id(inv): (home dispatcher, inv)} for invocations with
        # vertices or in-flight transfers on that node: a dying node must
        # fail them (their home dispatcher would otherwise wait forever on
        # work the dead node silently dropped)
        self._deps: Dict[int, Dict[int, Tuple[Dispatcher, InvocationRun]]] = {}
        self._deps_prune_at: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def attach(self, node: WorkerNode):
        """Register ``node``'s dispatcher: ready vertices flow back here."""
        self._home[id(node.dispatcher)] = node
        node.dispatcher.placer = self

    def link(self, src_name: str, dst_name: str) -> TransferProfile:
        return self.links.get((src_name, dst_name), self.default_link)

    def vertex_load(self, node: WorkerNode) -> int:
        return self._vload.get(id(node), 0)

    def _depend(self, node: WorkerNode, disp: Dispatcher, inv: InvocationRun):
        d = self._deps.setdefault(id(node), {})
        if id(inv) not in d:
            d[id(inv)] = (disp, inv)
            # geometric compaction: sweep settled invocations only once
            # the dict doubles past the last sweep's live size, so each
            # O(n) scan is paid for by n inserts (amortized O(1) even
            # when every entry is live)
            if len(d) >= self._deps_prune_at.get(id(node), 4096):
                for k in [k for k, (_, i) in d.items() if i.done or i.failed]:
                    del d[k]
                self._deps_prune_at[id(node)] = max(4096, 2 * len(d))

    def on_node_failure(self, node: WorkerNode):
        """``node`` died: fail every live invocation (homed elsewhere)
        that has vertices placed on it or transfers touching it; the
        cluster's restart-on-survivor path re-executes them."""
        for disp, inv in list(self._deps.pop(id(node), {}).values()):
            if not inv.done and not inv.failed:
                disp._fail(inv, "node_failure", kind=FAIL_NODE)

    # ---------------------------------------------------------- policy
    def _pick(self, fn_name: str, home: WorkerNode) -> WorkerNode:
        cp = self.cluster.control_plane
        if cp is not None:
            return cp.place_vertex(fn_name, home, self.vertex_load)
        alive = [n for n in self.cluster._nodes if n.alive]
        if len(alive) <= 1:
            return alive[0] if alive else home

        def key(i_n):
            i, n = i_n
            load = self.cluster._outstanding.get(id(n), 0) + self.vertex_load(n)
            # warmest code cache first, then least loaded; ties keep the
            # vertex home (no transfer charge), then stable node order
            return (-n.warm_fraction((fn_name,)), load, n is not home, i)

        return min(enumerate(alive), key=key)[1]

    # ------------------------------------------------------- placement
    def place(self, disp: Dispatcher, inv: InvocationRun, vr: VertexRun) -> bool:
        """Place one ready vertex. Returns True iff the vertex is waiting
        behind a remote-input barrier (the placer resumes the launch);
        False means the dispatcher proceeds immediately (locally or on
        the target's engines with no inbound transfers)."""
        v = vr.vertex
        home = self._home[id(disp)]
        if v.kind == COMPUTE:
            if (self.spread_instances and vr.tmpl is not None
                    and vr.tmpl.fan_edge is not None):
                # each/key fan-outs spread per *instance* (see spread()):
                # the vertex anchors home so downstream edge accounting
                # sees its merged outputs at the home node — remote
                # instances gather their outputs back explicitly
                target = home
            else:
                target = self._pick(v.function, home)
        else:
            # comm vertices run on the home comm engines and subgraphs
            # unfold on the home dispatcher (their inner vertices get
            # placed individually), but either may still need remote
            # producers' outputs pulled back first (charged below)
            target = home
        vr.exec_node = target
        if target is home:
            self.stats.local_placements += 1
        else:
            self.stats.remote_placements += 1
            self._vload[id(target)] = self._vload.get(id(target), 0) + 1
            self._depend(target, disp, inv)
            vr.exec_engines = target.engines
            vr.exec_code_cache = target.code_cache
            vr.exec_weights = target.weight_store

            def release():
                self._vload[id(target)] -= 1
                cp = self.cluster.control_plane
                if cp is not None and self._vload[id(target)] == 0:
                    cp.on_vertex_complete(target)

            vr.placed_release = release

        # one transfer per data dependency that crosses nodes: in-edges
        # whose producer executed on a different node than this vertex,
        # and composition inputs (they arrived at the home frontend) when
        # the vertex itself moved away from home
        transfers: List[Tuple[WorkerNode, list]] = []
        for e in inv.comp.in_edges(v.name):
            up = inv.vertex_runs[e.src.vertex]
            src = up.exec_node or home
            if src is not target:
                transfers.append((src, up.outputs.get(e.src.set_name, [])))
        if target is not home:
            for in_name, port in inv.comp.input_bindings.items():
                if port.vertex == v.name:
                    transfers.append((home, inv.inputs.get(in_name, [])))
        if not transfers:
            return False
        vr.barrier = len(transfers)
        for src, items in transfers:
            self._charge(disp, inv, vr, src, target, items)
        return True

    def _charge(self, disp: Dispatcher, inv: InvocationRun, vr: VertexRun,
                src: WorkerNode, dst: WorkerNode, items: list):
        nbytes = set_bytes(items)
        cpu_s, io_s = self.link(src.name, dst.name).charge(nbytes)
        self.stats.record_transfer(src.name, dst.name, nbytes, cpu_s, io_s)
        self._depend(src, disp, inv)   # sender death must fail the barrier
        # stage the in-flight bytes on the sender; freed exactly once at
        # the consuming vertex's completion or invocation failure
        stage = MemoryContext(capacity=max(nbytes, 1), tracker=src.tracker)
        if items:
            stage.write_set("payload", items)
        vr.staged.append(stage)

        def arrived(task: Task, outputs, _ctx):
            stage.transfer_ownership(dst.tracker)   # no-op if already freed
            vr.barrier -= 1
            if inv.failed:
                return
            if vr.barrier == 0:
                disp.launch_placed(inv, vr)

        src.engines.submit(Task(
            kind=TRANSFER, fn_name="transfer", inputs={}, context_bytes=0,
            transfer_bytes=nbytes, transfer_cpu_s=cpu_s, transfer_io_s=io_s,
            on_complete=arrived,
        ))

    # ------------------------------------------------ instance spreading
    def spread(self, disp: Dispatcher, inv: InvocationRun, vr: VertexRun):
        """Scatter a fan-out vertex's instances across alive nodes so an
        ``each``/``key`` expansion can saturate the cluster instead of
        landing on one node (scatter-gather semantics: each remote
        instance's inputs are charged as a transfer home->target, its
        outputs as a transfer target->home before the instance counts as
        done, so ``vr.exec_node`` stays home and downstream edges are
        accounted exactly as if the vertex ran locally).

        Picks are deterministic — least placed-load with per-call
        assignment counts, ties prefer home then stable node order — no
        RNG. Retries and hedges of a spread instance resubmit on the
        home node (fallback-to-home). A target's death fails the whole
        invocation through the normal ``_depend`` path."""
        home = self._home[id(disp)]
        cp = self.cluster.control_plane
        if cp is not None:
            alive = cp.active_nodes
        else:
            alive = [n for n in self.cluster._nodes if n.alive]
        if len(alive) <= 1:
            for inst in vr.instances:
                disp._submit_instance(inv, vr, inst)
            return
        assigned: Dict[int, int] = {}
        pending: Dict[int, WorkerNode] = {}    # inst idx -> remote node

        def release_one(idx: int):
            n = pending.pop(idx, None)
            if n is None:
                return
            self._vload[id(n)] -= 1
            if cp is not None and self._vload[id(n)] == 0:
                cp.on_vertex_complete(n)

        def release_all():
            for idx in list(pending):
                release_one(idx)

        vr.placed_release = release_all

        for inst in vr.instances:
            target = min(
                enumerate(alive),
                key=lambda i_n: (
                    self.vertex_load(i_n[1]) + assigned.get(id(i_n[1]), 0),
                    i_n[1] is not home,
                    i_n[0],
                ),
            )[1]
            assigned[id(target)] = assigned.get(id(target), 0) + 1
            if target is home:
                self.stats.local_placements += 1
                disp._submit_instance(inv, vr, inst)
                continue
            self.stats.remote_placements += 1
            self._vload[id(target)] = self._vload.get(id(target), 0) + 1
            pending[inst.idx] = target
            self._depend(target, disp, inv)
            self._scatter(disp, inv, vr, inst, home, target, release_one)

    def _scatter(self, disp: Dispatcher, inv: InvocationRun, vr: VertexRun,
                 inst, home: WorkerNode, target: WorkerNode,
                 release_one: Callable[[int], None]):
        """Move one instance's inputs home->target, then run it there;
        arm the gather-back on completion."""
        items = [it for iset in inst.inputs.values() for it in iset]
        nbytes = set_bytes(items)
        cpu_s, io_s = self.link(home.name, target.name).charge(nbytes)
        self.stats.record_transfer(home.name, target.name, nbytes, cpu_s, io_s)
        stage = MemoryContext(capacity=max(nbytes, 1), tracker=home.tracker)
        if items:
            stage.write_set("payload", items)
        vr.staged.append(stage)

        def arrived(_task: Task, _outputs, _ctx):
            stage.transfer_ownership(target.tracker)
            if inv.failed:
                release_one(inst.idx)
                return
            task = disp._submit_instance(inv, vr, inst, remote=target)
            self._arm_gather(disp, inv, vr, inst, task, home, target,
                             release_one)

        home.engines.submit(Task(
            kind=TRANSFER, fn_name="transfer", inputs={}, context_bytes=0,
            transfer_bytes=nbytes, transfer_cpu_s=cpu_s, transfer_io_s=io_s,
            on_complete=arrived,
        ))

    def _arm_gather(self, disp: Dispatcher, inv: InvocationRun, vr: VertexRun,
                    inst, task: Task, home: WorkerNode, target: WorkerNode,
                    release_one: Callable[[int], None]):
        """Wrap the remote task's callbacks: its outputs travel back to
        the home node (one charged transfer) before the instance counts
        as complete; failures release the placement and take the normal
        retry path (which resubmits at home)."""

        def done(t: Task, outputs, ctx):
            if inv.failed or inst.done:
                # dead invocation / hedge loser: no gather to charge —
                # the normal completion path just frees the context
                release_one(inst.idx)
                disp._on_task_complete(t, outputs, ctx)
                return
            items = [it for iset in outputs.values() for it in iset]
            gbytes = set_bytes(items)
            cpu_s, io_s = self.link(target.name, home.name).charge(gbytes)
            self.stats.record_transfer(target.name, home.name, gbytes,
                                       cpu_s, io_s)
            stage = MemoryContext(capacity=max(gbytes, 1),
                                  tracker=target.tracker)
            if items:
                stage.write_set("payload", items)
            vr.staged.append(stage)

            def landed(_t: Task, _o, _c):
                stage.transfer_ownership(home.tracker)
                release_one(inst.idx)
                disp._on_task_complete(t, outputs, ctx)

            target.engines.submit(Task(
                kind=TRANSFER, fn_name="transfer", inputs={},
                context_bytes=0, transfer_bytes=gbytes,
                transfer_cpu_s=cpu_s, transfer_io_s=io_s,
                on_complete=landed,
            ))

        def failed(t: Task, reason: str):
            release_one(inst.idx)
            disp._on_task_failed(t, reason)

        task.on_complete = done
        task.on_failed = failed


class ClusterManager:
    """Cluster frontend. Routing/scaling either static (least-outstanding
    over a fixed node list) or delegated to an ``ElasticControlPlane``;
    failure-restart semantics (idempotent re-execution on survivors) live
    here in both modes. ``crossnode=True`` (or ``CROSSNODE=1`` in the
    environment) enables vertex-granular cross-node scheduling via
    ``CrossNodePlacer``."""

    def __init__(
        self,
        nodes: Optional[List[WorkerNode]] = None,
        loop: Optional[EventLoop] = None,
        *,
        control_plane=None,   # repro.core.control_plane.ElasticControlPlane
        crossnode: Optional[bool] = None,   # None -> CROSSNODE env knob
        crossnode_spread: Optional[bool] = None,  # None -> CROSSNODE_SPREAD
        transfer_links: Optional[Dict[Tuple[str, str], TransferProfile]] = None,
        transfer_profile: Optional[TransferProfile] = None,
        restart_attempts: int = 3,   # node-death re-executions per request
        route_policy: str = "outstanding",  # "outstanding" | "batch_aware"
        batch_router=None,   # control_plane.BatchRouter override
        distributor=None,    # artifacts.P2PDistributor: P2P prefetch on join
    ):
        if restart_attempts < 0:
            raise ValueError(
                f"restart_attempts must be >= 0, got {restart_attempts}"
            )
        self.restart_attempts = restart_attempts
        self.control_plane = control_plane
        if control_plane is not None:
            if nodes:
                raise ValueError(
                    "pass nodes OR control_plane, not both; the control "
                    "plane owns the pool (use add_node/adopt for extras)"
                )
            self.loop = loop or control_plane.loop
            self._nodes: List[WorkerNode] = []
        else:
            if not nodes:
                raise ValueError("cluster needs at least one node")
            if loop is None:
                raise ValueError("static cluster needs an explicit loop")
            self.loop = loop
            self._nodes = list(nodes)
        self.latency = LatencyStats()
        self.restarts = 0
        self.failed = 0
        self.cancelled = 0
        self._outstanding: Dict[int, int] = {id(n): 0 for n in self._nodes}
        if route_policy not in ("outstanding", "batch_aware"):
            raise ValueError(f"unknown route_policy {route_policy!r}")
        self.batch_router = batch_router
        if route_policy == "batch_aware" and self.batch_router is None:
            from repro.core.control_plane import BatchRouter
            self.batch_router = BatchRouter()
        self.distributor = distributor
        if distributor is not None and self.control_plane is not None \
                and self.control_plane.distributor is None:
            self.control_plane.distributor = distributor
        if crossnode is None:
            crossnode = os.environ.get("CROSSNODE") == "1"
        if crossnode_spread is None:
            crossnode_spread = os.environ.get("CROSSNODE_SPREAD") == "1"
        self.placer: Optional[CrossNodePlacer] = None
        if crossnode:
            self.placer = CrossNodePlacer(
                self, links=transfer_links, default_link=transfer_profile,
                spread_instances=crossnode_spread,
            )
            if self.control_plane is not None:
                self.control_plane.placer = self.placer
                for n in self.control_plane.worker_nodes:
                    self.placer.attach(n)
            else:
                for n in self._nodes:
                    self.placer.attach(n)

    @property
    def nodes(self) -> List[WorkerNode]:
        if self.control_plane is not None:
            return self.control_plane.worker_nodes
        return self._nodes

    # ------------------------------------------------------------ routing
    def _route(self, comp: Composition) -> WorkerNode:
        if self.control_plane is not None:
            return self.control_plane.route(comp)
        alive = [n for n in self._nodes if n.alive]
        if not alive:
            raise RuntimeError("no alive nodes")
        if self.batch_router is not None:
            # marginal-latency routing over batch replicas; compositions
            # with no batchable work fall through to least-outstanding
            picked = self.batch_router.pick(
                alive, comp, alive[0].registry,
                load=lambda n: self._outstanding[id(n)],
            )
            if picked is not None:
                return picked
        return min(alive, key=lambda n: self._outstanding[id(n)])

    def invoke(
        self,
        comp: Composition,
        inputs: SetDict,
        on_done: Optional[Callable[[InvocationRun], None]] = None,
        _attempt: int = 0,
        on_start: Optional[Callable[[InvocationRun], None]] = None,
    ) -> InvocationRun:
        """Route and admit one invocation; returns the live
        ``InvocationRun``. ``on_start`` fires for every admission —
        including node-death re-executions — with the (new) live run, so
        callers holding a handle can track/cancel the current attempt."""
        node = self._route(comp)
        if self.control_plane is not None:
            self.control_plane.on_dispatch(node)
        else:
            self._outstanding[id(node)] += 1
        t_submit = self.loop.now

        def done(inv: InvocationRun):
            if self.control_plane is not None:
                self.control_plane.on_complete(node)
            else:
                self._outstanding[id(node)] -= 1
            # structured failure kind, not a reason-substring match: a
            # user vertex named "node_failure" must not restart, and a
            # cancelled request must never be resurrected
            if (
                inv.failure_kind == FAIL_NODE
                and _attempt < self.restart_attempts
            ):
                # idempotent re-execution on a surviving node (SS6.1)
                self.restarts += 1
                self.invoke(comp, inputs, on_done, _attempt=_attempt + 1,
                            on_start=on_start)
                return
            if inv.failure_kind == FAIL_CANCELLED:
                self.cancelled += 1
            elif inv.failed:
                self.failed += 1
            else:
                self.latency.add(self.loop.now - t_submit)
            if on_done:
                on_done(inv)

        inv = node.invoke(comp, inputs, on_done=done)
        # a synchronously finished run is never the caller's live attempt
        # (a restart's recursive invoke already reported the newer one)
        if on_start is not None and not inv.done and not inv.failed:
            on_start(inv)
        return inv

    def invoke_at(self, t: float, comp: Composition, inputs: SetDict,
                  on_done=None):
        self.loop.at(t, lambda: self.invoke(comp, inputs, on_done))

    def invoke_stream(self, arrivals, on_done=None):
        """Bulk trace injection: time-sorted ``(t, comp, inputs)`` triples
        replayed through one heap cursor (see EventLoop.at_stream)."""
        self.loop.at_stream(
            ((t, (comp, inputs)) for t, comp, inputs in arrivals),
            lambda ci: self.invoke(ci[0], ci[1], on_done),
        )

    # ------------------------------------------------------ elasticity
    def add_node(self, node: WorkerNode):
        if self.control_plane is not None:
            self.control_plane.adopt(node)   # adopt attaches the placer
            return
        self._nodes.append(node)
        self._outstanding[id(node)] = 0
        if self.placer is not None:
            self.placer.attach(node)
        if self.distributor is not None:
            # static pool has no routing-popularity feed: stream the
            # whole catalog to the joiner over the existing warm nodes
            peers = [n for n in self._nodes if n is not node and n.alive]
            self.distributor.on_node_join(node, peers=peers)

    def remove_node(self, node: WorkerNode):
        """Graceful drain: stop routing; node finishes in-flight work."""
        if self.control_plane is not None:
            self.control_plane.drain(node)
            return
        node.alive = False

    def fail_node_at(self, t: float, idx: int):
        def do():
            node = self.nodes[idx]
            node.fail()
            if self.placer is not None:
                self.placer.on_node_failure(node)
            if self.control_plane is not None:
                self.control_plane.on_node_failure(node)

        self.loop.at(t, do)

    def run(self, until: Optional[float] = None):
        self.loop.run(until=until)

    @property
    def committed_avg_bytes(self) -> float:
        if self.control_plane is not None:
            return self.control_plane.committed_avg_bytes()
        return sum(n.committed_avg_bytes for n in self.nodes)


# ===========================================================================
# Keep-warm baseline (Firecracker / Knative)
# ===========================================================================
@dataclass
class Sandbox:
    fn_name: str
    committed_bytes: int
    idle_since: float = 0.0
    busy: bool = False


@dataclass
class _FnState:
    profile: ColdStartProfile          # boot(setup) + execute times
    context_bytes: int
    pool: List[Sandbox] = field(default_factory=list)
    waiting: int = 0
    # autoscaler state
    concurrency: int = 0
    history: List[Tuple[float, int]] = field(default_factory=list)


class KeepWarmPlatform:
    """Single-function baseline with a per-function warm sandbox pool."""

    def __init__(
        self,
        loop: EventLoop,
        *,
        cores: int = 16,
        guest_os_bytes: int = 128 << 20,
        hot_ratio: Optional[float] = None,  # forced ratio; None -> autoscale
        keepalive_s: float = 60.0,
        target_concurrency: float = 1.0,
        reap_interval_s: float = 1.0,
        seed: int = 0,
        name: str = "keepwarm",
    ):
        self.loop = loop
        self.cores = cores
        self.guest_os_bytes = guest_os_bytes
        self.hot_ratio = hot_ratio
        self.keepalive_s = keepalive_s
        self.target_concurrency = target_concurrency
        self.reap_interval_s = reap_interval_s
        self.rng = np.random.default_rng(seed)
        self.name = name
        self.fns: Dict[str, _FnState] = {}
        self.tracker = MemoryTracker(loop)
        self.latency = LatencyStats()
        self.cold_count = 0
        self.warm_count = 0
        self._core_free = cores
        self._runq: List[Tuple[float, Callable[[], None]]] = []
        self._reaper_started = False

    # ------------------------------------------------------------------
    def register(self, fn_name: str, profile: ColdStartProfile,
                 context_bytes: int = 1 << 20):
        self.fns[fn_name] = _FnState(profile=profile, context_bytes=context_bytes)

    def _sandbox_bytes(self, st: _FnState) -> int:
        return st.context_bytes + self.guest_os_bytes

    # ------------------------------------------------------- core model
    def _run_on_core(self, duration: float, done: Callable[[], None]):
        if self._core_free > 0:
            self._core_free -= 1

            def fin():
                self._core_free += 1
                done()
                if self._runq:
                    d, cb = self._runq.pop(0)
                    self._run_on_core(d, cb)

            self.loop.after(duration, fin)
        else:
            self._runq.append((duration, done))

    # ------------------------------------------------------------------
    def request_at(self, t: float, fn_name: str,
                   on_done: Optional[Callable[[float], None]] = None):
        self.loop.at(t, lambda: self._request(fn_name, on_done))

    def request_stream(self, arrivals,
                       on_done: Optional[Callable[[float], None]] = None):
        """Bulk trace injection: time-sorted ``(t, fn_name)`` pairs
        replayed through one heap cursor (see EventLoop.at_stream)."""
        self.loop.at_stream(arrivals, lambda fn_name: self._request(fn_name, on_done))

    def _request(self, fn_name: str, on_done):
        if not self._reaper_started and self.hot_ratio is None:
            self._reaper_started = True
            self.loop.after(self.reap_interval_s, self._reap, daemon=True)
        st = self.fns[fn_name]
        st.concurrency += 1
        t0 = self.loop.now
        idle = next((s for s in st.pool if not s.busy), None)

        forced_cold = (
            self.hot_ratio is not None
            and self.rng.random() >= self.hot_ratio
        )
        if idle is not None and not forced_cold:
            self.warm_count += 1
            self._serve(st, idle, t0, on_done, boot_s=0.0)
        else:
            self.cold_count += 1
            sb = Sandbox(fn_name, self._sandbox_bytes(st))
            st.pool.append(sb)
            self.tracker.commit(sb.committed_bytes)
            boot_s, _ = st.profile.sample(self.rng)
            self._serve(st, sb, t0, on_done, boot_s=boot_s)

    def _serve(self, st: _FnState, sb: Sandbox, t0: float, on_done,
               boot_s: float):
        sb.busy = True
        _, exec_s = st.profile.sample(self.rng)

        def finish():
            sb.busy = False
            sb.idle_since = self.loop.now
            st.concurrency -= 1
            lat = self.loop.now - t0
            self.latency.add(lat)
            if on_done:
                on_done(lat)

        self._run_on_core(boot_s + exec_s, finish)

    # -------------------------------------------------------- autoscaler
    def _reap(self):
        now = self.loop.now
        for st in self.fns.values():
            # Knative-style: desired = ceil(avg concurrency / target);
            # keep-alive grace before reaping idle sandboxes beyond desired
            st.history.append((now, st.concurrency))
            st.history = [(t, c) for t, c in st.history if now - t <= 60.0]
            avg_c = np.mean([c for _, c in st.history]) if st.history else 0.0
            desired = int(np.ceil(avg_c / self.target_concurrency))
            idle = [s for s in st.pool if not s.busy]
            idle.sort(key=lambda s: s.idle_since)
            keep = max(desired - sum(1 for s in st.pool if s.busy), 0)
            for sb in idle[keep:] if len(idle) > keep else []:
                if now - sb.idle_since > self.keepalive_s:
                    st.pool.remove(sb)
                    self.tracker.release(sb.committed_bytes)
        self.loop.after(self.reap_interval_s, self._reap, daemon=True)

    @property
    def committed_avg_bytes(self) -> float:
        return self.tracker.timeline.average(self.loop.now)
