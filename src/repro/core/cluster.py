"""Cluster manager + keep-warm baseline platform.

``ClusterManager`` plays Dirigent's role (SS5): it load-balances
composition invocations over Dandelion worker nodes, injects/handles node
failures (pure functions are idempotent, so lost invocations restart on a
surviving node), supports elastic node add/remove, and aggregates memory /
latency accounting.

``KeepWarmPlatform`` is the baseline execution model (Firecracker/
Knative): single-function requests served by a per-function sandbox pool.
Two modes:
  * forced ``hot_ratio`` (the paper's 97%-hot microbenchmark setting);
  * ``autoscale=True``: Knative-style concurrency autoscaler with panic
    window + keep-alive reaping (the Azure-trace experiment).
Sandboxes commit context + guest-OS memory while alive - the
over-provisioning Figures 1/10 quantify.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.coldstart import ColdStartProfile
from repro.core.context import MemoryTracker
from repro.core.dag import Composition
from repro.core.dispatcher import InvocationRun
from repro.core.items import SetDict
from repro.core.node import WorkerNode
from repro.core.sim import EventLoop
from repro.core.tracing import LatencyStats


class ClusterManager:
    """Cluster frontend. Routing/scaling either static (least-outstanding
    over a fixed node list) or delegated to an ``ElasticControlPlane``;
    failure-restart semantics (idempotent re-execution on survivors) live
    here in both modes."""

    def __init__(
        self,
        nodes: Optional[List[WorkerNode]] = None,
        loop: Optional[EventLoop] = None,
        *,
        control_plane=None,   # repro.core.control_plane.ElasticControlPlane
    ):
        self.control_plane = control_plane
        if control_plane is not None:
            if nodes:
                raise ValueError(
                    "pass nodes OR control_plane, not both; the control "
                    "plane owns the pool (use add_node/adopt for extras)"
                )
            self.loop = loop or control_plane.loop
            self._nodes: List[WorkerNode] = []
        else:
            if not nodes:
                raise ValueError("cluster needs at least one node")
            if loop is None:
                raise ValueError("static cluster needs an explicit loop")
            self.loop = loop
            self._nodes = list(nodes)
        self.latency = LatencyStats()
        self.restarts = 0
        self.failed = 0
        self._outstanding: Dict[int, int] = {id(n): 0 for n in self._nodes}

    @property
    def nodes(self) -> List[WorkerNode]:
        if self.control_plane is not None:
            return self.control_plane.worker_nodes
        return self._nodes

    # ------------------------------------------------------------ routing
    def _route(self, comp: Composition) -> WorkerNode:
        if self.control_plane is not None:
            return self.control_plane.route(comp)
        alive = [n for n in self._nodes if n.alive]
        if not alive:
            raise RuntimeError("no alive nodes")
        return min(alive, key=lambda n: self._outstanding[id(n)])

    def invoke(
        self,
        comp: Composition,
        inputs: SetDict,
        on_done: Optional[Callable[[InvocationRun], None]] = None,
        _attempt: int = 0,
    ) -> None:
        node = self._route(comp)
        if self.control_plane is not None:
            self.control_plane.on_dispatch(node)
        else:
            self._outstanding[id(node)] += 1
        t_submit = self.loop.now

        def done(inv: InvocationRun):
            if self.control_plane is not None:
                self.control_plane.on_complete(node)
            else:
                self._outstanding[id(node)] -= 1
            if inv.failed and "node_failure" in inv.failed and _attempt < 3:
                # idempotent re-execution on a surviving node (SS6.1)
                self.restarts += 1
                self.invoke(comp, inputs, on_done, _attempt=_attempt + 1)
                return
            if inv.failed:
                self.failed += 1
            else:
                self.latency.add(self.loop.now - t_submit)
            if on_done:
                on_done(inv)

        node.invoke(comp, inputs, on_done=done)

    def invoke_at(self, t: float, comp: Composition, inputs: SetDict,
                  on_done=None):
        self.loop.at(t, lambda: self.invoke(comp, inputs, on_done))

    def invoke_stream(self, arrivals, on_done=None):
        """Bulk trace injection: time-sorted ``(t, comp, inputs)`` triples
        replayed through one heap cursor (see EventLoop.at_stream)."""
        self.loop.at_stream(
            ((t, (comp, inputs)) for t, comp, inputs in arrivals),
            lambda ci: self.invoke(ci[0], ci[1], on_done),
        )

    # ------------------------------------------------------ elasticity
    def add_node(self, node: WorkerNode):
        if self.control_plane is not None:
            self.control_plane.adopt(node)
            return
        self._nodes.append(node)
        self._outstanding[id(node)] = 0

    def remove_node(self, node: WorkerNode):
        """Graceful drain: stop routing; node finishes in-flight work."""
        if self.control_plane is not None:
            self.control_plane.drain(node)
            return
        node.alive = False

    def fail_node_at(self, t: float, idx: int):
        def do():
            node = self.nodes[idx]
            node.fail()
            if self.control_plane is not None:
                self.control_plane.on_node_failure(node)

        self.loop.at(t, do)

    def run(self, until: Optional[float] = None):
        self.loop.run(until=until)

    @property
    def committed_avg_bytes(self) -> float:
        if self.control_plane is not None:
            return self.control_plane.committed_avg_bytes()
        return sum(n.committed_avg_bytes for n in self.nodes)


# ===========================================================================
# Keep-warm baseline (Firecracker / Knative)
# ===========================================================================
@dataclass
class Sandbox:
    fn_name: str
    committed_bytes: int
    idle_since: float = 0.0
    busy: bool = False


@dataclass
class _FnState:
    profile: ColdStartProfile          # boot(setup) + execute times
    context_bytes: int
    pool: List[Sandbox] = field(default_factory=list)
    waiting: int = 0
    # autoscaler state
    concurrency: int = 0
    history: List[Tuple[float, int]] = field(default_factory=list)


class KeepWarmPlatform:
    """Single-function baseline with a per-function warm sandbox pool."""

    def __init__(
        self,
        loop: EventLoop,
        *,
        cores: int = 16,
        guest_os_bytes: int = 128 << 20,
        hot_ratio: Optional[float] = None,  # forced ratio; None -> autoscale
        keepalive_s: float = 60.0,
        target_concurrency: float = 1.0,
        reap_interval_s: float = 1.0,
        seed: int = 0,
        name: str = "keepwarm",
    ):
        self.loop = loop
        self.cores = cores
        self.guest_os_bytes = guest_os_bytes
        self.hot_ratio = hot_ratio
        self.keepalive_s = keepalive_s
        self.target_concurrency = target_concurrency
        self.reap_interval_s = reap_interval_s
        self.rng = np.random.default_rng(seed)
        self.name = name
        self.fns: Dict[str, _FnState] = {}
        self.tracker = MemoryTracker(loop)
        self.latency = LatencyStats()
        self.cold_count = 0
        self.warm_count = 0
        self._core_free = cores
        self._runq: List[Tuple[float, Callable[[], None]]] = []
        self._reaper_started = False

    # ------------------------------------------------------------------
    def register(self, fn_name: str, profile: ColdStartProfile,
                 context_bytes: int = 1 << 20):
        self.fns[fn_name] = _FnState(profile=profile, context_bytes=context_bytes)

    def _sandbox_bytes(self, st: _FnState) -> int:
        return st.context_bytes + self.guest_os_bytes

    # ------------------------------------------------------- core model
    def _run_on_core(self, duration: float, done: Callable[[], None]):
        if self._core_free > 0:
            self._core_free -= 1

            def fin():
                self._core_free += 1
                done()
                if self._runq:
                    d, cb = self._runq.pop(0)
                    self._run_on_core(d, cb)

            self.loop.after(duration, fin)
        else:
            self._runq.append((duration, done))

    # ------------------------------------------------------------------
    def request_at(self, t: float, fn_name: str,
                   on_done: Optional[Callable[[float], None]] = None):
        self.loop.at(t, lambda: self._request(fn_name, on_done))

    def request_stream(self, arrivals,
                       on_done: Optional[Callable[[float], None]] = None):
        """Bulk trace injection: time-sorted ``(t, fn_name)`` pairs
        replayed through one heap cursor (see EventLoop.at_stream)."""
        self.loop.at_stream(arrivals, lambda fn_name: self._request(fn_name, on_done))

    def _request(self, fn_name: str, on_done):
        if not self._reaper_started and self.hot_ratio is None:
            self._reaper_started = True
            self.loop.after(self.reap_interval_s, self._reap, daemon=True)
        st = self.fns[fn_name]
        st.concurrency += 1
        t0 = self.loop.now
        idle = next((s for s in st.pool if not s.busy), None)

        forced_cold = (
            self.hot_ratio is not None
            and self.rng.random() >= self.hot_ratio
        )
        if idle is not None and not forced_cold:
            self.warm_count += 1
            self._serve(st, idle, t0, on_done, boot_s=0.0)
        else:
            self.cold_count += 1
            sb = Sandbox(fn_name, self._sandbox_bytes(st))
            st.pool.append(sb)
            self.tracker.commit(sb.committed_bytes)
            boot_s, _ = st.profile.sample(self.rng)
            self._serve(st, sb, t0, on_done, boot_s=boot_s)

    def _serve(self, st: _FnState, sb: Sandbox, t0: float, on_done,
               boot_s: float):
        sb.busy = True
        _, exec_s = st.profile.sample(self.rng)

        def finish():
            sb.busy = False
            sb.idle_since = self.loop.now
            st.concurrency -= 1
            lat = self.loop.now - t0
            self.latency.add(lat)
            if on_done:
                on_done(lat)

        self._run_on_core(boot_s + exec_s, finish)

    # -------------------------------------------------------- autoscaler
    def _reap(self):
        now = self.loop.now
        for st in self.fns.values():
            # Knative-style: desired = ceil(avg concurrency / target);
            # keep-alive grace before reaping idle sandboxes beyond desired
            st.history.append((now, st.concurrency))
            st.history = [(t, c) for t, c in st.history if now - t <= 60.0]
            avg_c = np.mean([c for _, c in st.history]) if st.history else 0.0
            desired = int(np.ceil(avg_c / self.target_concurrency))
            idle = [s for s in st.pool if not s.busy]
            idle.sort(key=lambda s: s.idle_since)
            keep = max(desired - sum(1 for s in st.pool if s.busy), 0)
            for sb in idle[keep:] if len(idle) > keep else []:
                if now - sb.idle_since > self.keepalive_s:
                    st.pool.remove(sb)
                    self.tracker.release(sb.committed_bytes)
        self.loop.after(self.reap_interval_s, self._reap, daemon=True)

    @property
    def committed_avg_bytes(self) -> float:
        return self.tracker.timeline.average(self.loop.now)
