"""Synthetic Azure-Functions-like trace generator.

The real Azure trace is not shipped in this offline container, so we
generate a statistically matched workload following the published
characterization (Shahrad et al., ATC'20 [93]):

  * per-function invocation rates are heavy-tailed (Zipf-like: a few hot
    functions dominate, a long tail is called rarely);
  * execution times are lognormal, median tens of ms;
  * arrivals are bursty: per-function ON/OFF modulation over Poisson
    arrivals;
  * memory requirements: lognormal around ~100-300 MB.

Deterministic given the seed; parameters recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class TraceFunction:
    name: str
    rate_hz: float            # average invocation rate
    exec_median_s: float
    exec_sigma: float
    context_bytes: int
    burst_period_s: float     # ON/OFF cycle length
    burst_duty: float         # fraction of the period that is ON
    burst_phase: float = 0.0  # period fraction offsetting the ON window


@dataclass
class TraceEvent:
    t: float
    fn: str
    exec_s: float


def generate_functions(
    n_functions: int = 100,
    *,
    seed: int = 0,
    total_rate_hz: float = 50.0,
    zipf_s: float = 1.2,
    burst_period_range: Tuple[float, float] = (20.0, 120.0),
    burst_duty_range: Tuple[float, float] = (0.2, 0.9),
    exec_median_s: float = 0.030,
    stagger_bursts: bool = False,
) -> List[TraceFunction]:
    """``burst_duty_range`` shapes elasticity experiments: low duty means
    sharp ON/OFF bursts (Fig.-11-style scale-out), the default wide range
    reproduces the mixed Azure characterization. ``stagger_bursts`` gives
    each function a random ON-window phase so bursts are not synchronized
    at t=0 (defaults off to keep existing experiments bit-stable)."""
    rng = np.random.default_rng(seed)
    weights = 1.0 / np.arange(1, n_functions + 1) ** zipf_s
    weights /= weights.sum()
    rng.shuffle(weights)
    fns = []
    for i in range(n_functions):
        med = float(np.exp(rng.normal(np.log(exec_median_s), 0.8)))
        med = min(max(med, 0.002), 2.0)
        mem = int(np.exp(rng.normal(np.log(150e6), 0.5)))
        mem = min(max(mem, 16 << 20), 1 << 30)
        fns.append(
            TraceFunction(
                name=f"fn{i:03d}",
                rate_hz=float(total_rate_hz * weights[i]),
                exec_median_s=med,
                exec_sigma=0.4,
                context_bytes=mem,
                burst_period_s=float(rng.uniform(*burst_period_range)),
                burst_duty=float(rng.uniform(*burst_duty_range)),
                burst_phase=float(rng.uniform()) if stagger_bursts else 0.0,
            )
        )
    return fns


def generate_events(
    fns: List[TraceFunction],
    duration_s: float,
    *,
    seed: int = 1,
) -> List[TraceEvent]:
    """ON/OFF-modulated Poisson arrivals, vectorized by thinning:
    a homogeneous stream at the ON-phase rate is generated for the whole
    window and arrivals falling in OFF phases are dropped - statistically
    identical to drawing only during ON windows, with no scalar loops."""
    rng = np.random.default_rng(seed)
    events: List[TraceEvent] = []
    for f in fns:
        on_rate = f.rate_hz / max(f.burst_duty, 1e-3)
        n = int(min(on_rate * duration_s * 1.5 + 50, 5_000_000))
        ts = np.cumsum(rng.exponential(1.0 / max(on_rate, 1e-9), size=n))
        phase = (ts / f.burst_period_s + f.burst_phase) % 1.0
        ts = ts[(phase < f.burst_duty) & (ts < duration_s)]
        exec_s = np.exp(
            rng.normal(np.log(f.exec_median_s), f.exec_sigma, size=ts.size)
        )
        events.extend(
            TraceEvent(float(t), f.name, float(e)) for t, e in zip(ts, exec_s)
        )
    events.sort(key=lambda e: e.t)
    return events


def replay(loop, events: List[TraceEvent], fn) -> None:
    """Inject a sorted trace through a single arrival cursor: one heap
    entry outstanding at a time instead of one per future event, so
    full-scale traces cost O(1) heap residency (EventLoop.at_stream)."""
    loop.at_stream(((e.t, e) for e in events), fn)
