"""Platform-layer support for serving-style workloads (SS4 "higher-level
services": AI inference as a composition over the elastic platform).

Two pieces the serving-on-Dandelion workload needs that generic
compositions do not:

``BatchStepModel``
    Roofline-derived duration model for one *coalesced* batch step on a
    node's batching engine (``engines.BATCH``): co-resident decode
    vertices from different requests run as ONE modeled step whose
    duration is ``max(compute, memory) + overhead`` — the fixed
    weight-read term amortizes over the batch, so elastic scale-out
    trades batch efficiency against queueing (the paper's fig-8
    multiplexing story at cluster scale). The terms come from
    ``repro.launch.hlo_analysis`` cost models (or the trace-capture
    calibration in ``repro.serving.trace_capture``); this class keeps
    only plain floats so core stays below the launch/serving layers.

``WeightStore``
    Per-node model-weight residency: the multi-GB weight term that
    FaaSNet-style provisioning identifies as the dominant cold-start
    cost. Weights commit on first touch (the request then pays the
    profile's ``cold_setup_s`` — load from disk + compile) and are
    released once no request holds them and they have sat idle past the
    keep-alive. ``pinned=True`` models a keep-warm replica: committed
    from bind to the end of the run. Inflight refcounts guarantee a
    request never loses its weights between two back-to-back decode
    steps even at ``keepalive_s=0`` (the per-request-cold policy).

    With ``capacity_bytes`` set, the store also models node-RAM
    contention between multiplexed models: a commit that would exceed
    capacity first evicts resident *idle* models (``inflight == 0``,
    never pinned) in least-recently-touched order, registration order
    breaking exact-time ties — the documented deterministic policy the
    multiplexing tests pin. A task that has touched its model holds an
    inflight reference until ``task_done``, so eviction can never take
    weights out from under a queued or running step.

Contract / determinism invariants:

  * ``WeightStore`` commits/releases through the node's
    ``MemoryTracker`` only — committed bytes return to the pre-bind
    level once every request completes and keep-alives expire (the
    freed-exactly-once contract, pinned by
    tests/test_inference_service.py);
  * ``BatchStepModel.step_s`` is pure arithmetic on the batch size: no
    RNG, so batch-step durations are byte-stable run to run;
  * reap events are daemon events on the virtual loop: they never keep
    a simulation alive.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.context import MemoryTracker
from repro.core.sim import EventLoop


@dataclass(frozen=True)
class BatchStepModel:
    """Step time of one coalesced batch of ``n`` co-resident sequences.

    ``compute_s(n) = n * flops_per_seq / peak_flops`` (each sequence adds
    its own matmul work) vs ``memory_s(n) = (fixed_bytes + n *
    bytes_per_seq) / hbm_bw`` (the weight read is paid once per step, KV
    traffic per sequence) — decode is memory-bound at small ``n``, which
    is exactly why continuous batching wins: ``step_s(8) << 8 *
    step_s(1)``."""

    flops_per_seq: float        # FLOPs one sequence adds to the step
    fixed_bytes: float          # HBM bytes read once per step (weights)
    bytes_per_seq: float        # HBM bytes each sequence adds (KV cache)
    peak_flops: float
    hbm_bw: float
    overhead_s: float = 0.0     # per-step dispatch/kernel-launch floor

    def compute_s(self, batch: int) -> float:
        return batch * self.flops_per_seq / self.peak_flops

    def memory_s(self, batch: int) -> float:
        return (self.fixed_bytes + batch * self.bytes_per_seq) / self.hbm_bw

    def step_s(self, batch: int) -> float:
        """Roofline step time for a batch of ``batch`` sequences."""
        if batch <= 0:
            return 0.0
        return max(self.compute_s(batch), self.memory_s(batch)) + self.overhead_s

    def step_s_batch(self, batches) -> np.ndarray:
        """Vectorized ``step_s`` over an array of batch sizes: one numpy
        pass prices N coalesced steps (decode-chain replay, calibration
        sweeps, live telemetry) instead of N Python-level calls. Matches
        ``step_s`` element-for-element (pinned by tests)."""
        n = np.asarray(batches, dtype=np.float64)
        roof = np.maximum(
            n * self.flops_per_seq / self.peak_flops,
            (self.fixed_bytes + n * self.bytes_per_seq) / self.hbm_bw,
        )
        return np.where(n > 0, roof + self.overhead_s, 0.0)

    def amortization(self, batch: int) -> float:
        """Throughput multiplier of batching: ``batch * step_s(1) /
        step_s(batch)`` — the efficiency elastic scale-out trades away
        when it spreads co-resident sequences over more nodes."""
        if batch <= 0:
            return 1.0
        return batch * self.step_s(1) / self.step_s(batch)


@dataclass
class _ModelState:
    param_bytes: int
    resident: bool = False
    inflight: int = 0          # tasks submitted, not yet completed/failed
    idle_since: float = 0.0
    touches: int = 0
    cold_touches: int = 0
    last_touch_t: float = 0.0  # LRU clock for capacity eviction
    evictions: int = 0


class WeightStore:
    """Per-node model-weight residency with keep-alive release.

    Construct once per node, ``register`` each model with the compute
    functions that need it, and hand the store to the node
    (``WorkerNode(weight_store=...)``); the node binds it to its loop
    and memory tracker. The dispatcher then calls ``touch`` at instance
    submit (a miss commits the weights and returns False, so the task's
    ``cold_setup_s`` is charged) and ``task_done`` when the task
    completes, fails, or is cancelled.
    """

    def __init__(
        self,
        *,
        keepalive_s: float = 0.0,
        pinned: bool = False,
        capacity_bytes: Optional[int] = None,
    ):
        self.keepalive_s = keepalive_s
        self.pinned = pinned
        self.capacity_bytes = capacity_bytes
        self.loop: Optional[EventLoop] = None
        self.tracker: Optional[MemoryTracker] = None
        self._models: Dict[str, _ModelState] = {}
        self._by_fn: Dict[str, str] = {}     # fn_name -> model name
        self._reg_order: Dict[str, int] = {}  # model -> registration index
        self.evictions = 0
        self.evicted_bytes = 0
        self.over_capacity = 0   # commits forced past capacity (no victim)
        self.eviction_log: list = []         # (virtual t, model) journal

    # ------------------------------------------------------------------
    def register(self, model: str, param_bytes: int, fn_names) -> None:
        st = self._models.setdefault(model, _ModelState(param_bytes=param_bytes))
        st.param_bytes = param_bytes
        self._reg_order.setdefault(model, len(self._reg_order))
        for fn in fn_names:
            self._by_fn[fn] = model

    def bind(self, loop: EventLoop, tracker: MemoryTracker) -> None:
        """Attach to the owning node. Pinned stores commit every model's
        weights immediately (the keep-warm replica holds them for the
        whole run)."""
        self.loop = loop
        self.tracker = tracker
        if self.pinned:
            for st in self._models.values():
                if not st.resident:
                    st.resident = True
                    tracker.commit(st.param_bytes)

    def handles(self, fn_name: str) -> bool:
        return fn_name in self._by_fn

    def resident(self, model: str) -> bool:
        return self._models[model].resident

    def fn_resident(self, fn_name: str) -> bool:
        """True when ``fn_name``'s model is resident (or the store does
        not handle it) — the router's cold-penalty probe."""
        model = self._by_fn.get(fn_name)
        return True if model is None else self._models[model].resident

    @property
    def resident_bytes(self) -> int:
        return sum(s.param_bytes for s in self._models.values() if s.resident)

    @property
    def inflight(self) -> int:
        """Outstanding touch/task_done imbalance across all models — must
        drain to zero once every invocation completes, fails, or is
        cancelled (the reliability tests' refcount invariant)."""
        return sum(s.inflight for s in self._models.values())

    # ------------------------------------------------------------------
    def touch(self, fn_name: str) -> bool:
        """A task needing ``fn_name``'s model is being submitted. Returns
        True when the weights are already resident (warm start); a miss
        commits them and returns False — the caller charges the
        profile's ``cold_setup_s``."""
        model = self._by_fn.get(fn_name)
        if model is None:
            return True
        st = self._models[model]
        st.inflight += 1
        st.touches += 1
        st.last_touch_t = self.loop.now if self.loop is not None else 0.0
        if st.resident:
            return True
        st.cold_touches += 1
        if self.capacity_bytes is not None:
            self._evict_for(st)
        st.resident = True
        if self.tracker is not None:
            self.tracker.commit(st.param_bytes)
        return self.pinned  # a pinned store never pays the cold term

    def preload(self, model: str) -> bool:
        """Seed ``model`` resident without counting a cold touch.

        Used by P2P artifact prefetch (``core.artifacts``): the weights
        arrived over a modeled transfer that was already priced, so
        residency is committed here exactly once and the next request's
        ``touch`` sees a warm hit — ``cold_setup_s`` is never charged on
        top of the transfer. Honors ``capacity_bytes`` eviction and
        starts the keep-alive idle clock so an unused prefetch is reaped
        like any idle model. Returns True when the model is resident on
        exit (idempotent; False only for an unknown model)."""
        st = self._models.get(model)
        if st is None:
            return False
        now = self.loop.now if self.loop is not None else 0.0
        st.last_touch_t = now
        if st.resident:
            return True
        if self.capacity_bytes is not None:
            self._evict_for(st)
        st.resident = True
        if self.tracker is not None:
            self.tracker.commit(st.param_bytes)
        if not self.pinned and st.inflight == 0:
            st.idle_since = now
            if self.keepalive_s > 0.0 and self.loop is not None:
                self.loop.after(
                    self.keepalive_s, lambda: self._reap(st), daemon=True
                )
        return True

    def _evict_for(self, incoming: _ModelState) -> None:
        """Make room for ``incoming`` under ``capacity_bytes`` by evicting
        resident idle models, least-recently-touched first (registration
        order breaks exact-time ties). Models with inflight tasks are
        never victims — their refcount holds the weights; if no victim
        set suffices, the commit proceeds over capacity (counted)."""
        need = incoming.param_bytes
        resident = self.resident_bytes
        if resident + need <= self.capacity_bytes:
            return
        victims = sorted(
            (name for name, st in self._models.items()
             if st is not incoming and st.resident and st.inflight == 0),
            key=lambda name: (self._models[name].last_touch_t,
                              self._reg_order[name]),
        )
        now = self.loop.now if self.loop is not None else 0.0
        for name in victims:
            if resident + need <= self.capacity_bytes:
                break
            st = self._models[name]
            self._release(st)
            resident -= st.param_bytes
            st.evictions += 1
            self.evictions += 1
            self.evicted_bytes += st.param_bytes
            self.eviction_log.append((now, name))
        if resident + need > self.capacity_bytes:
            self.over_capacity += 1

    def task_done(self, fn_name: str) -> None:
        """Balance a prior ``touch``: the task completed, failed, or was
        cancelled. When the model goes fully idle, schedule the
        keep-alive reap (a daemon event; pinned stores never release)."""
        model = self._by_fn.get(fn_name)
        if model is None:
            return
        st = self._models[model]
        st.inflight -= 1
        if st.inflight > 0 or self.pinned or not st.resident:
            return
        now = self.loop.now if self.loop is not None else 0.0
        st.idle_since = now
        if self.keepalive_s <= 0.0:
            self._release(st)
        elif self.loop is not None:
            self.loop.after(self.keepalive_s, lambda: self._reap(st), daemon=True)

    def _reap(self, st: _ModelState) -> None:
        if (
            st.resident
            and st.inflight == 0
            and not self.pinned
            and self.loop is not None
            and self.loop.now - st.idle_since >= self.keepalive_s - 1e-12
        ):
            self._release(st)

    def _release(self, st: _ModelState) -> None:
        st.resident = False
        if self.tracker is not None:
            self.tracker.release(st.param_bytes)

    # ------------------------------------------------------------ stats
    def summary(self) -> Dict[str, float]:
        touches = sum(s.touches for s in self._models.values())
        colds = sum(s.cold_touches for s in self._models.values())
        return {
            "models": len(self._models),
            "touches": touches,
            "cold_touches": colds,
            "cold_rate": colds / touches if touches else 0.0,
            "evictions": self.evictions,
            "evicted_bytes": self.evicted_bytes,
            "over_capacity": self.over_capacity,
        }
