"""Deterministic discrete-event loop with a virtual clock.

All platform benchmarks run in virtual time: service durations come either
from real measured executions (the cold-start code paths and jitted
compute functions actually run; see repro.core.coldstart) or from seeded
latency models (remote HTTP services). Virtual time makes thousand-RPS
load sweeps reproducible and fast on a single-core container while
preserving true queueing behaviour.

Fast-path notes (the full-scale Azure-trace runs):

  * ``EventLoop.at_stream`` injects a pre-sorted arrival stream through a
    single cursor entry on the heap instead of one heap entry per future
    event, so a million-event trace costs O(1) heap residency.
  * ``Timeline`` keeps O(1) streaming aggregates (time-weighted integral,
    peak, last value) and coalesces equal consecutive values, so
    ``average()``/``peak()`` no longer re-walk unbounded point lists.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Iterable, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        self._now = 0.0
        self._heap: List[Tuple[float, int, bool, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._live = 0  # non-daemon events outstanding

    @property
    def now(self) -> float:
        return self._now

    def at(self, time: float, fn: Callable[[], None], daemon: bool = False) -> None:
        """Schedule ``fn``. Daemon events (periodic controller/reaper ticks)
        do not keep the loop alive: ``run()`` stops once only daemons remain."""
        if time < self._now - 1e-12:
            raise ValueError(f"event in the past: {time} < {self._now}")
        heapq.heappush(self._heap, (time, next(self._seq), daemon, fn))
        if not daemon:
            self._live += 1

    def after(self, delay: float, fn: Callable[[], None], daemon: bool = False) -> None:
        self.at(self._now + max(0.0, delay), fn, daemon=daemon)

    def at_stream(
        self,
        arrivals: Iterable[Tuple[float, object]],
        fn: Callable[[object], None],
        daemon: bool = False,
    ) -> None:
        """Bulk trace injection: replay a time-sorted ``(t, payload)``
        stream by keeping a single cursor event on the heap. Each firing
        calls ``fn(payload)`` and schedules the next arrival, so replaying
        a full production trace does not pre-load one heap entry (plus one
        closure) per future event."""
        it = iter(arrivals)
        pending = next(it, None)
        if pending is None:
            return

        def fire():
            nonlocal pending
            t, payload = pending
            fn(payload)
            pending = next(it, None)
            if pending is not None:
                if pending[0] < t - 1e-12:
                    raise ValueError(
                        f"arrival stream not sorted: {pending[0]} after {t}"
                    )
                self.at(max(pending[0], self._now), fire, daemon=daemon)

        self.at(pending[0], fire, daemon=daemon)

    def step(self) -> bool:
        if not self._heap:
            return False
        t, _, daemon, fn = heapq.heappop(self._heap)
        self._now = t
        if not daemon:
            self._live -= 1
        fn()
        return True

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000):
        n = 0
        while self._heap and n < max_events:
            if until is None and self._live == 0:
                return
            if until is not None and self._heap[0][0] > until:
                self._now = until
                return
            self.step()
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exhausted (livelock?)")

    def empty(self) -> bool:
        return self._live == 0


class Timeline:
    """Step-function series with O(1) streaming aggregates.

    ``record(t, value)`` maintains a running time-weighted integral, peak,
    and last value, so ``average()``/``peak()`` are O(1) instead of
    re-walking an unbounded point list. The point list itself is still
    kept (``keep_points=True``, the default) with equal consecutive values
    coalesced — consumers that need the full step function (``merged_peak``,
    journaling tests) read ``points``; at full trace scale a tracker can
    opt out with ``keep_points=False``.

    ``average(t_end)`` with a historical ``t_end`` (before the last
    recorded point — e.g. a measurement window queried after draining
    stragglers) falls back to an O(n) walk over the retained points; query
    the window before draining, or keep points, to stay on the fast path.
    """

    __slots__ = ("points", "keep_points", "_t0", "_last_t", "_last_v",
                 "_integral", "_peak")

    def __init__(self, keep_points: bool = True):
        self.points: List[Tuple[float, float]] = []
        self.keep_points = keep_points
        self._t0: Optional[float] = None
        self._last_t = 0.0
        self._last_v = 0.0
        self._integral = 0.0
        self._peak = 0.0

    def record(self, t: float, value: float):
        if self._t0 is None:
            self._t0 = t
        else:
            self._integral += self._last_v * (t - self._last_t)
        if self.keep_points and (not self.points or self.points[-1][1] != value):
            self.points.append((t, value))
        self._last_t = t
        self._last_v = value
        if value > self._peak:
            self._peak = value

    # ------------------------------------------------------ aggregates
    @property
    def t0(self) -> Optional[float]:
        return self._t0

    @property
    def last_t(self) -> float:
        return self._last_t

    @property
    def last_value(self) -> float:
        return self._last_v

    def average(self, t_end: Optional[float] = None) -> float:
        """Time-weighted average over [first point, t_end]. Points recorded
        after ``t_end`` are excluded (a run may drain stragglers past the
        measurement window; they must not inflate the window's average)."""
        if self._t0 is None:
            return 0.0
        t_end = t_end if t_end is not None else self._last_t
        if t_end >= self._last_t:
            total = self._integral + self._last_v * (t_end - self._last_t)
        else:
            total = self._scan_integral(t_end)
        span = t_end - self._t0
        return total / span if span > 0 else self._last_v

    def _scan_integral(self, t_end: float) -> float:
        """O(n) reference walk for historical windows (t_end < last_t)."""
        if not self.keep_points:
            raise ValueError(
                "historical average() needs keep_points=True "
                "(or query the window before recording past it)"
            )
        total = 0.0
        pts = self.points
        for (t0, v), (t1, _) in zip(pts, pts[1:]):
            if t0 >= t_end:
                break
            total += v * (min(t1, t_end) - t0)
        else:
            if pts and t_end > pts[-1][0]:
                total += pts[-1][1] * (t_end - pts[-1][0])
        return total

    def peak(self) -> float:
        return self._peak


def merged_peak(timelines: List["Timeline"]) -> float:
    """Exact peak of the sum of several committed-value step functions
    (per-node memory timelines -> cluster-wide peak). Requires the member
    timelines to retain points; an aggregate parent ``MemoryTracker``
    (see repro.core.context) gives the same answer in O(1)."""
    deltas: List[Tuple[float, float]] = []
    for tl in timelines:
        prev = 0.0
        for t, v in tl.points:
            deltas.append((t, v - prev))
            prev = v
    deltas.sort(key=lambda d: d[0])
    cur = peak = 0.0
    for _, d in deltas:
        cur += d
        peak = max(peak, cur)
    return peak
