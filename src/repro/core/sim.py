"""Deterministic discrete-event loop with a virtual clock.

All platform benchmarks run in virtual time: service durations come either
from real measured executions (the cold-start code paths and jitted
compute functions actually run; see repro.core.coldstart) or from seeded
latency models (remote HTTP services). Virtual time makes thousand-RPS
load sweeps reproducible and fast on a single-core container while
preserving true queueing behaviour.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        self._now = 0.0
        self._heap: List[Tuple[float, int, bool, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._live = 0  # non-daemon events outstanding

    @property
    def now(self) -> float:
        return self._now

    def at(self, time: float, fn: Callable[[], None], daemon: bool = False) -> None:
        """Schedule ``fn``. Daemon events (periodic controller/reaper ticks)
        do not keep the loop alive: ``run()`` stops once only daemons remain."""
        if time < self._now - 1e-12:
            raise ValueError(f"event in the past: {time} < {self._now}")
        heapq.heappush(self._heap, (time, next(self._seq), daemon, fn))
        if not daemon:
            self._live += 1

    def after(self, delay: float, fn: Callable[[], None], daemon: bool = False) -> None:
        self.at(self._now + max(0.0, delay), fn, daemon=daemon)

    def step(self) -> bool:
        if not self._heap:
            return False
        t, _, daemon, fn = heapq.heappop(self._heap)
        self._now = t
        if not daemon:
            self._live -= 1
        fn()
        return True

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000):
        n = 0
        while self._heap and n < max_events:
            if until is None and self._live == 0:
                return
            if until is not None and self._heap[0][0] > until:
                self._now = until
                return
            self.step()
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exhausted (livelock?)")

    def empty(self) -> bool:
        return self._live == 0


class Timeline:
    """Append-only (t, value) series with step-function integration."""

    def __init__(self):
        self.points: List[Tuple[float, float]] = []

    def record(self, t: float, value: float):
        self.points.append((t, value))

    def average(self, t_end: Optional[float] = None) -> float:
        """Time-weighted average over [first point, t_end]. Points recorded
        after ``t_end`` are excluded (a run may drain stragglers past the
        measurement window; they must not inflate the window's average)."""
        if not self.points:
            return 0.0
        pts = self.points
        t_end = t_end if t_end is not None else pts[-1][0]
        total = 0.0
        for (t0, v), (t1, _) in zip(pts, pts[1:]):
            if t0 >= t_end:
                break
            total += v * (min(t1, t_end) - t0)
        if t_end > pts[-1][0]:
            total += pts[-1][1] * (t_end - pts[-1][0])
        span = t_end - pts[0][0]
        return total / span if span > 0 else pts[-1][1]

    def peak(self) -> float:
        return max((v for _, v in self.points), default=0.0)


def merged_peak(timelines: List["Timeline"]) -> float:
    """Exact peak of the sum of several committed-value step functions
    (per-node memory timelines -> cluster-wide peak)."""
    deltas: List[Tuple[float, float]] = []
    for tl in timelines:
        prev = 0.0
        for t, v in tl.points:
            deltas.append((t, v - prev))
            prev = v
    deltas.sort(key=lambda d: d[0])
    cur = peak = 0.0
    for _, d in deltas:
        cur += d
        peak = max(peak, cur)
    return peak
