"""Deterministic discrete-event loop with a virtual clock.

All platform benchmarks run in virtual time: service durations come either
from real measured executions (the cold-start code paths and jitted
compute functions actually run; see repro.core.coldstart) or from seeded
latency models (remote HTTP services). Virtual time makes thousand-RPS
load sweeps reproducible and fast on a single-core container while
preserving true queueing behaviour.

Fast-path notes (the full-scale Azure-trace runs):

  * ``EventLoop.at_stream`` injects a pre-sorted arrival stream through a
    single cursor entry on the heap instead of one heap entry per future
    event, so a million-event trace costs O(1) heap residency.
  * ``Timeline`` keeps O(1) streaming aggregates (time-weighted integral,
    peak, last value) and coalesces equal consecutive values, so
    ``average()``/``peak()`` no longer re-walk unbounded point lists.
"""
from __future__ import annotations

import heapq
import itertools
from bisect import bisect_left
from typing import Callable, Iterable, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        self._now = 0.0
        self._heap: List[Tuple[float, int, bool, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._live = 0  # non-daemon events outstanding

    @property
    def now(self) -> float:
        return self._now

    def at(self, time: float, fn: Callable[[], None], daemon: bool = False) -> None:
        """Schedule ``fn``. Daemon events (periodic controller/reaper ticks)
        do not keep the loop alive: ``run()`` stops once only daemons remain."""
        if time < self._now - 1e-12:
            raise ValueError(f"event in the past: {time} < {self._now}")
        heapq.heappush(self._heap, (time, next(self._seq), daemon, fn))
        if not daemon:
            self._live += 1

    def after(self, delay: float, fn: Callable[[], None], daemon: bool = False) -> None:
        self.at(self._now + max(0.0, delay), fn, daemon=daemon)

    def at_stream(
        self,
        arrivals: Iterable[Tuple[float, object]],
        fn: Callable[[object], None],
        daemon: bool = False,
    ) -> None:
        """Bulk trace injection: replay a time-sorted ``(t, payload)``
        stream by keeping a single cursor event on the heap. Each firing
        calls ``fn(payload)`` and schedules the next arrival, so replaying
        a full production trace does not pre-load one heap entry (plus one
        closure) per future event."""
        it = iter(arrivals)
        pending = next(it, None)
        if pending is None:
            return

        def fire():
            nonlocal pending
            t, payload = pending
            fn(payload)
            pending = next(it, None)
            if pending is not None:
                if pending[0] < t - 1e-12:
                    raise ValueError(
                        f"arrival stream not sorted: {pending[0]} after {t}"
                    )
                self.at(max(pending[0], self._now), fire, daemon=daemon)

        self.at(pending[0], fire, daemon=daemon)

    def step(self) -> bool:
        if not self._heap:
            return False
        t, _, daemon, fn = heapq.heappop(self._heap)
        self._now = t
        if not daemon:
            self._live -= 1
        fn()
        return True

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000):
        n = 0
        while self._heap and n < max_events:
            if until is None and self._live == 0:
                return
            if until is not None and self._heap[0][0] > until:
                self._now = until
                return
            self.step()
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exhausted (livelock?)")

    def empty(self) -> bool:
        return self._live == 0


class _LoopShard:
    """One shard (one node) of a ``ShardedEventLoop``: the full
    ``EventLoop`` scheduling surface (``now``/``at``/``after``/
    ``at_stream``) over a private heap, sharing the owner's global
    sequence counter and non-daemon liveness count.

    In exact mode (owner ``lookahead_s == 0``) ``now`` reads the owner's
    global clock, so cross-shard scheduling — a dispatcher submitting a
    ``TRANSFER`` onto another node's comm engine — computes exactly the
    times it would on one merged heap. With lookahead each shard keeps a
    local clock that may run ahead of the global one by at most
    ``lookahead_s``."""

    __slots__ = ("_owner", "name", "_heap", "_local_now")

    def __init__(self, owner: "ShardedEventLoop", name: str):
        self._owner = owner
        self.name = name
        self._heap: List[Tuple[float, int, bool, Callable[[], None]]] = []
        self._local_now = owner._now

    @property
    def now(self) -> float:
        o = self._owner
        return o._now if o.lookahead_s <= 0.0 else self._local_now

    def at(self, time: float, fn: Callable[[], None], daemon: bool = False) -> None:
        if time < self.now - 1e-12:
            raise ValueError(f"event in the past: {time} < {self.now}")
        o = self._owner
        heapq.heappush(self._heap, (time, next(o._seq), daemon, fn))
        if not daemon:
            o._live += 1

    def after(self, delay: float, fn: Callable[[], None], daemon: bool = False) -> None:
        self.at(self.now + max(0.0, delay), fn, daemon=daemon)

    def at_stream(
        self,
        arrivals: Iterable[Tuple[float, object]],
        fn: Callable[[object], None],
        daemon: bool = False,
    ) -> None:
        """Cursor-based trace injection onto this shard; semantics match
        ``EventLoop.at_stream``."""
        it = iter(arrivals)
        pending = next(it, None)
        if pending is None:
            return

        def fire():
            nonlocal pending
            t, payload = pending
            fn(payload)
            pending = next(it, None)
            if pending is not None:
                if pending[0] < t - 1e-12:
                    raise ValueError(
                        f"arrival stream not sorted: {pending[0]} after {t}"
                    )
                self.at(max(pending[0], self.now), fire, daemon=daemon)

        self.at(pending[0], fire, daemon=daemon)

    def _step(self) -> None:
        t, _, daemon, fn = heapq.heappop(self._heap)
        self._local_now = t
        o = self._owner
        if o.lookahead_s <= 0.0:
            o._now = t          # exact mode: one shared clock
        if not daemon:
            o._live -= 1
        fn()


class ShardedEventLoop:
    """Node-sharded event loop: per-shard heaps over one global virtual
    clock and one global sequence counter.

    ``shard(name)`` returns the named shard view (created on first use).
    The loop object itself exposes the plain ``EventLoop`` API — ``at``/
    ``after``/``at_stream`` land on a built-in *control* shard (platform
    arrival streams, cluster routing, control-plane ticks), so it is a
    drop-in replacement wherever an ``EventLoop`` is expected.

    Two execution modes:

    * ``lookahead_s == 0.0`` (default, **exact**): ``run()`` repeatedly
      executes the globally minimal ``(time, seq)`` event across every
      shard heap. The sequence counter is global, so the pop order is
      exactly what a single merged heap would produce — execution is
      byte-identical to ``EventLoop``, event for event, for any workload
      (pinned by tests/test_shard_equivalence.py). The value is
      structural: each node's events live in a small private heap that a
      future parallel driver can own.
    * ``lookahead_s > 0.0`` (**conservative windows**): the shard owning
      the globally minimal event at ``t_min`` drains its own heap up to
      ``t_min + lookahead_s`` before the next global selection. This
      batches per-node work but changes cross-shard interleaving, so it
      is only sound when shards interact exclusively through explicitly
      latency-delayed edges — cross-node ``TRANSFER`` tasks whose wire
      latency is at least ``lookahead_s`` (the classic conservative-
      synchronization lower bound: no event a remote shard schedules can
      land inside another shard's current window). Byte identity is NOT
      part of this mode's contract; the exact default is.
    """

    def __init__(self, lookahead_s: float = 0.0):
        if lookahead_s < 0.0:
            raise ValueError("lookahead_s must be >= 0")
        self._now = 0.0
        self._seq = itertools.count()
        self._live = 0
        self.lookahead_s = lookahead_s
        self._control = _LoopShard(self, "_control")
        self._shards: List[_LoopShard] = [self._control]
        self._by_name: dict = {}

    # ------------------------------------------------------------ shards
    def shard(self, name: str) -> _LoopShard:
        """The shard for ``name`` (one per node), created on first use."""
        s = self._by_name.get(name)
        if s is None:
            s = self._by_name[name] = _LoopShard(self, name)
            self._shards.append(s)
        return s

    @property
    def shards(self) -> List[_LoopShard]:
        return list(self._shards)

    # ----------------------------------------- EventLoop-compatible API
    @property
    def now(self) -> float:
        return self._now

    def at(self, time: float, fn: Callable[[], None], daemon: bool = False) -> None:
        self._control.at(time, fn, daemon=daemon)

    def after(self, delay: float, fn: Callable[[], None], daemon: bool = False) -> None:
        self._control.after(delay, fn, daemon=daemon)

    def at_stream(self, arrivals, fn, daemon: bool = False) -> None:
        self._control.at_stream(arrivals, fn, daemon=daemon)

    def empty(self) -> bool:
        return self._live == 0

    # --------------------------------------------------------- execution
    def _min_shard(self) -> Optional[_LoopShard]:
        best = None
        bh = None
        for s in self._shards:
            h = s._heap
            if h and (bh is None or h[0][0] < bh[0][0]
                      or (h[0][0] == bh[0][0] and h[0][1] < bh[0][1])):
                best, bh = s, h
        return best

    def step(self) -> bool:
        """One globally minimal event (exact order), regardless of mode."""
        best = self._min_shard()
        if best is None:
            return False
        t = best._heap[0][0]
        if t > self._now:
            self._now = t
        best._step()
        return True

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000):
        n = 0
        la = self.lookahead_s
        while n < max_events:
            best = self._min_shard()
            if best is None:
                break                     # every heap drained
            if until is None and self._live == 0:
                return                    # only daemons remain
            t_min = best._heap[0][0]
            if until is not None and t_min > until:
                self._advance_to(until)
                return
            if la <= 0.0:
                best._step()
                n += 1
            else:
                horizon = t_min + la
                if until is not None and horizon > until:
                    horizon = until
                if t_min > self._now:
                    self._now = t_min     # committed global time
                h = best._heap
                while h and h[0][0] <= horizon and n < max_events:
                    best._step()
                    n += 1
                    if until is None and self._live == 0:
                        return
        if n >= max_events:
            raise RuntimeError("event budget exhausted (livelock?)")

    def _advance_to(self, t: float) -> None:
        self._now = t
        for s in self._shards:
            if s._local_now < t:
                s._local_now = t


class Timeline:
    """Step-function series with O(1) streaming aggregates.

    ``record(t, value)`` maintains a running time-weighted integral, peak,
    and last value, so ``average()``/``peak()`` are O(1) instead of
    re-walking an unbounded point list. The point list itself is still
    kept (``keep_points=True``, the default) with equal consecutive values
    coalesced — consumers that need the full step function (``merged_peak``,
    journaling tests) read ``points``; at full trace scale a tracker can
    opt out with ``keep_points=False``.

    ``average(t_end)`` with a historical ``t_end`` (before the last
    recorded point — e.g. a measurement window queried after draining
    stragglers) stays fast too: ``record`` maintains a per-point cumulative
    integral (``_cum``) with the same left-to-right arithmetic as the O(n)
    reference walk, so historical queries are an O(log n) bisect that
    returns the bit-identical total. ``_scan_integral`` is retained as the
    brute-force reference (pinned by tests/test_timeline_average.py).
    """

    __slots__ = ("points", "keep_points", "_t0", "_last_t", "_last_v",
                 "_integral", "_peak", "_cum")

    def __init__(self, keep_points: bool = True):
        self.points: List[Tuple[float, float]] = []
        self.keep_points = keep_points
        self._t0: Optional[float] = None
        self._last_t = 0.0
        self._last_v = 0.0
        self._integral = 0.0
        self._peak = 0.0
        # _cum[i] = integral of the step function from points[0][0] to
        # points[i][0], accumulated over the *coalesced* segments exactly
        # like _scan_integral does (term order matters for float identity)
        self._cum: List[float] = []

    def record(self, t: float, value: float):
        if self._t0 is None:
            self._t0 = t
        else:
            self._integral += self._last_v * (t - self._last_t)
        if self.keep_points and (not self.points or self.points[-1][1] != value):
            if self.points:
                pt, pv = self.points[-1]
                self._cum.append(self._cum[-1] + pv * (t - pt))
            else:
                self._cum.append(0.0)
            self.points.append((t, value))
        self._last_t = t
        self._last_v = value
        if value > self._peak:
            self._peak = value

    # ------------------------------------------------------ aggregates
    @property
    def t0(self) -> Optional[float]:
        return self._t0

    @property
    def last_t(self) -> float:
        return self._last_t

    @property
    def last_value(self) -> float:
        return self._last_v

    def average(self, t_end: Optional[float] = None) -> float:
        """Time-weighted average over [first point, t_end]. Points recorded
        after ``t_end`` are excluded (a run may drain stragglers past the
        measurement window; they must not inflate the window's average)."""
        if self._t0 is None:
            return 0.0
        t_end = t_end if t_end is not None else self._last_t
        if t_end >= self._last_t:
            total = self._integral + self._last_v * (t_end - self._last_t)
        else:
            total = self._integral_until(t_end)
        span = t_end - self._t0
        return total / span if span > 0 else self._last_v

    def _integral_until(self, t_end: float) -> float:
        """Integral over [points[0][0], t_end] for a historical window
        (t_end < last_t): O(log n) bisect into the streaming per-point
        cumulative integral. Bit-identical to ``_scan_integral`` because
        ``_cum`` is accumulated with the same term order at record time."""
        if not self.keep_points:
            raise ValueError(
                "historical average() needs keep_points=True "
                "(or query the window before recording past it)"
            )
        # first retained point with t >= t_end; a bare (t_end,) tuple
        # compares below any (t_end, v) point, so ties resolve leftward
        i = bisect_left(self.points, (t_end,))
        if i == 0:
            return 0.0
        pt, pv = self.points[i - 1]
        return self._cum[i - 1] + pv * (t_end - pt)

    def _scan_integral(self, t_end: float) -> float:
        """O(n) reference walk for historical windows (t_end < last_t)."""
        if not self.keep_points:
            raise ValueError(
                "historical average() needs keep_points=True "
                "(or query the window before recording past it)"
            )
        total = 0.0
        pts = self.points
        for (t0, v), (t1, _) in zip(pts, pts[1:]):
            if t0 >= t_end:
                break
            total += v * (min(t1, t_end) - t0)
        else:
            if pts and t_end > pts[-1][0]:
                total += pts[-1][1] * (t_end - pts[-1][0])
        return total

    def peak(self) -> float:
        return self._peak


def merged_peak(timelines: List["Timeline"]) -> float:
    """Exact peak of the sum of several committed-value step functions
    (per-node memory timelines -> cluster-wide peak). Requires the member
    timelines to retain points; an aggregate parent ``MemoryTracker``
    (see repro.core.context) gives the same answer in O(1)."""
    deltas: List[Tuple[float, float]] = []
    for tl in timelines:
        prev = 0.0
        for t, v in tl.points:
            deltas.append((t, v - prev))
            prev = v
    deltas.sort(key=lambda d: d[0])
    cur = peak = 0.0
    for _, d in deltas:
        cur += d
        peak = max(peak, cur)
    return peak
