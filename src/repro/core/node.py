"""Worker node: engines + dispatcher + PI controller + memory accounting.

One ``WorkerNode`` is the unit Figure 4 draws: HTTP frontend (the
``invoke`` entry point), dispatcher, typed engine queues, engine slots,
and the per-node PI slot controller, all over one (usually shared)
virtual-time event loop.

Contract / determinism invariants:

  * all state a node owns hangs off its ``MemoryTracker`` — committed
    bytes return to zero once every admitted invocation completes or
    fails (freed-exactly-once, see dispatcher);
  * per-node RNG is seeded at construction; identical seed + workload =>
    identical timelines (the cross-PR byte-identity contract);
  * under cross-node scheduling this node's engines may also serve
    vertices *placed here* by another node's dispatcher, and its comm
    slots may carry outbound ``TRANSFER`` tasks; both are accounted on
    this node's tracker/busy counters, while invocation bookkeeping
    stays with the home node that admitted the request;
  * ``fail()`` kills queued + in-flight work and fails this node's own
    live invocations with "node_failure" (the cluster re-executes them
    on survivors). Invocations homed elsewhere with vertices placed here
    are rescued one layer up: ``ClusterManager.fail_node_at`` notifies
    ``CrossNodePlacer.on_node_failure``, which fails them for the same
    restart path — so in cross-node runs, inject failures through the
    cluster manager, not by calling ``fail()`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

from repro.core.coldstart import CodeCache, ColdStartProfile
from repro.core.context import MemoryTracker
from repro.core.controller import PIController
from repro.core.dag import Composition, RetryPolicy
from repro.core.dispatcher import (
    FAIL_NODE, Dispatcher, InvocationRun, release_task_weights,
)
from repro.core.engines import EngineSet, Task
from repro.core.http import ServiceRegistry
from repro.core.items import SetDict
from repro.core.registry import FunctionRegistry
from repro.core.sim import EventLoop
from repro.core.tracing import LatencyStats


class WorkerNode:
    def __init__(
        self,
        registry: FunctionRegistry,
        services: Optional[ServiceRegistry] = None,
        *,
        loop: Optional[EventLoop] = None,
        num_slots: int = 16,
        comm_slots: int = 1,
        backend: str = "dandelion",
        profiles: Optional[Dict[str, ColdStartProfile]] = None,
        controller_enabled: bool = True,
        controller_interval_s: float = 0.030,
        max_retries: int = 2,
        retry_policy: Optional[RetryPolicy] = None,  # node-wide default
        hedge_after_s: float = 0.0,
        cache_miss_rate: float = 0.0,
        code_cache_entries: int = 0,   # >0 -> model per-node code residency
        base_bytes: int = 0,           # node runtime/OS footprint while up
        batch_slots: int = 0,          # >0 -> model a batching engine
        batch_model=None,              # workloads.BatchStepModel
        batch_models=None,             # per-fn {fn_name: BatchStepModel};
                                       # declares elastic batch capability
        max_batch: int = 32,
        replica_bytes: int = 0,        # RAM arena committed per replica
        weight_store=None,             # workloads.WeightStore (unbound)
        seed: int = 0,
        name: str = "node0",
    ):
        self.name = name
        self.loop = loop or EventLoop()
        self.registry = registry
        self.services = services or ServiceRegistry()
        self.tracker = MemoryTracker(self.loop)
        self.engines = EngineSet(
            self.loop,
            registry,
            self.services,
            num_slots=num_slots,
            comm_slots=comm_slots,
            backend=backend,
            tracker=self.tracker,
            seed=seed,
            batch_slots=batch_slots,
            batch_model=batch_model,
            batch_models=batch_models,
            max_batch=max_batch,
            replica_bytes=replica_bytes,
        )
        self.controller = PIController(
            self.engines,
            self.loop,
            interval_s=controller_interval_s,
            enabled=controller_enabled,
        )
        self.code_cache: Optional[CodeCache] = (
            CodeCache(code_cache_entries) if code_cache_entries > 0 else None
        )
        self.weight_store = weight_store
        if weight_store is not None:
            weight_store.bind(self.loop, self.tracker)
        self.dispatcher = Dispatcher(
            self.loop,
            self.engines,
            registry,
            profiles=profiles,
            max_retries=max_retries,
            default_retry=retry_policy,
            hedge_after_s=hedge_after_s,
            cache_miss_rate=cache_miss_rate,
            code_cache=self.code_cache,
            weights=weight_store,
        )
        self.num_slots = num_slots
        self.base_bytes = base_bytes
        self.latency = LatencyStats()
        self.failed_count = 0
        self.alive = True

    # -------------------------------------------------------- frontend
    def invoke(
        self,
        comp: Composition,
        inputs: SetDict,
        on_done: Optional[Callable[[InvocationRun], None]] = None,
    ) -> InvocationRun:
        """HTTP-frontend entry: schedule a composition invocation now."""
        self.controller.start()

        def done(inv: InvocationRun):
            if inv.failed:
                self.failed_count += 1
            else:
                self.latency.add(inv.latency)
            if on_done:
                on_done(inv)

        return self.dispatcher.invoke(comp, inputs, on_done=done)

    def invoke_at(
        self,
        t: float,
        comp: Composition,
        inputs: SetDict,
        on_done: Optional[Callable[[InvocationRun], None]] = None,
    ):
        self.loop.at(t, lambda: self.invoke(comp, inputs, on_done))

    def invoke_stream(self, arrivals, on_done=None):
        """Bulk trace injection: ``arrivals`` is a time-sorted iterable of
        ``(t, composition, inputs)``; replayed through a single heap
        cursor (EventLoop.at_stream) instead of one entry per event."""
        self.loop.at_stream(
            ((t, (comp, inputs)) for t, comp, inputs in arrivals),
            lambda ci: self.invoke(ci[0], ci[1], on_done),
        )

    def run(self, until: Optional[float] = None):
        self.loop.run(until=until)

    # -------------------------------------------------- fault injection
    def fail(self):
        """Node dies: every queued and in-flight task is lost, and every
        live invocation fails with "node_failure" (the cluster manager
        re-executes them on survivors - pure functions are idempotent)."""
        self.alive = False
        for q in (self.engines.compute_q, self.engines.comm_q,
                  self.engines.batch_q):
            for task in q:
                task.cancelled = True
                release_task_weights(task)  # no callback will ever fire
            q.clear()
        # in-flight tasks: their completion events will observe done flags
        for inv in list(self.dispatcher.active.values()):
            for vr in inv.vertex_runs.values():
                for inst in vr.instances:
                    inst.done = True  # suppress straggling completions
            self.dispatcher._fail(inv, "node_failure", kind=FAIL_NODE)

    # ------------------------------------------------- control-plane API
    @property
    def outstanding(self) -> int:
        """Invocations admitted to this node but not yet finished."""
        return self.dispatcher.outstanding

    def queue_delay_s(self) -> float:
        return self.dispatcher.queue_delay_s()

    def warm_fraction(self, fn_names) -> float:
        """Fraction of ``fn_names`` resident in this node's RAM code cache
        (1.0 when residency is not modeled: a shared-registry node is
        always as warm as the global RAM cache)."""
        if self.code_cache is None:
            return 1.0
        return self.code_cache.warm_fraction(fn_names)

    @property
    def committed_avg_bytes(self) -> float:
        return self.tracker.timeline.average(self.loop.now)

    @property
    def committed_peak_bytes(self) -> float:
        return self.tracker.timeline.peak()
