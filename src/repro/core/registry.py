"""Function registry: compute-function binaries, code cache, compositions.

Compute functions are registered as python callables ``fn(inputs: SetDict)
-> SetDict`` plus an optional jax payload (``jax_fn`` + abstract args) that
the snapshot/microvm cold-start backends AOT-compile/serialize (the real
code paths those backends time - see repro.core.coldstart).

The registry models Dandelion's two-level code store: binaries live on
disk (pickle files) and may be cached in RAM. ``load_code(cached=False)``
does a real disk read + unpickle; ``cached=True`` a memcpy - the "load
from disk" row of Table 1.
"""
from __future__ import annotations

import os
import pickle
import tempfile
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.dag import Composition
from repro.core.items import SetDict


@dataclass
class ComputeFunction:
    name: str
    fn: Callable[[SetDict], SetDict]
    context_bytes: int = 1 << 20
    # optional jax payload for AOT cold-start backends
    jax_fn: Optional[Callable] = None
    abstract_args: Tuple[Any, ...] = ()
    # modeled execution time; None -> execute for real and measure
    service_time_s: Optional[float] = None
    idempotent: bool = True  # pure compute functions always are (SS6.1)
    disk_path: str = ""
    code: bytes = b""


class FunctionRegistry:
    def __init__(self, code_dir: Optional[str] = None):
        self.code_dir = code_dir or tempfile.mkdtemp(prefix="dandelion_code_")
        self.functions: Dict[str, ComputeFunction] = {}
        self.compositions: Dict[str, Composition] = {}
        self._ram_cache: Dict[str, bytes] = {}

    # ------------------------------------------------------- functions
    def register_function(
        self,
        name: str,
        fn: Callable[[SetDict], SetDict],
        *,
        context_bytes: int = 1 << 20,
        jax_fn: Optional[Callable] = None,
        abstract_args: Tuple[Any, ...] = (),
        service_time_s: Optional[float] = None,
    ) -> ComputeFunction:
        try:
            code = pickle.dumps(fn)
        except Exception:
            # closures/jitted callables aren't picklable; store a stub of
            # representative size (the bytes still flow through the real
            # disk/cache code paths).
            code = pickle.dumps(name.encode() * 64)
        path = os.path.join(self.code_dir, f"{name}.bin")
        with open(path, "wb") as f:
            f.write(code)
        cf = ComputeFunction(
            name=name,
            fn=fn,
            context_bytes=context_bytes,
            jax_fn=jax_fn,
            abstract_args=abstract_args,
            service_time_s=service_time_s,
            disk_path=path,
            code=code,
        )
        self.functions[name] = cf
        return cf

    def get(self, name: str) -> ComputeFunction:
        if name not in self.functions:
            raise KeyError(f"unregistered compute function {name!r}")
        return self.functions[name]

    def load_code(self, name: str, cached: bool) -> bytes:
        """Real code-load path: RAM cache memcpy or disk read + unpickle."""
        cf = self.get(name)
        if cached and name in self._ram_cache:
            return bytes(self._ram_cache[name])  # copy, like a memcpy
        with open(cf.disk_path, "rb") as f:
            raw = f.read()
        try:
            pickle.loads(raw)
        except Exception:
            pass
        self._ram_cache[name] = raw
        return raw

    def evict(self, name: str) -> None:
        self._ram_cache.pop(name, None)

    # ---------------------------------------------------- compositions
    def register_composition(self, comp: Composition) -> Composition:
        comp.validate()
        self.compositions[comp.name] = comp
        return comp

    def get_composition(self, name: str) -> Composition:
        if name not in self.compositions:
            raise KeyError(f"unregistered composition {name!r}")
        return self.compositions[name]
