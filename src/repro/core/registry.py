"""Function registry: compute-function binaries, code cache, compositions.

Compute functions are registered as python callables ``fn(inputs: SetDict)
-> SetDict`` plus an optional jax payload (``jax_fn`` + abstract args) that
the snapshot/microvm cold-start backends AOT-compile/serialize (the real
code paths those backends time - see repro.core.coldstart).

The registry models Dandelion's two-level code store: binaries live on
disk (pickle files) and may be cached in RAM. ``load_code(cached=False)``
does a real disk read + unpickle; ``cached=True`` a memcpy - the "load
from disk" row of Table 1.
"""
from __future__ import annotations

import os
import pickle
import tempfile
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.dag import (COMPUTE, SUBGRAPH, Composition,
                            fire_registration_hooks)
from repro.core.items import SetDict, fingerprint_sets


@dataclass
class ComputeFunction:
    name: str
    fn: Callable[[SetDict], SetDict]
    context_bytes: int = 1 << 20
    # optional jax payload for AOT cold-start backends
    jax_fn: Optional[Callable] = None
    abstract_args: Tuple[Any, ...] = ()
    # modeled execution time; None -> execute for real and measure
    service_time_s: Optional[float] = None
    idempotent: bool = True  # pure compute functions always are (SS6.1)
    memoize: bool = True     # pure fn: repeated inputs may reuse outputs
    # instances of this function may be coalesced with co-resident
    # instances into one modeled step on a node's batching engine
    # (continuous batching for serving decode steps; see
    # repro.core.workloads.BatchStepModel). Platforms without batch
    # slots run batchable functions as ordinary compute tasks.
    batchable: bool = False
    disk_path: str = ""
    code: bytes = b""
    # declared purity opt-out (sdk.function(pure_unsafe=True)): the
    # analysis pass records it in the PurityReport instead of blocking
    pure_unsafe: bool = False


class PayloadMemo:
    """Content-addressed payload-execution cache (simulator fast path).

    Dandelion functions are pure (SS6.1): the same function body over the
    same input sets always produces the same output sets. When a task's
    *duration* comes from a calibrated ``ColdStartProfile`` (modeled
    virtual time), re-executing the real payload for every repeated trace
    event buys nothing — so each distinct ``(fn_name, input digest)``
    body runs once and later invocations reuse the outputs. Items are
    immutable, so sharing them is safe; output set lists are shallow-copied
    on both store and hit so callers can never mutate the cached entry.
    DAG dataflow stays byte-identical with the cache on or off (pinned by
    tests/test_sim_fastpath.py).

    Adaptive fingerprint bypass: hashing inputs is pure overhead for a
    function whose inputs never repeat (e.g. unique prompts in a serving
    trace), so after ``bypass_after`` consecutive misses with zero hits
    ever, the memo stops fingerprinting that function and executes its
    payload directly (counted in ``skips``). The rule is a deterministic
    function of the invocation history, and because payloads are pure
    and durations are modeled, skipping the cache never changes dataflow
    values or virtual timing — only the counters. One hit disables the
    bypass for that function permanently.
    """

    def __init__(self, capacity_entries: int = 65536, *,
                 bypass_after: int = 64):
        self.capacity_entries = capacity_entries
        self.bypass_after = bypass_after
        self._cache: "OrderedDict[Tuple[str, str], SetDict]" = OrderedDict()
        # per-function [hits, consecutive misses] for the adaptive bypass
        self._fn_stats: Dict[str, list] = {}
        self.hits = 0
        self.misses = 0
        self.skips = 0   # unfingerprintable inputs, memoize=False fns,
                         # or adaptive bypass

    def run(self, cf: ComputeFunction, inputs: SetDict) -> SetDict:
        """Execute ``cf`` over ``inputs`` through the cache."""
        if not cf.memoize:
            self.skips += 1
            return cf.fn(inputs)
        st = self._fn_stats.get(cf.name)
        if st is None:
            st = [0, 0]
            self._fn_stats[cf.name] = st
        elif st[0] == 0 and st[1] >= self.bypass_after:
            self.skips += 1
            return cf.fn(inputs)
        fp = fingerprint_sets(inputs)
        if fp is None:
            self.skips += 1
            return cf.fn(inputs)
        key = (cf.name, fp)
        cached = self._cache.get(key)
        if cached is not None:
            self.hits += 1
            st[0] += 1
            self._cache.move_to_end(key)
            return {name: list(items) for name, items in cached.items()}
        self.misses += 1
        st[1] += 1
        out = cf.fn(inputs)
        self._cache[key] = {name: list(items) for name, items in out.items()}
        while len(self._cache) > self.capacity_entries:
            self._cache.popitem(last=False)
        return out

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._cache)


class FunctionRegistry:
    def __init__(self, code_dir: Optional[str] = None, *, memoize: bool = True):
        self.code_dir = code_dir or tempfile.mkdtemp(prefix="dandelion_code_")
        self.functions: Dict[str, ComputeFunction] = {}
        self.compositions: Dict[str, Composition] = {}
        self._ram_cache: Dict[str, bytes] = {}
        # payload-execution memo for modeled-duration tasks; None disables
        self.memo: Optional[PayloadMemo] = PayloadMemo() if memoize else None

    # ------------------------------------------------------- functions
    def register_function(
        self,
        name: str,
        fn: Callable[[SetDict], SetDict],
        *,
        context_bytes: int = 1 << 20,
        jax_fn: Optional[Callable] = None,
        abstract_args: Tuple[Any, ...] = (),
        service_time_s: Optional[float] = None,
        memoize: bool = True,
        batchable: bool = False,
        pure_unsafe: bool = False,
    ) -> ComputeFunction:
        try:
            code = pickle.dumps(fn)
        except Exception:
            # closures/jitted callables aren't picklable; store a stub of
            # representative size (the bytes still flow through the real
            # disk/cache code paths).
            code = pickle.dumps(name.encode() * 64)
        path = os.path.join(self.code_dir, f"{name}.bin")
        with open(path, "wb") as f:
            f.write(code)
        cf = ComputeFunction(
            name=name,
            fn=fn,
            context_bytes=context_bytes,
            jax_fn=jax_fn,
            abstract_args=abstract_args,
            service_time_s=service_time_s,
            memoize=memoize,
            batchable=batchable,
            disk_path=path,
            code=code,
            pure_unsafe=pure_unsafe,
        )
        self.functions[name] = cf
        return cf

    def run_payload(self, name: str, inputs: SetDict) -> SetDict:
        """Execute a function body, reusing memoized outputs for repeated
        input digests (valid only when the caller models the duration —
        the virtual-time fast path must not short-circuit measured runs)."""
        cf = self.get(name)
        if self.memo is not None:
            return self.memo.run(cf, inputs)
        return cf.fn(inputs)

    def get(self, name: str) -> ComputeFunction:
        if name not in self.functions:
            raise KeyError(f"unregistered compute function {name!r}")
        return self.functions[name]

    def load_code(self, name: str, cached: bool) -> bytes:
        """Real code-load path: RAM cache memcpy or disk read + unpickle."""
        cf = self.get(name)
        if cached and name in self._ram_cache:
            return bytes(self._ram_cache[name])  # copy, like a memcpy
        with open(cf.disk_path, "rb") as f:
            raw = f.read()
        try:
            pickle.loads(raw)
        except Exception:
            pass
        self._ram_cache[name] = raw
        return raw

    def code_size(self, name: str) -> int:
        """Binary size in bytes without performing the real load (the
        modeled fast path commits code memory by size only)."""
        return len(self.get(name).code)

    def evict(self, name: str) -> None:
        self._ram_cache.pop(name, None)

    # ---------------------------------------------------- compositions
    def register_composition(self, comp: Composition) -> Composition:
        """Validate and store a composition. Beyond the structural
        ``Composition.validate`` checks, every compute vertex (including
        nested subgraphs) must reference a registered function — a typo'd
        ``function=`` name fails here, naming the vertex, instead of at
        invoke time."""
        comp.validate()
        self._check_functions(comp)
        # analysis seam: lint hooks (repro.core.dag.add_registration_hook)
        # see every structurally-valid composition before it is stored —
        # a strict hook raises and the registration never lands
        fire_registration_hooks(comp)
        self.compositions[comp.name] = comp
        return comp

    def _check_functions(self, comp: Composition) -> None:
        for v in comp.vertices.values():
            if v.kind == COMPUTE and v.function not in self.functions:
                raise ValueError(
                    f"{comp.name}: compute vertex {v.name!r} references "
                    f"unregistered function {v.function!r}"
                )
            if v.kind == SUBGRAPH and v.subgraph is not None:
                self._check_functions(v.subgraph)

    def get_composition(self, name: str) -> Composition:
        if name not in self.compositions:
            raise KeyError(f"unregistered composition {name!r}")
        return self.compositions[name]
