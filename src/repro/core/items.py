"""Data model for function I/O: items grouped into named sets.

An Item is an immutable (key, data) pair; data is ``bytes`` or a numpy /
jax array (arrays move through memory contexts without serialization -
the TPU analogue of Dandelion's memory-mapped input sets). Keys are only
used by 'key'-mode edge grouping, exactly as in the paper (SS4.1).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from functools import cached_property
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class Item:
    data: Any
    key: str = ""

    # items are immutable, so both the size and the content digest are
    # computed once per item and cached (frozen dataclasses still carry a
    # __dict__, which is what cached_property writes into)
    @cached_property
    def nbytes(self) -> int:
        d = self.data
        if isinstance(d, (bytes, bytearray)):
            return len(d)
        if hasattr(d, "nbytes"):
            return int(d.nbytes)
        if isinstance(d, str):
            return len(d.encode())
        return 64  # opaque python object: nominal

    @cached_property
    def content_fp(self) -> Optional[bytes]:
        """16-byte digest of (key, data), or None when the data is opaque
        and offers no ``fingerprint()`` hook. Cached so an item flowing
        through several consumers (or repeated invocations of the same
        composition) is hashed exactly once."""
        enc = _data_bytes(self.data)
        if enc is None:
            return None
        h = hashlib.blake2b(digest_size=16)
        k = self.key.encode()
        h.update(len(k).to_bytes(8, "little"))
        h.update(k)
        h.update(len(enc).to_bytes(8, "little"))
        h.update(enc)
        return h.digest()


ItemSet = List[Item]
SetDict = Dict[str, ItemSet]


def make_set(*values, keys: Optional[List[str]] = None) -> ItemSet:
    keys = keys or [""] * len(values)
    return [Item(v, k) for v, k in zip(values, keys)]


def set_bytes(s: ItemSet) -> int:
    return sum(it.nbytes for it in s)


def sets_bytes(d: SetDict) -> int:
    return sum(set_bytes(s) for s in d.values())


def group_by_key(s: ItemSet) -> Dict[str, ItemSet]:
    out: Dict[str, ItemSet] = {}
    for it in s:
        out.setdefault(it.key, []).append(it)
    return out


# ---------------------------------------------------------------------------
# Content fingerprints (payload-execution memoization, see registry.PayloadMemo)
# ---------------------------------------------------------------------------
def _data_bytes(d: Any) -> Optional[bytes]:
    """Canonical byte encoding of item data for hashing, or None when the
    payload is an opaque object we cannot fingerprint safely (memoization
    is then skipped for the whole invocation)."""
    if isinstance(d, (bytes, bytearray)):
        return b"b:" + bytes(d)
    if isinstance(d, str):
        return b"s:" + d.encode()
    if isinstance(d, bool):
        return b"B:%d" % d
    if isinstance(d, int):
        return b"i:" + repr(d).encode()
    if isinstance(d, float):
        return b"f:" + repr(d).encode()
    if d is None:
        return b"n:"
    if isinstance(d, np.ndarray):
        if d.dtype.hasobject:
            return None  # tobytes() would hash PyObject pointers
        return b"a:" + str(d.dtype).encode() + repr(d.shape).encode() + d.tobytes()
    # opaque objects may opt in to memoization by providing a
    # ``fingerprint()`` method returning a stable str/bytes content id
    # (e.g. apps.inference_service.KVCache) — the memoized-decode contract
    fp = getattr(d, "fingerprint", None)
    if callable(fp):
        out = fp()
        if isinstance(out, str):
            out = out.encode()
        if isinstance(out, (bytes, bytearray)):
            return b"o:" + type(d).__name__.encode() + b":" + bytes(out)
    return None


def fingerprint_sets(d: SetDict) -> Optional[str]:
    """Content digest of a SetDict: set names, item order, keys, and data.
    Returns None (caller must execute for real) if any item holds data we
    cannot canonically encode — arbitrary python objects without a
    ``fingerprint()`` hook, device arrays. Set names are length-framed and
    per-item digests are fixed-width, so payload bytes can never masquerade
    as field boundaries (no collisions by concatenation)."""
    h = hashlib.blake2b(digest_size=16)
    for name in sorted(d):
        nb = name.encode()
        h.update(b"\x00")
        h.update(len(nb).to_bytes(8, "little"))
        h.update(nb)
        for it in d[name]:
            fp = it.content_fp
            if fp is None:
                return None
            h.update(b"\x01")
            h.update(fp)
    return h.hexdigest()
