"""Data model for function I/O: items grouped into named sets.

An Item is an immutable (key, data) pair; data is ``bytes`` or a numpy /
jax array (arrays move through memory contexts without serialization -
the TPU analogue of Dandelion's memory-mapped input sets). Keys are only
used by 'key'-mode edge grouping, exactly as in the paper (SS4.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class Item:
    data: Any
    key: str = ""

    @property
    def nbytes(self) -> int:
        d = self.data
        if isinstance(d, (bytes, bytearray)):
            return len(d)
        if hasattr(d, "nbytes"):
            return int(d.nbytes)
        if isinstance(d, str):
            return len(d.encode())
        return 64  # opaque python object: nominal


ItemSet = List[Item]
SetDict = Dict[str, ItemSet]


def make_set(*values, keys: Optional[List[str]] = None) -> ItemSet:
    keys = keys or [""] * len(values)
    return [Item(v, k) for v, k in zip(values, keys)]


def set_bytes(s: ItemSet) -> int:
    return sum(it.nbytes for it in s)


def sets_bytes(d: SetDict) -> int:
    return sum(set_bytes(s) for s in d.values())


def group_by_key(s: ItemSet) -> Dict[str, ItemSet]:
    out: Dict[str, ItemSet] = {}
    for it in s:
        out.setdefault(it.key, []).append(it)
    return out
