"""Data model for function I/O: items grouped into named sets.

An Item is an immutable (key, data) pair; data is ``bytes`` or a numpy /
jax array (arrays move through memory contexts without serialization -
the TPU analogue of Dandelion's memory-mapped input sets). Keys are only
used by 'key'-mode edge grouping, exactly as in the paper (SS4.1).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class Item:
    data: Any
    key: str = ""

    @property
    def nbytes(self) -> int:
        d = self.data
        if isinstance(d, (bytes, bytearray)):
            return len(d)
        if hasattr(d, "nbytes"):
            return int(d.nbytes)
        if isinstance(d, str):
            return len(d.encode())
        return 64  # opaque python object: nominal


ItemSet = List[Item]
SetDict = Dict[str, ItemSet]


def make_set(*values, keys: Optional[List[str]] = None) -> ItemSet:
    keys = keys or [""] * len(values)
    return [Item(v, k) for v, k in zip(values, keys)]


def set_bytes(s: ItemSet) -> int:
    return sum(it.nbytes for it in s)


def sets_bytes(d: SetDict) -> int:
    return sum(set_bytes(s) for s in d.values())


def group_by_key(s: ItemSet) -> Dict[str, ItemSet]:
    out: Dict[str, ItemSet] = {}
    for it in s:
        out.setdefault(it.key, []).append(it)
    return out


# ---------------------------------------------------------------------------
# Content fingerprints (payload-execution memoization, see registry.PayloadMemo)
# ---------------------------------------------------------------------------
def _data_bytes(d: Any) -> Optional[bytes]:
    """Canonical byte encoding of item data for hashing, or None when the
    payload is an opaque object we cannot fingerprint safely (memoization
    is then skipped for the whole invocation)."""
    if isinstance(d, (bytes, bytearray)):
        return b"b:" + bytes(d)
    if isinstance(d, str):
        return b"s:" + d.encode()
    if isinstance(d, bool):
        return b"B:%d" % d
    if isinstance(d, int):
        return b"i:" + repr(d).encode()
    if isinstance(d, float):
        return b"f:" + repr(d).encode()
    if d is None:
        return b"n:"
    if isinstance(d, np.ndarray):
        if d.dtype.hasobject:
            return None  # tobytes() would hash PyObject pointers
        return b"a:" + str(d.dtype).encode() + repr(d.shape).encode() + d.tobytes()
    return None


def fingerprint_sets(d: SetDict) -> Optional[str]:
    """Content digest of a SetDict: set names, item order, keys, and data.
    Returns None (caller must execute for real) if any item holds data we
    cannot canonically encode — arbitrary python objects, device arrays.
    Every field is length-framed before hashing so payload bytes can never
    masquerade as field boundaries (no collisions by concatenation)."""
    h = hashlib.blake2b(digest_size=16)

    def field(tag: bytes, payload: bytes):
        h.update(tag)
        h.update(len(payload).to_bytes(8, "little"))
        h.update(payload)

    for name in sorted(d):
        field(b"\x00", name.encode())
        for it in d[name]:
            enc = _data_bytes(it.data)
            if enc is None:
                return None
            field(b"\x01", it.key.encode())
            field(b"\x02", enc)
    return h.hexdigest()
