"""Content-addressed artifact catalog + P2P tree distribution (FaaSNet).

A freshly booted node pays per-function disk misses and full model-weight
cold starts on first touch, so scale-up is slowest exactly when bursts
hit. FaaSNet's answer (Alibaba Function Compute, see PAPERS.md) is to
provision function artifacts peer-to-peer over a tree of already-warm
nodes instead of hammering the origin registry. This module models both
halves:

  * ``ArtifactCatalog`` — content-addressed artifacts with *real* sizes:
    function binaries straight from the ``FunctionRegistry`` code store
    (``len(ComputeFunction.code)``) and model weights from a node's
    ``WeightStore`` registration (``param_bytes``). The digest is the
    content address; two registrations of the same bytes are one
    artifact.
  * ``P2PDistributor`` — on node join (or an explicit prefetch decision)
    streams hot artifacts to the new node. Every stream is an explicit
    ``TRANSFER`` task on the *sending* node's comm engine, priced by the
    per-link ``TransferProfile`` — distribution contends with real
    traffic and is journaled/byte-deterministic exactly like cross-node
    edges (``cluster.CrossNodePlacer``). Peers serve at most
    ``fanout`` concurrent downloads per artifact; a node that finishes
    its download immediately becomes a serving peer for nodes still
    waiting — the FaaSNet tree, built dynamically and deterministically.
    With no warm peer (or ``peer=False``, the baseline) the artifact is
    fetched from the origin registry, whose single uplink serializes
    concurrent downloads — the bottleneck P2P exists to remove.

Arrived artifacts seed the receiving node through the existing cold-start
accounting so nothing is double-billed: code binaries enter the node's
``CodeCache`` via ``warm()`` (residency without a counted hit/miss) and
weights enter the ``WeightStore`` via ``preload()`` (residency committed
once, no cold touch) — the next request's ``touch`` probes see warm state
and the task's ``cold_setup_s`` is never charged a second time.

Contract / determinism invariants:

  * source selection, tree shape, and transfer durations are pure
    functions of catalog content, join order, and link profiles — no RNG;
    the journal is byte-stable run to run, under both ``CROSSNODE``
    values and the sharded loop (pinned by tests/test_prefetch.py);
  * in-flight bytes are staged in a ``MemoryContext`` on the sender and
    released on arrival (weights re-commit through the store's own
    residency accounting — freed-exactly-once holds through prefetch);
  * with no distributor attached (the default), no code path changes:
    fig10–13 byte-identity is untouched.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.coldstart import TransferProfile
from repro.core.context import MemoryContext
from repro.core.engines import TRANSFER, Task
from repro.core.node import WorkerNode
from repro.core.tracing import TransferStats

CODE, WEIGHTS = "code", "weights"

#: pseudo-node name for origin-registry fetches in journals/link counters
ORIGIN = "origin"


@dataclass(frozen=True)
class Artifact:
    """One distributable blob: a function binary or a model's weights."""

    name: str                  # "code:<fn_name>" | "weights:<model>"
    kind: str                  # CODE | WEIGHTS
    nbytes: int                # real size (code bytes / param bytes)
    fn_names: Tuple[str, ...]  # functions this artifact warms
    digest: str                # content address

    @property
    def key(self) -> str:
        """The registry-level identity (fn name or model name)."""
        return self.name.split(":", 1)[1]


def _digest(kind: str, key: str, nbytes: int) -> str:
    return hashlib.sha256(f"{kind}:{key}:{nbytes}".encode()).hexdigest()[:16]


class ArtifactCatalog:
    """Content-addressed index of everything the distributor may stream.

    Registration is idempotent per (kind, key, size): re-syncing from a
    registry or weight store never duplicates an artifact, and a size
    change (a redeployed binary) produces a *new* digest — the content
    address is the identity, as in any CAS registry.
    """

    def __init__(self):
        self._by_name: Dict[str, Artifact] = {}   # insertion-ordered

    def __len__(self) -> int:
        return len(self._by_name)

    def __iter__(self):
        return iter(self._by_name.values())

    def get(self, name: str) -> Optional[Artifact]:
        return self._by_name.get(name)

    # ---------------------------------------------------- registration
    def register_code(self, fn_name: str, nbytes: int) -> Artifact:
        name = f"{CODE}:{fn_name}"
        art = Artifact(name=name, kind=CODE, nbytes=int(nbytes),
                       fn_names=(fn_name,),
                       digest=_digest(CODE, fn_name, int(nbytes)))
        self._by_name[name] = art
        return art

    def register_weights(self, model: str, param_bytes: int,
                         fn_names) -> Artifact:
        name = f"{WEIGHTS}:{model}"
        art = Artifact(name=name, kind=WEIGHTS, nbytes=int(param_bytes),
                       fn_names=tuple(fn_names),
                       digest=_digest(WEIGHTS, model, int(param_bytes)))
        self._by_name[name] = art
        return art

    def sync_registry(self, registry) -> None:
        """Register every compute function's binary at its real size."""
        for fn_name, cf in registry.functions.items():
            existing = self._by_name.get(f"{CODE}:{fn_name}")
            nbytes = max(len(cf.code), 1)
            if existing is None or existing.nbytes != nbytes:
                self.register_code(fn_name, nbytes)

    def sync_weight_store(self, ws) -> None:
        """Register every model a ``WeightStore`` knows, with the compute
        functions mapped to it, at its registered ``param_bytes``."""
        if ws is None:
            return
        by_model: Dict[str, List[str]] = {}
        for fn, model in ws._by_fn.items():
            by_model.setdefault(model, []).append(fn)
        for model, st in ws._models.items():
            fns = tuple(sorted(by_model.get(model, ())))
            existing = self._by_name.get(f"{WEIGHTS}:{model}")
            if existing is None or existing.nbytes != st.param_bytes \
                    or existing.fn_names != fns:
                self.register_weights(model, st.param_bytes, fns)

    # --------------------------------------------------------- queries
    def for_functions(self, fn_names) -> List[Artifact]:
        """Artifacts needed to serve ``fn_names`` warm, in registration
        order: each function's binary plus the weights of any model
        mapped to it."""
        wanted = set(fn_names)
        return [a for a in self._by_name.values()
                if wanted.intersection(a.fn_names)]


@dataclass
class PrefetchConfig:
    """Knobs for P2P artifact distribution (``P2PDistributor``). Ships
    only through ``sdk.PlatformConfig(prefetch=...)``."""

    hot_k: int = 8              # top-K hot functions prefetched on join
    fanout: int = 2             # concurrent downloads one peer serves
    peer: bool = True           # False -> origin-only fetch (baseline)
    include_weights: bool = True
    # peer links default to the cross-node TransferProfile; the origin
    # registry's shared uplink is slower per FaaSNet's motivation
    peer_link: TransferProfile = field(default_factory=TransferProfile)
    origin_link: TransferProfile = field(
        default_factory=lambda: TransferProfile(
            latency_s=1e-3, bandwidth_bps=256e6
        )
    )
    journal: bool = False

    def __post_init__(self):
        if self.hot_k < 1:
            raise ValueError(f"prefetch hot_k must be >= 1, got {self.hot_k}")
        if self.fanout < 1:
            raise ValueError(f"prefetch fanout must be >= 1, got {self.fanout}")


class _ArtifactFlow:
    """Per-artifact distribution state: which nodes hold a complete copy,
    which are mid-download, and who is queued waiting for a serving slot."""

    __slots__ = ("holders", "inflight", "outbound", "queue")

    def __init__(self):
        self.holders: List[WorkerNode] = []     # completed copies, in order
        self.inflight: set = set()              # node ids mid-download
        self.outbound: Dict[int, int] = {}      # holder id -> live streams
        self.queue: List[Tuple[WorkerNode, Callable[[], None]]] = []


class P2PDistributor:
    """Streams catalog artifacts to joining/prefetching nodes over a
    deterministic tree of warm peers. See module docstring."""

    def __init__(
        self,
        loop,
        catalog: Optional[ArtifactCatalog] = None,
        *,
        config: Optional[PrefetchConfig] = None,
        journal: Optional[bool] = None,
    ):
        self.loop = loop
        self.catalog = catalog or ArtifactCatalog()
        self.cfg = config or PrefetchConfig()
        if journal is None:
            journal = self.cfg.journal
        self.journal: Optional[List[str]] = [] if journal else None
        self.stats = TransferStats()
        self.peer_fetches = 0
        self.origin_fetches = 0
        self.joins = 0
        #: (node name, join time, warm latency seconds) per completed join
        self.join_log: List[Tuple[str, float, float]] = []
        self._flows: Dict[str, _ArtifactFlow] = {}
        self._origin_free_t = 0.0   # single origin uplink: FIFO in time

    # ------------------------------------------------------------------
    def _log(self, msg: str):
        if self.journal is not None:
            self.journal.append(f"{self.loop.now:.9f} {msg}")

    def _flow(self, art: Artifact) -> _ArtifactFlow:
        f = self._flows.get(art.digest)
        if f is None:
            f = self._flows[art.digest] = _ArtifactFlow()
        return f

    # ------------------------------------------------------- residency
    @staticmethod
    def node_has(node: WorkerNode, art: Artifact) -> bool:
        """Whether ``node`` already holds ``art`` resident."""
        if art.kind == CODE:
            cc = node.code_cache
            return cc is None or cc.resident(art.key)
        ws = node.weight_store
        if ws is None or art.key not in ws._models:
            return False
        return ws.resident(art.key)

    def _seed(self, node: WorkerNode, art: Artifact) -> None:
        """Mark ``art`` resident on ``node`` through the cold-start
        accounting: the next dispatcher ``touch`` is a warm hit, so the
        profile's ``cold_setup_s`` is never billed on top of the
        transfer the artifact already paid."""
        if art.kind == CODE:
            if node.code_cache is not None:
                for fn in art.fn_names:
                    node.code_cache.warm(fn)
        else:
            ws = node.weight_store
            if ws is not None and art.key in ws._models:
                ws.preload(art.key)

    def scan_holders(self, nodes) -> None:
        """Index already-warm nodes as serving peers (seed nodes warmed
        through ordinary traffic rather than through a prefetch)."""
        for art in self.catalog:
            flow = self._flow(art)
            held = {id(n) for n in flow.holders}
            for n in nodes:
                if id(n) not in held and n.alive and self.node_has(n, art):
                    flow.holders.append(n)

    # ------------------------------------------------------ entrypoints
    def on_node_join(self, node: WorkerNode, *, peers, hot_fns=None,
                     on_complete: Optional[Callable[[float], None]] = None):
        """A node joined the pool: sync the catalog from what it can run,
        index the existing ``peers`` as serving candidates, and stream it
        the hot artifact set. ``hot_fns`` (e.g. from
        ``RoutingStats.hot_functions``) narrows the set; None prefetches
        the whole catalog. ``on_complete(warm_s)`` fires when every
        artifact has landed."""
        self.catalog.sync_registry(node.registry)
        self.catalog.sync_weight_store(node.weight_store)
        self.scan_holders(list(peers) + [node])
        arts = (self.catalog.for_functions(hot_fns) if hot_fns is not None
                else list(self.catalog))
        if not self.cfg.include_weights:
            arts = [a for a in arts if a.kind != WEIGHTS]
        self.joins += 1
        t0 = self.loop.now
        self._log(f"join {node.name} artifacts={len(arts)}")

        def done():
            warm_s = self.loop.now - t0
            self.join_log.append((node.name, t0, warm_s))
            self._log(f"join_warm {node.name} warm_s={warm_s:.9f}")
            if on_complete is not None:
                on_complete(warm_s)

        self.prefetch(node, arts, on_complete=done)

    def prefetch(self, node: WorkerNode, artifacts,
                 on_complete: Optional[Callable[[], None]] = None):
        """Stream ``artifacts`` to ``node``; ``on_complete`` fires once
        all of them are resident there (immediately if they already are)."""
        pending = 0
        fired = [False]

        def one_done():
            nonlocal pending
            pending -= 1
            if pending == 0 and not fired[0]:
                fired[0] = True
                if on_complete is not None:
                    on_complete()

        artifacts = list(artifacts)
        for art in artifacts:
            flow = self._flow(art)
            if self.node_has(node, art) or id(node) in flow.inflight:
                continue
            pending += 1
            flow.inflight.add(id(node))
            flow.queue.append((node, one_done))
        if pending == 0:
            if on_complete is not None:
                on_complete()
            return
        for art in artifacts:
            self._drain(art)

    # ------------------------------------------------------ tree engine
    def _drain(self, art: Artifact) -> None:
        """Start every queued download of ``art`` that has a serving slot:
        warm holders first (up to ``fanout`` concurrent streams each, in
        stable holder order), the origin uplink as the fallback root."""
        flow = self._flow(art)
        while flow.queue:
            dst, cb = flow.queue[0]
            if not dst.alive:
                flow.queue.pop(0)
                flow.inflight.discard(id(dst))
                cb()
                continue
            src = None
            if self.cfg.peer:
                for h in flow.holders:
                    if h.alive and h is not dst \
                            and flow.outbound.get(id(h), 0) < self.cfg.fanout:
                        src = h
                        break
            if src is not None:
                flow.queue.pop(0)
                self._stream_peer(art, flow, src, dst, cb)
            elif not flow.holders or not self.cfg.peer:
                flow.queue.pop(0)
                self._stream_origin(art, flow, dst, cb)
            else:
                # warm peers exist but all fanout slots are busy: wait for
                # a stream to finish (the finisher re-drains the queue)
                return

    def _arrived(self, art: Artifact, flow: _ArtifactFlow,
                 dst: WorkerNode, cb: Callable[[], None]) -> None:
        flow.inflight.discard(id(dst))
        if dst.alive:
            self._seed(dst, art)
            flow.holders.append(dst)    # dst now serves the tree
        cb()
        self._drain(art)

    def _stream_peer(self, art: Artifact, flow: _ArtifactFlow,
                     src: WorkerNode, dst: WorkerNode,
                     cb: Callable[[], None]) -> None:
        cpu_s, io_s = self.cfg.peer_link.charge(art.nbytes)
        self.peer_fetches += 1
        flow.outbound[id(src)] = flow.outbound.get(id(src), 0) + 1
        self.stats.record_transfer(src.name, dst.name, art.nbytes, cpu_s, io_s)
        self._log(f"transfer {art.name} {src.name}->{dst.name} "
                  f"bytes={art.nbytes}")
        # stage the in-flight bytes on the sender for the wire time; the
        # receiver's residency is committed by _seed through the
        # CodeCache/WeightStore accounting (never both at once)
        stage = MemoryContext(capacity=max(art.nbytes, 1),
                              tracker=src.tracker)
        stage.load_code_size(art.nbytes)

        def landed(_task: Task, _outputs, _ctx):
            stage.free()
            flow.outbound[id(src)] -= 1
            self._arrived(art, flow, dst, cb)

        src.engines.submit(Task(
            kind=TRANSFER, fn_name="transfer", inputs={}, context_bytes=0,
            transfer_bytes=art.nbytes, transfer_cpu_s=cpu_s,
            transfer_io_s=io_s, on_complete=landed,
        ))

    def _stream_origin(self, art: Artifact, flow: _ArtifactFlow,
                       dst: WorkerNode, cb: Callable[[], None]) -> None:
        cpu_s, io_s = self.cfg.origin_link.charge(art.nbytes)
        self.origin_fetches += 1
        # the origin registry has ONE shared uplink: concurrent fetches
        # serialize in FIFO order (the scale bottleneck FaaSNet removes)
        start = max(self.loop.now, self._origin_free_t)
        self._origin_free_t = start + io_s
        self.stats.record_transfer(ORIGIN, dst.name, art.nbytes, cpu_s, io_s)
        self._log(f"origin_fetch {art.name} ->{dst.name} bytes={art.nbytes} "
                  f"start={start:.9f}")

        def landed(_task: Task, _outputs, _ctx):
            self._arrived(art, flow, dst, cb)

        def submit():
            if not dst.alive:
                self._arrived(art, flow, dst, cb)
                return
            # the download occupies the RECEIVER's comm engine (protocol
            # CPU + wire time), contending with its real traffic
            dst.engines.submit(Task(
                kind=TRANSFER, fn_name="transfer", inputs={},
                context_bytes=0, transfer_bytes=art.nbytes,
                transfer_cpu_s=cpu_s, transfer_io_s=io_s,
                on_complete=landed,
            ))

        if start <= self.loop.now:
            submit()
        else:
            self.loop.at(start, submit)

    # ------------------------------------------------------------ stats
    def summary(self) -> Dict[str, float]:
        warms = [w for _, _, w in self.join_log]
        return {
            "artifacts": len(self.catalog),
            "joins": self.joins,
            "peer_fetches": self.peer_fetches,
            "origin_fetches": self.origin_fetches,
            "transfer_mb": self.stats.bytes_total / 1024**2,
            "join_warm_max_s": max(warms) if warms else 0.0,
            "join_warm_avg_s": sum(warms) / len(warms) if warms else 0.0,
        }
