"""Worker control plane: PI controller over engine-queue growth rates (SS5).

Every ``interval`` (30 ms default, as in the paper) the controller samples
both queue lengths, computes each queue's growth rate since the last tick,
and uses the growth-rate difference as the error signal of a
Proportional-Integral controller. A positive control signal re-assigns one
CPU core from the communication engines to the compute engines; negative,
the opposite. Engine pools never drop below one slot each.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.engines import COMM, COMPUTE, EngineSet
from repro.core.sim import EventLoop


@dataclass
class PIController:
    engines: EngineSet
    loop: EventLoop
    interval_s: float = 0.030
    kp: float = 0.6
    ki: float = 0.2
    deadband: float = 0.5          # |u| below this: no action
    enabled: bool = True
    history: List[Tuple[float, int, int, float]] = field(default_factory=list)

    def __post_init__(self):
        self._last = self.engines.queue_lengths()
        self._integral = 0.0
        self._started = False

    def start(self):
        if not self._started:
            self._started = True
            self.loop.after(self.interval_s, self._tick, daemon=True)

    def _tick(self):
        cur = self.engines.queue_lengths()
        dt = self.interval_s
        growth_compute = (cur[COMPUTE] - self._last[COMPUTE]) / dt
        growth_comm = (cur[COMM] - self._last[COMM]) / dt
        self._last = cur

        error = (growth_compute - growth_comm) * dt  # per-tick units
        self._integral = 0.9 * self._integral + error
        u = self.kp * error + self.ki * self._integral

        moved = 0
        if self.enabled:
            if u > self.deadband:
                if self.engines.retype_one(COMM, COMPUTE):
                    moved = 1
                    self._integral = 0.0
            elif u < -self.deadband:
                if self.engines.retype_one(COMPUTE, COMM):
                    moved = -1
                    self._integral = 0.0
        counts = self.engines.counts()
        self.history.append((self.loop.now, counts[COMPUTE], counts[COMM], u))
        self.loop.after(self.interval_s, self._tick, daemon=True)
