"""Dirigent-style elastic control plane: locality routing + node autoscaling.

The paper's cluster layer (SS5 "Dirigent") is what makes per-request
contexts pay off at fleet scale: committed memory tracks the active floor
only if the node pool itself follows load. This module replaces the static
``ClusterManager`` routing path with:

  * **two-level routing** - code-cache/locality affinity first (FaaSNet's
    observation: provisioning speed hinges on where function code already
    lives), falling back to load-aware spillover via power-of-two-choices
    on per-node outstanding work;
  * **node autoscaling** - scale up on per-node outstanding-load or
    queue-delay thresholds, paying a ``ColdStartProfile``-modeled node
    boot cost before the new node takes traffic (Boxer's ephemeral burst
    capacity); scale down after an idle keep-alive window, draining
    in-flight work before retiring a node;
  * **accounting** - per-node cache-hit / routed / committed-memory
    counters (``tracing.RoutingStats``), a node-count timeline, and
    cluster-wide committed-memory integration including the per-node
    runtime/OS base footprint that a static peak-provisioned fleet pays
    around the clock.

With cross-node compositions enabled (``cluster.CrossNodePlacer``), the
control plane additionally makes **vertex-granular** decisions:
``place_vertex`` applies the same two-level affinity/p2c policy to a
single compute function when the dispatcher exports a ready vertex, so
different vertices of one DAG can run on different nodes (the paper's
per-vertex elasticity claim). Placement decisions are journaled like
routing decisions (``place <fn> <node> ...`` entries).

Contract / determinism invariants:

  * everything runs on the shared deterministic ``EventLoop``; given the
    same seed and workload, routing decisions, scaling events, placements
    and final stats are bit-identical across runs — the decision journal
    (``journal=True``) is byte-stable (pinned by
    tests/test_control_plane.py);
  * the p2c RNG is consumed only on spillover (and never with a single
    active node), so enabling features that don't spill leaves the
    decision stream unchanged;
  * committed-memory aggregates are exact: every node tracker mirrors
    into ``cluster_mem`` streaming (no post-hoc timeline merging).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.coldstart import ColdStartProfile
from repro.core.context import MemoryTracker
from repro.core.dag import COMPUTE, SUBGRAPH, Composition
from repro.core.node import WorkerNode
from repro.core.sim import EventLoop, Timeline
from repro.core.tracing import RoutingStats

BOOTING, ACTIVE, DRAINING, RETIRED = "booting", "active", "draining", "retired"


def composition_functions(comp: Composition) -> Tuple[str, ...]:
    """All compute-function names a composition (incl. nested subgraphs)
    will load - the set the affinity router matches against node caches."""
    cached = comp.__dict__.get("_compute_fns")
    if cached is not None:
        return cached
    names: List[str] = []

    def walk(c: Composition):
        for v in c.vertices.values():
            if v.kind == SUBGRAPH and v.subgraph is not None:
                walk(v.subgraph)
            elif v.kind == COMPUTE:
                names.append(v.function)

    walk(comp)
    out = tuple(dict.fromkeys(names))
    comp.__dict__["_compute_fns"] = out
    return out


def composition_batch_units(comp: Composition, registry) -> int:
    """Units of BATCH-engine work one invocation of ``comp`` submits:
    the sum of ``vertex.batch_units`` over batchable compute vertices
    (nested subgraphs included). Zero means the composition never
    touches a batching engine and batch-aware routing defers to the
    default policy. Cached on the composition — batchable flags and
    units are structural, identical across the registries a benchmark
    replays one composition against."""
    cached = comp.__dict__.get("_batch_units")
    if cached is not None:
        return cached
    total = 0

    def walk(c: Composition):
        nonlocal total
        for v in c.vertices.values():
            if v.kind == SUBGRAPH and v.subgraph is not None:
                walk(v.subgraph)
            elif v.kind == COMPUTE:
                cf = registry.functions.get(v.function)
                if cf is not None and getattr(cf, "batchable", False):
                    total += max(1, getattr(v, "batch_units", 1))

    walk(comp)
    comp.__dict__["_batch_units"] = total
    return total


@dataclass
class ReplicaConfig:
    """Knobs for BATCH-replica autoscaling (``ReplicaAutoscaler``):
    model-instance elasticity *within* nodes, one layer below the
    control plane's node autoscaling."""

    min_replicas: int = 0            # pool-wide floor of active replicas
    max_per_node: int = 2            # accelerator slots one node can host
    # scale-up triggers (either): queued units per active replica, or the
    # next coalesced steps already near-full (headroom exhausted)
    target_queue_per_replica: float = 8.0
    headroom_fraction: float = 0.9
    keepalive_s: float = 3.0         # idle window before a replica drains
    tick_interval_s: float = 0.25
    boot_s: float = 0.05             # replica spin-up (runtime attach; the
                                     # *weight* cold term stays on the
                                     # existing cold_setup_s task path)


class ReplicaAutoscaler:
    """Scales BATCH-engine replicas (model instances) inside a node pool.

    Each tick (a daemon event on the shared loop) it reads every node's
    batch backlog in *units* plus in-flight step occupancy and:

      * **scales up** when a node has queued work and either no active
        replica, a backlog above ``target_queue_per_replica``, or its
        next coalesced steps past ``headroom_fraction`` of capacity —
        paying ``boot_s`` before the new slot serves (weight residency
        stays task-driven: the first task on a cold node still charges
        ``cold_setup_s`` through the ``WeightStore`` miss path);
      * **scales down** a node whose batch engine sat fully idle past
        ``keepalive_s``, via ``EngineSet.retire_batch_slot`` — drain
        before retire, never below ``min_replicas`` pool-wide.

    Decisions are pure functions of observed queue state — no RNG — so
    scaling timelines are byte-stable run to run. Scale-up latencies
    (decision to slot-active) are recorded for the fig13 CI gate.
    """

    def __init__(
        self,
        loop: EventLoop,
        nodes,                       # list or callable -> live WorkerNodes
        *,
        config: Optional[ReplicaConfig] = None,
        journal: bool = False,
    ):
        self.loop = loop
        self._nodes = nodes if callable(nodes) else (lambda: list(nodes))
        self.cfg = config or ReplicaConfig()
        self.journal: Optional[List[str]] = [] if journal else None
        self.scale_ups = 0
        self.scale_downs = 0
        self.scaleup_latencies: List[float] = []
        self._pending: Dict[int, int] = {}     # node id -> booting replicas
        self._idle_since: Dict[int, float] = {}
        self._ticking = False

    def _log(self, msg: str):
        if self.journal is not None:
            self.journal.append(f"{self.loop.now:.9f} {msg}")

    @staticmethod
    def _is_batch(node: WorkerNode) -> bool:
        eng = node.engines
        return eng.batch_model is not None or bool(eng.batch_models)

    def start(self):
        if self._ticking:
            return
        self._ticking = True
        if self.cfg.min_replicas > 0:
            self._ensure_floor()
        self._attach_starvation_hooks()
        self.loop.after(self.cfg.tick_interval_s, self._tick, daemon=True)

    def _attach_starvation_hooks(self):
        """Wire every batch node's ``on_batch_starved`` liveness hook to
        an immediate scale-up. The tick is a *daemon* event: a decode
        task queued on a zero-replica node with nothing else scheduled
        would otherwise strand when the loop drains — the hook's boot
        event is non-daemon, so it keeps the loop alive."""
        for n in self._nodes():
            if self._is_batch(n) and n.engines.on_batch_starved is None:
                n.engines.on_batch_starved = (lambda n=n: self._starved(n))

    def _starved(self, node: WorkerNode):
        """Synchronous kick from the engine: batchable work just queued
        (or the last replica just retired) with zero active replicas.
        Boot one now — the decision the next tick would take anyway
        (``eff == 0`` is unconditionally pressured), so timing moves
        from tick-aligned to enqueue-aligned and stays deterministic."""
        cfg = self.cfg
        nid = id(node)
        pending = self._pending.get(nid, 0)
        if (not node.alive
                or node.engines.active_batch_slots() + pending > 0
                or pending >= cfg.max_per_node):
            return
        self.scale_ups += 1
        self._pending[nid] = pending + 1
        t0 = self.loop.now
        self._log(f"replica_up {node.name} starved")

        def activate(n=node, nid=nid, t0=t0):
            self._pending[nid] -= 1
            if not n.alive:
                return
            n.engines.add_batch_slot()
            self.scaleup_latencies.append(self.loop.now - t0)
            self._log(f"replica_ready {n.name} "
                      f"lat={self.loop.now - t0:.6f}")

        self.loop.after(cfg.boot_s, activate)

    def prewarm(self):
        """Predictive spin-up (``BurstPredictor``): boot one replica on
        every batch-capable node currently at zero replicas, through the
        normal ``_starved`` activate path — same ``boot_s``, same
        accounting, just enqueue-aligned to the *predicted* burst rather
        than the first starved task."""
        for n in self._nodes():
            if n.alive and self._is_batch(n):
                self._starved(n)

    def _ensure_floor(self):
        """Provision the ``min_replicas`` floor round-robin (instant,
        like the control plane's min_nodes: the floor exists before
        traffic does)."""
        nodes = [n for n in self._nodes() if self._is_batch(n)]
        if not nodes:
            return
        total = sum(n.engines.active_batch_slots() for n in nodes)
        attempts = 0
        i = 0
        while total < self.cfg.min_replicas and attempts <= len(nodes):
            n = nodes[i % len(nodes)]
            if n.engines.active_batch_slots() < self.cfg.max_per_node:
                n.engines.add_batch_slot()
                total += 1
                attempts = 0
            else:
                attempts += 1
            i += 1

    def _tick(self):
        cfg = self.cfg
        now = self.loop.now
        nodes = [n for n in self._nodes() if n.alive and self._is_batch(n)]
        for n in nodes:                    # nodes booted since last tick
            if n.engines.on_batch_starved is None:
                n.engines.on_batch_starved = (lambda n=n: self._starved(n))
        total_active = sum(
            n.engines.active_batch_slots() + self._pending.get(id(n), 0)
            for n in nodes
        )
        for n in nodes:
            eng = n.engines
            nid = id(n)
            pending = self._pending.get(nid, 0)
            eff = eng.active_batch_slots() + pending
            backlog = eng.batch_queued_units()
            inflight = eng.batch_inflight_units
            # ---- scale up on queue pressure / coalesced-step headroom
            if backlog > 0 and eff < cfg.max_per_node:
                cap = eff * eng.max_batch
                pressured = (
                    eff == 0
                    or backlog > cfg.target_queue_per_replica * eff
                    or backlog + inflight >= cfg.headroom_fraction * cap
                )
                if pressured:
                    self.scale_ups += 1
                    self._pending[nid] = pending + 1
                    self._log(f"replica_up {n.name} backlog={backlog} "
                              f"active={eff}")

                    def activate(n=n, nid=nid, t0=now):
                        self._pending[nid] -= 1
                        if not n.alive:
                            return
                        n.engines.add_batch_slot()
                        self.scaleup_latencies.append(self.loop.now - t0)
                        self._log(f"replica_ready {n.name} "
                                  f"lat={self.loop.now - t0:.6f}")

                    self.loop.after(cfg.boot_s, activate)
                    total_active += 1
                    eff += 1
            # ---- idle clock / scale down (one replica per node per tick)
            if backlog > 0 or inflight > 0 or eng.active_batch_slots() == 0:
                self._idle_since.pop(nid, None)
            else:
                since = self._idle_since.setdefault(nid, now)
                if (now - since >= cfg.keepalive_s
                        and total_active - 1 >= cfg.min_replicas
                        and eng.retire_batch_slot()):
                    self.scale_downs += 1
                    total_active -= 1
                    self._log(f"replica_down {n.name}")
                    if eng.active_batch_slots() == 0:
                        self._idle_since.pop(nid, None)
        self.loop.after(cfg.tick_interval_s, self._tick, daemon=True)

    def summary(self) -> Dict[str, float]:
        lats = self.scaleup_latencies
        return {
            "replica_scale_ups": self.scale_ups,
            "replica_scale_downs": self.scale_downs,
            "scaleup_latency_max_s": max(lats) if lats else 0.0,
            "scaleup_latency_avg_s": sum(lats) / len(lats) if lats else 0.0,
        }


@dataclass
class PredictorConfig:
    """Knobs for trace-driven burst prediction (``BurstPredictor``).
    Ships only through ``sdk.PlatformConfig(predictor=...)``."""

    bin_s: float = 0.5          # arrival-count bin width
    alpha: float = 0.2          # EWMA smoothing over per-bin counts
    on_factor: float = 1.5      # bin > on_factor * EWMA after quiet => ON edge
    min_cycles: int = 2         # ON-edge gaps observed before predicting
    lead_s: float = 1.0         # fire this early before the predicted edge
    nodes_ahead: int = 1        # nodes pre-booted per predicted burst
    prewarm_replicas: bool = True  # also spin BATCH replicas via autoscaler
    max_history: int = 64       # ON-edge timestamps retained

    def __post_init__(self):
        if self.bin_s <= 0.0:
            raise ValueError(f"predictor bin_s must be > 0, got {self.bin_s}")
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"predictor alpha must be in (0, 1], "
                             f"got {self.alpha}")
        if self.min_cycles < 1:
            raise ValueError(f"predictor min_cycles must be >= 1, "
                             f"got {self.min_cycles}")


class BurstPredictor:
    """EWMA + ON/OFF period detection over the arrival stream.

    ``observe(t)`` is called synchronously from ``route`` — the predictor
    costs *zero* loop events until it has learned a period, so a disabled
    or still-learning predictor leaves the event stream untouched.
    Arrivals are counted into ``bin_s`` bins; a bin whose count jumps
    past ``on_factor`` times the EWMA after a silent bin is an ON edge
    (fig11/fig13's duty-cycled traces go fully quiet between bursts).
    Once ``min_cycles`` edge-to-edge gaps are seen, the period estimate
    (median gap — robust to one irregular cycle) schedules ``on_burst``
    at the next predicted edge minus ``lead_s``: early enough that node
    boot delay leaves the p99 entirely (Boxer's argument). Prediction
    events are daemon events — an armed prediction past the end of the
    trace never keeps the loop alive. No RNG; byte-deterministic."""

    def __init__(self, loop: EventLoop, config: Optional[PredictorConfig]
                 = None, *, on_burst: Optional[Callable[[float], None]] = None):
        self.loop = loop
        self.cfg = config or PredictorConfig()
        self.on_burst = on_burst
        self.edges: List[float] = []        # detected ON-edge times
        self.predictions: List[float] = []  # scheduled fire times
        self.fired = 0
        self._bin_i: Optional[int] = None
        self._count = 0
        self._ewma = 0.0
        self._on = False
        self._last_fire_t = -float("inf")

    @property
    def period_s(self) -> Optional[float]:
        """Current period estimate (median ON-edge gap), or None while
        still learning."""
        gaps = [b - a for a, b in zip(self.edges, self.edges[1:])]
        if len(gaps) < self.cfg.min_cycles:
            return None
        return sorted(gaps)[len(gaps) // 2]

    def observe(self, t: float) -> None:
        """Count one arrival at virtual time ``t`` (monotone non-dec)."""
        i = int(t / self.cfg.bin_s)
        if self._bin_i is None:
            self._bin_i = i
        while i > self._bin_i:
            self._close_bin()
            self._bin_i += 1
        self._count += 1

    def _close_bin(self) -> None:
        c = self._count
        self._count = 0
        if c == 0:
            self._on = False                 # silent bin: OFF
        elif not self._on and self._ewma > 0.0 \
                and c > self.cfg.on_factor * self._ewma:
            self._on = True
            self._edge(self._bin_i * self.cfg.bin_s)
        a = self.cfg.alpha
        self._ewma = c if self._ewma == 0.0 else (1 - a) * self._ewma + a * c

    def _edge(self, t: float) -> None:
        self.edges.append(t)
        if len(self.edges) > self.cfg.max_history:
            self.edges.pop(0)
        period = self.period_s
        if period is None or period <= 0.0:
            return
        fire_t = t + period - self.cfg.lead_s
        # one armed prediction per cycle; never fire in the past
        if fire_t <= self.loop.now or fire_t <= self._last_fire_t:
            return
        self._last_fire_t = fire_t
        self.predictions.append(fire_t)
        self.loop.at(fire_t, lambda ft=fire_t: self._fire(ft), daemon=True)

    def _fire(self, predicted_t: float) -> None:
        self.fired += 1
        if self.on_burst is not None:
            self.on_burst(predicted_t)

    def summary(self) -> Dict[str, float]:
        return {
            "edges": len(self.edges),
            "predictions": len(self.predictions),
            "fired": self.fired,
            "period_s": self.period_s or 0.0,
        }


class BatchRouter:
    """Marginal-latency estimator behind the ``batch_aware`` routing
    policy: score each candidate node by when its *next coalesced step*
    could absorb this composition's batchable units, instead of by
    shortest invocation queue.

    ``estimate`` prices a node as (queued + in-flight units) divided by
    active-replica step capacity, times the replica's full-batch
    ``BatchStepModel.step_s`` — plus a ``spinup_s`` penalty when no
    replica is active and a ``cold_s`` penalty when the node's
    ``WeightStore`` holds none of the composition's models resident.
    Ties break on invocation load then stable node order, so with one
    replica/one model (every estimate equal) the decision sequence is
    exactly the least-outstanding policy's — the degeneration contract
    pinned by tests/test_fleet_serving.py. No RNG is consumed."""

    def __init__(self, *, spinup_s: float = 0.3, cold_s: float = 0.0):
        self.spinup_s = spinup_s
        self.cold_s = cold_s
        self.decisions = 0

    def estimate(self, node: WorkerNode, units: int, fns=()) -> float:
        eng = node.engines
        model = eng.batch_model
        if model is None and eng.batch_models:
            model = next(iter(eng.batch_models.values()))
        if model is None:
            return float("inf")
        mb = max(eng.max_batch, 1)
        units = min(max(units, 1), mb)
        active = eng.active_batch_slots()
        if active == 0:
            est = self.spinup_s + model.step_s(units)
        else:
            backlog = eng.batch_queued_units() + eng.batch_inflight_units
            est = (backlog / (active * mb)) * model.step_s(mb) \
                + model.step_s(units)
        if self.cold_s > 0.0:
            ws = node.weight_store
            if ws is not None and not ws.pinned:
                for fn in fns:
                    if not ws.fn_resident(fn):
                        est += self.cold_s
                        break
        return est

    def pick(self, nodes: List[WorkerNode], comp: Composition, registry,
             load: Callable[[WorkerNode], float]):
        """Best node for ``comp`` by marginal estimate, or None when the
        composition has no batchable work (caller falls back to its
        default policy)."""
        units = composition_batch_units(comp, registry)
        if units == 0 or not nodes:
            return None
        fns = composition_functions(comp)
        best = None
        best_key = None
        for i, n in enumerate(nodes):
            key = (self.estimate(n, units, fns), load(n), i)
            if best_key is None or key < best_key:
                best, best_key = n, key
        self.decisions += 1
        return best


@dataclass
class ControlPlaneConfig:
    min_nodes: int = 1
    max_nodes: int = 8
    # ---- scale-up triggers (either one fires)
    target_outstanding_per_node: float = 8.0
    max_queue_delay_s: float = 25e-3
    # ---- scale-down: fully-idle nodes past keep-alive are drained, and a
    # sustained-low-utilization cluster sheds its least-loaded node once the
    # survivors can absorb the work below this fraction of target load
    keepalive_s: float = 30.0
    scale_down_watermark: float = 0.8
    tick_interval_s: float = 0.5
    # ---- routing: an affinity node this overloaded spills to p2c anyway
    affinity_overload_factor: float = 2.0
    # ---- node provisioning cost (VM boot / runtime start), sampled per boot
    node_boot: ColdStartProfile = field(
        default_factory=lambda: ColdStartProfile(
            setup_s=1.0, execute_s=0.0, jitter_sigma=0.1
        )
    )
    # runtime/OS footprint committed while a node is up (used when the
    # factory does not set WorkerNode.base_bytes)
    node_base_bytes: int = 256 << 20
    # ---- serving-tier elasticity: BATCH-replica autoscaling inside the
    # pool, and marginal-latency routing over those replicas
    replicas: Optional[ReplicaConfig] = None
    route_policy: str = "affinity"   # "affinity" | "batch_aware"
    batch_router: Optional[BatchRouter] = None  # default-built when
                                                # route_policy=batch_aware


@dataclass
class ManagedNode:
    node: WorkerNode
    state: str = BOOTING
    outstanding: int = 0
    idle_since: float = 0.0
    boot_t: float = 0.0
    ready_t: float = 0.0
    base_committed: int = 0


class ElasticControlPlane:
    """Owns the node pool: routes invocations, scales nodes with load."""

    def __init__(
        self,
        loop: EventLoop,
        node_factory: Callable[[str], WorkerNode],
        *,
        config: Optional[ControlPlaneConfig] = None,
        seed: int = 0,
        journal: bool = False,
        predictor: Optional[PredictorConfig] = None,
        distributor=None,   # artifacts.P2PDistributor (optional)
    ):
        self.loop = loop
        self.factory = node_factory
        self.cfg = config or ControlPlaneConfig()
        if self.cfg.min_nodes < 1:
            raise ValueError("control plane needs min_nodes >= 1")
        self.rng = np.random.default_rng(seed)
        self.stats = RoutingStats()
        # cluster-wide committed-memory aggregate: every node tracker and
        # the base-bytes tracker mirror into it, so cluster average/peak
        # are O(1) streaming reads instead of per-query timeline merges
        self.cluster_mem = MemoryTracker(loop)
        self.mem = MemoryTracker(loop, parent=self.cluster_mem)  # node base bytes
        self.node_count_timeline = Timeline()
        self.members: List[ManagedNode] = []
        self._by_node: Dict[int, ManagedNode] = {}
        self._ids = itertools.count()
        self._ticking = False
        self._low_since: Optional[float] = None
        self.journal: Optional[List[str]] = [] if journal else None
        # cross-node vertex placement (cluster.CrossNodePlacer); set by the
        # ClusterManager when CROSSNODE is enabled — every node this plane
        # boots or adopts is attached so its dispatcher exports ready
        # vertices back to the cluster layer
        self.placer = None
        if self.cfg.route_policy not in ("affinity", "batch_aware"):
            raise ValueError(
                f"unknown route_policy {self.cfg.route_policy!r}")
        self.batch_router: Optional[BatchRouter] = (
            self.cfg.batch_router
            or (BatchRouter() if self.cfg.route_policy == "batch_aware"
                else None)
        )
        # P2P artifact prefetch on node join (core.artifacts); None (the
        # default) leaves every existing code path untouched
        self.distributor = distributor
        # trace-driven burst prediction: observe() is a synchronous call
        # from route(), so a disabled predictor adds zero loop events
        self.predictor: Optional[BurstPredictor] = None
        if predictor is not None:
            self.predictor = BurstPredictor(
                self.loop, predictor, on_burst=self._on_burst_predicted
            )
        for _ in range(self.cfg.min_nodes):
            self._boot_node(instant=True)
        self.replica_autoscaler: Optional[ReplicaAutoscaler] = None
        if self.cfg.replicas is not None:
            self.replica_autoscaler = ReplicaAutoscaler(
                loop,
                lambda: [m.node for m in self.members
                         if m.state == ACTIVE and m.node.alive],
                config=self.cfg.replicas,
                journal=journal,
            )
            self.replica_autoscaler.start()

    # ------------------------------------------------------------- pool
    @property
    def worker_nodes(self) -> List[WorkerNode]:
        """Nodes currently up (taking or finishing traffic)."""
        return [m.node for m in self.members if m.state in (ACTIVE, DRAINING)]

    @property
    def active_count(self) -> int:
        return sum(1 for m in self.members if m.state == ACTIVE)

    @property
    def active_nodes(self) -> List[WorkerNode]:
        """Alive ACTIVE nodes — the set new work may land on (draining
        nodes finish what they have but take nothing new)."""
        return [m.node for m in self.members
                if m.state == ACTIVE and m.node.alive]

    def _log(self, msg: str):
        if self.journal is not None:
            self.journal.append(f"{self.loop.now:.9f} {msg}")

    def _record_count(self):
        up = sum(1 for m in self.members if m.state in (ACTIVE, DRAINING))
        self.node_count_timeline.record(self.loop.now, float(up))

    def _boot_node(self, instant: bool = False):
        name = f"en{next(self._ids)}"
        node = self.factory(name)
        # a node may schedule on its own shard of the shared loop
        # (ShardedEventLoop), but never on an unrelated loop: clocks
        # would silently diverge
        if node.loop is not self.loop and \
                getattr(node.loop, "_owner", None) is not self.loop:
            raise ValueError(f"{name}: factory must build nodes on the shared loop")
        node.tracker.attach_parent(self.cluster_mem)
        if self.placer is not None:
            self.placer.attach(node)
        m = ManagedNode(node=node, boot_t=self.loop.now)
        self.members.append(m)
        self._by_node[id(node)] = m
        if instant:
            self._node_ready(m)
        else:
            boot_s, _ = self.cfg.node_boot.sample(self.rng)
            self.stats.scale_ups += 1
            self._log(f"scale_up {name} boot_s={boot_s:.6f}")
            self.loop.after(boot_s, lambda: self._node_ready(m))

    def _node_ready(self, m: ManagedNode):
        if not m.node.alive:            # failed while booting
            m.state = RETIRED
            return
        m.state = ACTIVE
        m.ready_t = self.loop.now
        m.idle_since = self.loop.now
        m.base_committed = m.node.base_bytes or self.cfg.node_base_bytes
        self.mem.commit(m.base_committed)
        self._log(f"ready {m.node.name}")
        self._record_count()
        if self.distributor is not None:
            # stream the hot artifact set to the fresh node over warm
            # peers; nothing is hot before any traffic (initial
            # min_nodes boots), so seed nodes warm through requests
            hot = self.distributor.cfg.hot_k
            hot_fns = self.stats.hot_functions(hot)
            if hot_fns:
                peers = [p.node for p in self.members
                         if p is not m and p.state in (ACTIVE, DRAINING)
                         and p.node.alive]
                self.distributor.on_node_join(
                    m.node, peers=peers, hot_fns=hot_fns
                )

    def adopt(self, node: WorkerNode):
        """Register an externally created node as active (manual add)."""
        node.tracker.attach_parent(self.cluster_mem)
        if self.placer is not None:
            self.placer.attach(node)
        m = ManagedNode(node=node, boot_t=self.loop.now)
        self.members.append(m)
        self._by_node[id(node)] = m
        self._node_ready(m)

    # ---------------------------------------------------------- routing
    def _pick_two_level(
        self,
        active: List[ManagedNode],
        fns,
        load: Callable[[ManagedNode], float],
        prefer: Optional[WorkerNode] = None,
    ) -> Tuple[ManagedNode, str]:
        """The shared two-level scorer behind ``route`` (whole
        compositions, load = outstanding) and ``place_vertex`` (single
        vertices, load includes placed-vertex counts, ties prefer the
        home node). Affinity: best code-cache residency wins among nodes
        under the overload limit; ties bin-pack — fill a node up to its
        slot count before spilling, so lightly loaded nodes go fully
        idle and the autoscaler can reap them (spreading a trickle over
        every warm node keeps the whole fleet alive forever). Fallback:
        power-of-two-choices on load (no RNG draw with one candidate)."""
        affinity: List[Tuple[float, ManagedNode]] = []
        for m in active:
            limit = self.cfg.affinity_overload_factor * max(m.node.num_slots, 1)
            score = m.node.warm_fraction(fns)
            if score > 0.0 and load(m) < limit:
                affinity.append((score, m))
        if affinity:
            def pack_key(sm):
                score, m = sm
                slots = max(m.node.num_slots, 1)
                under = load(m) < slots
                depth = load(m) if under else -load(m)
                return (score, under, depth, m.node is prefer)

            return max(affinity, key=pack_key)[1], "affinity"
        if len(active) == 1:
            return active[0], "spillover"
        i, j = self.rng.choice(len(active), size=2, replace=False)
        a, b = active[int(i)], active[int(j)]
        return (a if load(a) <= load(b) else b), "spillover"

    def route(self, comp: Composition) -> WorkerNode:
        """Two-level policy: code-cache affinity, else p2c on load."""
        self._ensure_tick()
        if self.predictor is not None:
            self.predictor.observe(self.loop.now)
        # per-function popularity feeds the distributor's hot set; pure
        # counter accounting, recorded only when someone consumes it
        track = composition_functions(comp) if self.distributor is not None \
            else ()
        active = [m for m in self.members if m.state == ACTIVE and m.node.alive]
        if not active:
            raise RuntimeError("no active nodes")
        if self.batch_router is not None:
            by_node = {id(m.node): m for m in active}
            picked = self.batch_router.pick(
                [m.node for m in active], comp, active[0].node.registry,
                load=lambda n: by_node[id(n)].outstanding,
            )
            if picked is not None:
                m = by_node[id(picked)]
                self.stats.record_route(m.node.name, affinity=False,
                                        fns=track)
                self._log(f"route {m.node.name} batch out={m.outstanding}")
                return m.node
        fns = composition_functions(comp)
        pick, kind = self._pick_two_level(active, fns, lambda m: m.outstanding)
        self.stats.record_route(pick.node.name, affinity=(kind == "affinity"),
                                fns=track)
        self._log(f"route {pick.node.name} {kind} out={pick.outstanding}")
        return pick.node

    def place_vertex(
        self,
        fn_name: str,
        home: WorkerNode,
        vload: Callable[[WorkerNode], int],
    ) -> WorkerNode:
        """Vertex-granular routing decision (cross-node compositions): the
        same two-level code-cache-affinity / p2c policy as ``route``,
        scored on the single compute function the ready vertex runs.
        ``vload(node)`` is the placer's count of vertices in flight on a
        node — layered on invocation-level ``outstanding`` so placements
        spread even within one composition. Ties prefer the home node (no
        transfer charge). With a single active node no RNG is consumed
        and the home path is taken (byte-identity with CROSSNODE=0 on
        1-node clusters)."""
        active = [m for m in self.members if m.state == ACTIVE and m.node.alive]
        if not active:
            return home
        if len(active) == 1:
            return active[0].node

        def load(m: ManagedNode) -> int:
            return m.outstanding + vload(m.node)

        pick, kind = self._pick_two_level(active, (fn_name,), load, prefer=home)
        self._log(f"place {fn_name} {pick.node.name} {kind} load={load(pick)}")
        return pick.node

    def on_dispatch(self, node: WorkerNode):
        m = self._by_node[id(node)]
        m.outstanding += 1

    def _foreign_load(self, m: ManagedNode) -> int:
        """Cross-node vertices placed on this node by other homes: work
        the invocation-level ``outstanding`` cannot see, but that must
        block drain/retire just the same."""
        return self.placer.vertex_load(m.node) if self.placer is not None else 0

    def on_complete(self, node: WorkerNode):
        m = self._by_node[id(node)]
        m.outstanding -= 1
        if m.outstanding <= 0:
            m.outstanding = 0
            if self._foreign_load(m) == 0:
                m.idle_since = self.loop.now
                if m.state == DRAINING:
                    self._retire(m, reason="drained")

    def on_vertex_complete(self, node: WorkerNode):
        """Placer notification: the last foreign-placed vertex on ``node``
        finished. Completes a deferred drain and restarts the idle clock
        (placed work must keep a node as alive as homed work)."""
        m = self._by_node.get(id(node))
        if m is None or m.outstanding > 0 or self._foreign_load(m) != 0:
            return
        m.idle_since = self.loop.now
        if m.state == DRAINING:
            self._retire(m, reason="drained")

    # ------------------------------------------------------- autoscaler
    def _ensure_tick(self):
        if not self._ticking:
            self._ticking = True
            self.loop.after(self.cfg.tick_interval_s, self._tick, daemon=True)

    def _tick(self):
        now = self.loop.now
        # reap nodes that died (ClusterManager re-executes their work)
        for m in self.members:
            if m.state in (ACTIVE, DRAINING) and not m.node.alive:
                self._retire(m, reason="failure")

        active = [m for m in self.members if m.state == ACTIVE]
        booting = [m for m in self.members if m.state == BOOTING]

        # ---- scale up: outstanding load or queue delay over threshold
        if active and len(active) + len(booting) < self.cfg.max_nodes:
            per_node = sum(m.outstanding for m in active) / len(active)
            qdelay = max(m.node.queue_delay_s() for m in active)
            if (
                per_node > self.cfg.target_outstanding_per_node
                or qdelay > self.cfg.max_queue_delay_s
            ):
                self._boot_node()

        # ---- scale down (one node per tick at most)
        if len(active) > self.cfg.min_nodes:
            # (a) a node fully idle past keep-alive retires outright
            # (foreign-placed cross-node vertices count as busy work)
            idle = [
                m for m in active
                if m.outstanding == 0 and self._foreign_load(m) == 0
                and now - m.idle_since > self.cfg.keepalive_s
            ]
            if idle:
                idle.sort(key=lambda m: m.idle_since)
                self.drain(idle[0].node)
            else:
                # (b) sustained low utilization: survivors could absorb all
                # work below the watermark -> drain the least-loaded node
                total = sum(m.outstanding for m in active)
                absorbable = (
                    total
                    <= (len(active) - 1)
                    * self.cfg.target_outstanding_per_node
                    * self.cfg.scale_down_watermark
                )
                if not absorbable:
                    self._low_since = None
                elif self._low_since is None:
                    self._low_since = now
                elif now - self._low_since > self.cfg.keepalive_s:
                    victim = min(active, key=lambda m: (
                        m.outstanding + self._foreign_load(m), m.node.name,
                    ))
                    self.drain(victim.node)
                    self._low_since = now
        else:
            self._low_since = None

        self.loop.after(self.cfg.tick_interval_s, self._tick, daemon=True)

    def _on_burst_predicted(self, predicted_t: float):
        """A learned ON edge is ``lead_s`` away: boot ``nodes_ahead``
        nodes now (normal boot path — same RNG-sampled delay, same
        journal/accounting) so they are ACTIVE when the burst lands,
        and optionally pre-spin BATCH replicas on existing nodes."""
        assert self.predictor is not None
        cfg = self.predictor.cfg
        active = sum(1 for m in self.members if m.state == ACTIVE)
        booting = sum(1 for m in self.members if m.state == BOOTING)
        n = min(cfg.nodes_ahead, self.cfg.max_nodes - active - booting)
        self._log(f"predict_burst t={predicted_t:.6f} boots={max(n, 0)}")
        for _ in range(max(n, 0)):
            self._boot_node()
        if cfg.prewarm_replicas and self.replica_autoscaler is not None:
            self.replica_autoscaler.prewarm()

    def on_node_failure(self, node: WorkerNode):
        """Out-of-band failure notification (the periodic tick would also
        reap the dead node, but may not run again if the loop drains)."""
        m = self._by_node.get(id(node))
        if m is not None and m.state in (ACTIVE, DRAINING, BOOTING):
            self._retire(m, reason="failure")

    def drain(self, node: WorkerNode):
        """Stop routing to ``node``; it finishes in-flight work, then
        retires (drain-before-remove)."""
        m = self._by_node[id(node)]
        if m.state != ACTIVE:
            return
        m.state = DRAINING
        self.stats.drains += 1
        self._log(f"drain {m.node.name} out={m.outstanding}")
        if m.outstanding == 0 and self._foreign_load(m) == 0:
            self._retire(m, reason="idle")

    def _retire(self, m: ManagedNode, reason: str):
        if m.state == RETIRED:
            return
        m.state = RETIRED
        m.node.alive = False
        if m.base_committed:
            self.mem.release(m.base_committed)
            m.base_committed = 0
        if reason != "failure":
            self.stats.scale_downs += 1
        self._log(f"retire {m.node.name} reason={reason}")
        self._record_count()

    # ------------------------------------------------------- accounting
    def committed_avg_bytes(self, t_end: Optional[float] = None) -> float:
        """Cluster committed-memory average over [start, t_end]: node base
        footprints plus every node's context memory. O(1): every member
        tracker mirrors into ``cluster_mem`` as events happen."""
        t_end = self.loop.now if t_end is None else t_end
        return self.cluster_mem.timeline.average(t_end)

    def committed_peak_bytes(self) -> float:
        """Exact peak of the merged committed-memory step function,
        maintained streaming by the aggregate tracker (equals
        ``merged_peak`` over the member timelines)."""
        return self.cluster_mem.timeline.peak()

    def summary(self, t_end: Optional[float] = None) -> Dict[str, float]:
        t_end = self.loop.now if t_end is None else t_end
        # refresh per-node counters from node-local caches/trackers
        for m in self.members:
            nc = self.stats.node(m.node.name)
            if m.node.code_cache is not None:
                nc.cache_hits = m.node.code_cache.hits
                nc.cache_misses = m.node.code_cache.misses
            pts = m.node.tracker.timeline.points
            if pts:
                nc.committed_avg_bytes = m.node.tracker.timeline.average(t_end)
        out = self.stats.summary()
        out.update({
            "nodes_avg": self.node_count_timeline.average(t_end),
            "nodes_peak": self.node_count_timeline.peak(),
            "committed_avg_mb": self.committed_avg_bytes(t_end) / 1024**2,
            "committed_peak_mb": self.committed_peak_bytes() / 1024**2,
        })
        return out
