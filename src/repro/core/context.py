"""Memory contexts: bounded arenas with committed-page accounting (SS5).

A context is a contiguous virtual region sized by the user-declared
function memory requirement. Physical commitment is modeled at page
granularity exactly like demand paging: pages are committed on first
write, and the node-level ``MemoryTracker`` integrates committed bytes
over (virtual) time - the quantity Figures 1/10 plot.

``transfer_to`` moves items between contexts (the dispatcher's data
passing; a memcpy here, device-to-device copy for array payloads).
``transfer_ownership`` re-homes a context's committed pages onto a
different node's tracker — cross-node scheduling stages in-flight edge
payloads on the sender and hands the bytes to the receiver when the
modeled wire transfer completes.

Contract / determinism invariants:

  * every committed byte is released exactly once: ``free()`` is
    idempotent, and ``transfer_ownership`` after ``free()`` is a no-op
    (a failed invocation may free a staging context mid-flight);
  * trackers chain (``parent``): child commits/releases mirror upward
    as they happen, so an aggregate (cluster-wide) tracker maintains the
    exact merged step function — and therefore exact peaks — in O(1)
    per event (PR 2's streaming-aggregate invariant, pinned by
    tests/test_sim_fastpath.py);
  * page accounting is purely arithmetic on item ``nbytes``: identical
    writes yield identical committed-byte timelines run to run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.items import Item, ItemSet, SetDict, sets_bytes
from repro.core.sim import Timeline

PAGE = 4096


class MemoryTracker:
    """Node-wide committed-memory accounting over virtual time.

    Trackers chain: a ``parent`` tracker (e.g. the control plane's
    cluster-wide aggregate) observes every commit/release of its children
    as it happens, so the exact merged step function — and therefore the
    cluster peak — is maintained streaming in O(1) per event instead of
    re-merging per-node point lists after the fact."""

    __slots__ = ("loop", "committed", "timeline", "parent")

    def __init__(self, loop=None, parent: "Optional[MemoryTracker]" = None):
        self.loop = loop
        self.committed = 0
        self.timeline = Timeline()
        self.parent = parent
        self._record()

    def _record(self):
        t = self.loop.now if self.loop is not None else 0.0
        self.timeline.record(t, float(self.committed))

    def commit(self, nbytes: int):
        self.committed += nbytes
        self.timeline.record(
            self.loop.now if self.loop is not None else 0.0,
            float(self.committed),
        )
        if self.parent is not None:
            self.parent.commit(nbytes)

    def release(self, nbytes: int):
        self.committed -= nbytes
        self.timeline.record(
            self.loop.now if self.loop is not None else 0.0,
            float(self.committed),
        )
        if self.parent is not None:
            self.parent.release(nbytes)

    def attach_parent(self, parent: "MemoryTracker"):
        """Start mirroring into ``parent``, folding in anything already
        committed so the aggregate stays exact."""
        if self.parent is parent:
            return
        if self.parent is not None:
            raise ValueError("tracker already has a parent")
        self.parent = parent
        if self.committed:
            parent.commit(self.committed)


@dataclass(slots=True)
class MemoryContext:
    """One function's isolated memory region."""

    capacity: int
    tracker: Optional[MemoryTracker] = None
    committed_pages: int = 0
    inputs: SetDict = field(default_factory=dict)
    outputs: SetDict = field(default_factory=dict)
    code_bytes: int = 0
    freed: bool = False

    def _commit_for(self, nbytes: int):
        pages = (nbytes + PAGE - 1) // PAGE
        self.committed_pages += pages
        if self.tracker:
            self.tracker.commit(pages * PAGE)

    @property
    def committed_bytes(self) -> int:
        return self.committed_pages * PAGE

    def load_code(self, code: bytes) -> None:
        self.code_bytes = len(code)
        self._commit_for(len(code))

    def load_code_size(self, nbytes: int) -> None:
        """Commit code memory by size only (modeled fast path: no real
        disk read / memcpy, identical page accounting)."""
        self.code_bytes = nbytes
        self._commit_for(nbytes)

    def write_set(self, name: str, items: ItemSet, into: str = "inputs") -> None:
        store = self.inputs if into == "inputs" else self.outputs
        store.setdefault(name, []).extend(items)
        self._commit_for(sum(i.nbytes for i in items))

    def bulk_load(self, code_nbytes: int, inputs: SetDict) -> None:
        """Modeled cold start: commit the code plus every input set in
        ONE tracker record. Page accounting is identical to
        ``load_code_size`` followed by per-set ``write_set`` calls —
        pages still round per write, then sum — and collapsing the
        same-instant, all-positive commits into a single timeline point
        is observation-identical: the streaming integral terms it
        removes are exact float zeros (``v * 0.0``), and within a
        same-time run of one timeline the positive deltas are monotone,
        so per-node peaks and ``sim.merged_peak`` see the same maximum
        (pinned by tests/test_perf_identity.py)."""
        self.code_bytes = code_nbytes
        pages = (code_nbytes + PAGE - 1) // PAGE
        store = self.inputs
        for name, items in inputs.items():
            prev = store.get(name)
            if prev is None:
                store[name] = list(items)
            else:
                prev.extend(items)
            if len(items) == 1:
                nb = items[0].nbytes
            else:
                nb = sum(i.nbytes for i in items)
            pages += (nb + PAGE - 1) // PAGE
        self.committed_pages += pages
        if self.tracker:
            self.tracker.commit(pages * PAGE)

    def write_sets_bulk(self, sets: SetDict, into: str = "outputs") -> None:
        """Write several sets with one collapsed tracker record (same
        accounting-identity argument as ``bulk_load``)."""
        store = self.outputs if into == "outputs" else self.inputs
        pages = 0
        for name, items in sets.items():
            prev = store.get(name)
            if prev is None:
                store[name] = list(items)
            else:
                prev.extend(items)
            if len(items) == 1:
                nb = items[0].nbytes
            else:
                nb = sum(i.nbytes for i in items)
            pages += (nb + PAGE - 1) // PAGE
        self.committed_pages += pages
        if self.tracker:
            self.tracker.commit(pages * PAGE)

    def read_set(self, name: str, frm: str = "outputs") -> ItemSet:
        store = self.outputs if frm == "outputs" else self.inputs
        return list(store.get(name, []))

    def transfer_to(
        self, other: "MemoryContext", set_name: str, dst_set: str,
        items: Optional[ItemSet] = None,
    ) -> int:
        """Copy items (default: whole output set) into ``other``'s inputs.
        Returns bytes moved (the dispatcher charges transfer time)."""
        payload = items if items is not None else self.read_set(set_name)
        other.write_set(dst_set, payload, into="inputs")
        return sum(i.nbytes for i in payload)

    def transfer_ownership(self, tracker: Optional[MemoryTracker]) -> None:
        """Re-home this context's committed pages onto ``tracker`` (the
        receiving node): released from the current tracker, committed to
        the new one, in the same virtual instant. No-op once freed — a
        failed invocation may free a staging context while its transfer
        task is still in flight, and the bytes must not be re-committed
        (freed-exactly-once invariant)."""
        if self.freed or tracker is self.tracker:
            return
        nbytes = self.committed_bytes
        if self.tracker is not None:
            self.tracker.release(nbytes)
        self.tracker = tracker
        if self.tracker is not None and nbytes:
            self.tracker.commit(nbytes)

    def free(self) -> None:
        if self.freed:
            return
        self.freed = True
        if self.tracker:
            self.tracker.release(self.committed_bytes)
        self.inputs.clear()
        self.outputs.clear()
        self.committed_pages = 0
