"""Dispatcher: composition orchestration within a worker node (SS5, SS6.1).

Tracks pending invocations, function readiness (all input sets fed),
instance fan-out per edge keywords, data movement between contexts,
context deallocation once all consumers have taken a function's outputs,
idempotent re-execution on failure, and hedged backups for stragglers.

Cross-node scheduling hook: when a ``placer`` is attached (see
``cluster.CrossNodePlacer``), every vertex that becomes ready (all input
sets fed — the per-vertex ready-set export) is offered back to the
cluster layer, which may place it on a different node. A remotely placed
vertex runs its instances on that node's engines (and touches that
node's code cache); if any of its inputs were produced on another node,
the placer charges transfer tasks and the vertex waits behind a
*remote-input barrier* (``VertexRun.barrier``) until every transfer
lands, resumed via ``launch_placed``. With no placer attached (the
default), no cross-node code runs and behavior is byte-identical to the
single-node dispatcher.

Contract / determinism invariants:

  * every ``MemoryContext`` created for an invocation — instance
    contexts and cross-node staging contexts alike — is freed exactly
    once, on success, failure, timeout, hedging, and node failure
    (pinned by tests/test_dispatcher_properties.py and
    tests/test_crossnode.py);
  * instance submission order is a pure function of DAG structure and
    arrival order (engine FIFO-per-kind does the rest), so dataflow and
    virtual timelines are byte-stable run to run;
  * cache-miss sampling uses a deterministic golden-ratio Weyl sequence,
    not wall-clock RNG.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.coldstart import CodeCache, ColdStartProfile
from repro.core.dag import (
    COMM, COMPUTE, SUBGRAPH, Composition, Edge, RetryPolicy, Vertex,
)
from repro.core.engines import BATCH, EngineSet, Task, release_task_weights
from repro.core.http import IDEMPOTENT_METHODS, HttpRequest
from repro.core.items import Item, ItemSet, SetDict, group_by_key
from repro.core.registry import FunctionRegistry
from repro.core.sim import EventLoop

# structured failure classes (InvocationRun.failure_kind): what failed,
# independent of the human-readable reason string. The cluster restart
# path keys on FAIL_NODE — never on reason substrings — so a user vertex
# named (or failing with a reason containing) "node_failure" cannot
# trigger bogus restarts.
FAIL_ERROR = "error"            # generic task failure (e.g. sanitization)
FAIL_TIMEOUT = "timeout"
FAIL_NODE = "node_failure"
FAIL_CANCELLED = "cancelled"


@dataclass(slots=True)
class InstanceState:
    idx: int
    inputs: SetDict
    done: bool = False
    outputs: SetDict = field(default_factory=dict)
    # highest attempt number submitted for this instance: hedges carry
    # it (no fresh retry budget), and a failing task older than it is a
    # hedge sibling whose retry is already out (deduped, see
    # _on_task_failed)
    attempts: int = 0


@dataclass(frozen=True)
class VertexTemplate:
    """Invocation-invariant orchestration structure of one vertex,
    precomputed once per composition (``composition_template``) instead of
    being re-derived from edge scans on every invocation — serving-scale
    traces invoke the same composition thousands of times, and the
    per-invoke edge scans dominated the dispatcher's hot path."""

    vertex: Vertex
    in_sets: tuple                    # v.inputs (delivered-dict shape)
    pending_feeds: tuple              # ((set_name, feed_count), ...)
    consumers: int                    # distinct downstream consumer vertices
    fan_edge: Optional[Edge]          # the at-most-one each/key in-edge
    consumed_srcs: tuple              # unique upstream vertex names, in
                                      # first-occurrence in_edges order
    out_feeds: tuple                  # (dst_vertex, dst_set, src_set) per
                                      # out-edge, in edge order
    out_bindings: tuple               # (output_name, src_set) bound here


def composition_template(comp: Composition) -> Dict[str, VertexTemplate]:
    """Per-vertex orchestration templates for ``comp``, cached on the
    composition object and invalidated when its structure grows (vertex /
    edge / binding counts change)."""
    key = (
        len(comp.vertices), len(comp.edges),
        len(comp.input_bindings), len(comp.output_bindings),
    )
    cached = comp.__dict__.get("_dispatch_tmpl")
    if cached is not None and cached[0] == key:
        return cached[1]
    tmpl: Dict[str, VertexTemplate] = {}
    for name, v in comp.vertices.items():
        in_edges = comp.in_edges(name)
        pending = []
        for s in v.inputs:
            feeds = sum(1 for e in in_edges if e.dst.set_name == s)
            feeds += sum(
                1 for p in comp.input_bindings.values()
                if p.vertex == name and p.set_name == s
            )
            pending.append((s, feeds))
        fan = None
        consumed: List[str] = []
        for e in in_edges:
            if fan is None and e.mode in ("each", "key"):
                fan = e
            if e.src.vertex not in consumed:
                consumed.append(e.src.vertex)
        out_edges = comp.out_edges(name)
        tmpl[name] = VertexTemplate(
            vertex=v,
            in_sets=tuple(v.inputs),
            pending_feeds=tuple(pending),
            consumers=len({e.dst.vertex for e in out_edges}),
            fan_edge=fan,
            consumed_srcs=tuple(consumed),
            out_feeds=tuple(
                (e.dst.vertex, e.dst.set_name, e.src.set_name)
                for e in out_edges
            ),
            out_bindings=tuple(
                (out_name, p.set_name)
                for out_name, p in comp.output_bindings.items()
                if p.vertex == name
            ),
        )
    comp.__dict__["_dispatch_tmpl"] = (key, tmpl)
    return tmpl


@dataclass(slots=True)
class VertexRun:
    vertex: Vertex
    tmpl: Optional[VertexTemplate] = None
    delivered: Dict[str, ItemSet] = field(default_factory=dict)
    pending_feeds: Dict[str, int] = field(default_factory=dict)
    launched: bool = False
    instances: List[InstanceState] = field(default_factory=list)
    n_done: int = 0
    outputs: SetDict = field(default_factory=dict)
    contexts: List[Any] = field(default_factory=list)
    consumers_left: int = 0
    done_t: float = 0.0
    # ---- cross-node placement (None/0/empty on the local path)
    exec_node: Any = None           # WorkerNode the placer chose (None=home)
    exec_engines: Any = None        # that node's EngineSet (None=home)
    exec_code_cache: Any = None     # that node's CodeCache
    exec_weights: Any = None        # that node's WeightStore
    barrier: int = 0                # outstanding inbound transfer tasks
    placed_release: Optional[Callable[[], None]] = None  # vload decrement
    # inbound transfer staging contexts: freed at THIS vertex's own
    # completion (its instances copied the bytes), not the consumer-driven
    # lifecycle instance contexts follow — a zero-instance vertex must
    # still release its staged bytes
    staged: List[Any] = field(default_factory=list)
    # nested InvocationRun while a SUBGRAPH vertex is in flight (so
    # cancellation can cascade into it)
    sub_inv: Any = None


@dataclass(slots=True)
class InvocationRun:
    inv_id: int
    comp: Composition
    on_done: Optional[Callable[["InvocationRun"], None]]
    t_start: float
    inputs: SetDict = field(default_factory=dict)
    vertex_runs: Dict[str, VertexRun] = field(default_factory=dict)
    remaining: int = 0
    outputs: SetDict = field(default_factory=dict)
    done: bool = False
    failed: Optional[str] = None
    # structured failure class (FAIL_* above) set alongside ``failed``;
    # the cluster restart path and cancellation bookkeeping key on this,
    # never on reason substrings
    failure_kind: Optional[str] = None
    t_end: float = 0.0
    # live engine tasks by id: registered at submit, dropped at the
    # completion/failure callback. Cancellation marks them cancelled and
    # balances their weight touches; failure flushes the still-queued
    # ones so a dead invocation cannot leak work into live engine slots
    live_tasks: Dict[int, Task] = field(default_factory=dict)
    # back-pointer to the admitting dispatcher (set in Dispatcher.invoke)
    # so handles can route cancel() without knowing the node
    dispatcher: Any = field(default=None, repr=False, compare=False)

    @property
    def latency(self) -> float:
        return self.t_end - self.t_start

    @property
    def cancelled(self) -> bool:
        return self.failure_kind == FAIL_CANCELLED


class Dispatcher:
    def __init__(
        self,
        loop: EventLoop,
        engines: EngineSet,
        registry: FunctionRegistry,
        *,
        profiles: Optional[Dict[str, ColdStartProfile]] = None,
        comm_profile_cpu_only: bool = False,
        max_retries: int = 2,
        default_retry: Optional[RetryPolicy] = None,  # node-level policy
        hedge_after_s: float = 0.0,   # 0 = hedging off
        hedge_min_instances: int = 4,
        cache_miss_rate: float = 0.0,  # fraction of requests loading from disk
        code_cache: Optional["CodeCache"] = None,  # per-node residency model
        placer: Optional[Any] = None,  # cluster.CrossNodePlacer (attached)
        weights: Optional[Any] = None,  # workloads.WeightStore (per node)
    ):
        self.loop = loop
        self.engines = engines
        self.registry = registry
        # keep the caller's dict object (even while empty): platforms
        # share one profiles dict across nodes and populate it at deploy
        self.profiles = {} if profiles is None else profiles
        self.max_retries = max_retries
        self.default_retry = default_retry
        self.hedge_after_s = hedge_after_s
        self.hedge_min_instances = hedge_min_instances
        self.cache_miss_rate = cache_miss_rate
        self.code_cache = code_cache
        self.placer = placer
        self.weights = weights
        self._ids = itertools.count()
        self.completed_count = 0
        self.failed_count = 0
        self.active: Dict[int, InvocationRun] = {}
        self.rng_seq = itertools.count()

    # ----------------------------------------------------- control signals
    @property
    def outstanding(self) -> int:
        """Invocations admitted but not yet completed/failed."""
        return len(self.active)

    def queue_delay_s(self) -> float:
        """Worst queue-wait EWMA across engine types: how long work sits
        before a slot serves it. The elastic control plane's scale-up
        signal (queue growth precedes latency SLO violations). An engine
        kind's EWMA counts only while that kind has queued work - a stale
        EWMA after a drained burst must not keep triggering scale-ups."""
        q = self.engines.queue_lengths()
        return max(
            (self.engines.queue_delay_ewma[k] for k, n in q.items() if n > 0),
            default=0.0,
        )

    # ------------------------------------------------------------------
    def invoke(
        self,
        comp: Composition,
        inputs: SetDict,
        on_done: Optional[Callable[[InvocationRun], None]] = None,
    ) -> InvocationRun:
        tmpl = composition_template(comp)
        inv = InvocationRun(
            inv_id=next(self._ids), comp=comp, on_done=on_done,
            t_start=self.loop.now, inputs=inputs,
            remaining=len(comp.vertices),
            dispatcher=self,
        )
        self.active[inv.inv_id] = inv
        vruns = inv.vertex_runs
        for name, vt in tmpl.items():
            vruns[name] = VertexRun(
                vertex=vt.vertex,
                tmpl=vt,
                delivered={s: [] for s in vt.in_sets},
                pending_feeds=dict(vt.pending_feeds),
                consumers_left=vt.consumers,
            )
        # deliver composition-level inputs
        for in_name, port in comp.input_bindings.items():
            self._feed(inv, port.vertex, port.set_name, inputs.get(in_name, []))
        return inv

    # ------------------------------------------------------------------
    def _feed(self, inv: InvocationRun, vertex: str, set_name: str, items: ItemSet):
        vr = inv.vertex_runs[vertex]
        vr.delivered[set_name].extend(items)
        pf = vr.pending_feeds
        pf[set_name] -= 1
        if vr.launched:
            return
        for c in pf.values():
            if c > 0:
                return
        vr.launched = True
        self._launch(inv, vr)

    # ------------------------------------------------------------------
    def _fan_edge(self, inv: InvocationRun, vr: VertexRun) -> Optional[Edge]:
        if vr.tmpl is not None:
            return vr.tmpl.fan_edge
        for e in inv.comp.in_edges(vr.vertex.name):
            if e.mode in ("each", "key"):
                return e
        return None

    def _make_instances(self, inv: InvocationRun, vr: VertexRun) -> List[InstanceState]:
        fan = self._fan_edge(inv, vr)
        base = dict(vr.delivered)
        if fan is None:
            return [InstanceState(0, base)]
        fan_set = fan.dst.set_name
        fan_items = vr.delivered[fan_set]
        insts = []
        if fan.mode == "each":
            for i, it in enumerate(fan_items):
                d = dict(base)
                d[fan_set] = [it]
                insts.append(InstanceState(i, d))
        else:  # key
            for i, (k, items) in enumerate(sorted(group_by_key(fan_items).items())):
                d = dict(base)
                d[fan_set] = items
                insts.append(InstanceState(i, d))
        if not insts:  # empty fan-out: vertex produces empty outputs
            insts = []
        return insts

    def _launch(self, inv: InvocationRun, vr: VertexRun):
        # upstream contexts can be released once this consumer has copied
        # its inputs (captured in the instance input dicts below); the
        # template's consumed_srcs is already deduped to one entry per
        # (src, this) vertex pair, so each upstream is decremented once
        vruns = inv.vertex_runs
        for src in vr.tmpl.consumed_srcs:
            up = vruns[src]
            up.consumers_left -= 1
            if up.consumers_left == 0 and up.n_done == len(up.instances) and up.instances:
                self._free_vertex_contexts(up)

        if self.placer is not None and self.placer.place(self, inv, vr):
            # inbound cross-node transfers in flight (remote placement, or
            # a home-pinned comm/subgraph vertex pulling remote producers'
            # outputs back): the placer resumes us via launch_placed
            return
        self._launch_ready(inv, vr)

    def launch_placed(self, inv: InvocationRun, vr: VertexRun):
        """Remote-input barrier release: every inbound transfer task for a
        placed vertex has completed; it may now run."""
        if inv.failed:
            return
        self._launch_ready(inv, vr)

    def _launch_ready(self, inv: InvocationRun, vr: VertexRun):
        if vr.vertex.kind == SUBGRAPH:
            self._launch_subgraph(inv, vr)
        else:
            self._launch_instances(inv, vr)

    def _launch_instances(self, inv: InvocationRun, vr: VertexRun):
        tmpl = vr.tmpl
        if tmpl is not None and tmpl.fan_edge is None:
            # no fan-out edge: exactly one instance over the delivered
            # sets (what _make_instances returns, without the dispatch)
            vr.instances = [InstanceState(0, dict(vr.delivered))]
        else:
            vr.instances = self._make_instances(inv, vr)
        if not vr.instances:
            self._vertex_done(inv, vr)
            return
        placer = self.placer
        if (
            placer is not None
            and getattr(placer, "spread_instances", False)
            and len(vr.instances) > 1
            and vr.vertex.kind == COMPUTE
            and vr.exec_engines is None
        ):
            # fan-out spreading: the placer scatters instances across the
            # cluster (vr stays home-anchored; outputs gather back before
            # downstream vertices consume them)
            placer.spread(self, inv, vr)
        else:
            for inst in vr.instances:
                self._submit_instance(inv, vr, inst)
        if (
            self.hedge_after_s > 0
            and len(vr.instances) >= self.hedge_min_instances
        ):
            self.loop.after(self.hedge_after_s, lambda: self._hedge(inv, vr))

    def _launch_subgraph(self, inv: InvocationRun, vr: VertexRun):
        sub = vr.vertex.subgraph

        def sub_done(sub_inv: InvocationRun):
            vr.sub_inv = None
            if sub_inv.failed:
                # propagate the structured kind: a node death inside the
                # nested graph must still reach the cluster restart path
                self._fail(inv, f"{vr.vertex.name}: {sub_inv.failed}",
                           kind=sub_inv.failure_kind or FAIL_ERROR)
                return
            vr.outputs = sub_inv.outputs
            vr.instances = [InstanceState(0, {})]
            vr.n_done = 1
            self._vertex_done(inv, vr, merged=True)

        vr.sub_inv = self.invoke(sub, vr.delivered, on_done=sub_done)

    # ------------------------------------------------------------------
    def _submit_instance(
        self, inv: InvocationRun, vr: VertexRun, inst: InstanceState,
        attempts: int = 0, remote: Optional[Any] = None,
    ) -> Task:
        """Build and submit one instance's engine task. ``remote`` (a
        WorkerNode, set only by the placer's instance spreading) overrides
        the executing engines/caches/weights per *instance* — retries of
        a spread instance fall back to the home node."""
        v = vr.vertex
        kind = COMM if v.kind == COMM else COMPUTE
        if remote is not None:
            engines = remote.engines
        else:
            engines = vr.exec_engines or self.engines
        # batchable compute vertices go to the executing node's batching
        # engine when it models one; platforms without batch slots run
        # them as ordinary compute tasks (identical dataflow, unshared
        # step durations — the batching-off baseline). The probe is
        # "models a batching engine", not "has live replicas": an elastic
        # node (per-fn batch_models) scaled to zero must queue batch work
        # where the replica autoscaler can see it, not leak it onto CPU
        # slots
        if kind == COMPUTE and engines._models_batching():
            cf = self.registry.functions.get(v.function)
            if cf is None:
                cf = self.registry.get(v.function)  # contractual KeyError
            if cf.batchable:
                kind = BATCH
        # remotely placed vertices run on the target node's engines and
        # warm the target node's code cache (locality is per node)
        code_cache = (
            self.code_cache if vr.exec_engines is None else vr.exec_code_cache
        )
        if remote is not None:
            code_cache = remote.code_cache
        cached = True
        if kind != COMM and code_cache is not None:
            cached = code_cache.touch(v.function)
        elif self.cache_miss_rate > 0:
            # deterministic low-discrepancy (golden-ratio Weyl) sequence:
            # misses interleave uniformly across the run instead of the
            # old counter scheme's front-loaded block of misses
            cached = (next(self.rng_seq) * 0.6180339887498949) % 1.0 >= self.cache_miss_rate
        meta = {"inv": inv, "vr": vr, "inst": inst}
        # model-weight residency (workloads.WeightStore) is per executing
        # node, like the code cache; a miss makes the task pay its
        # profile's deterministic cold_setup_s term. The store — not the
        # code-cache bit — is the authority for functions it handles: a
        # code miss must never bill a weight load that is resident
        cold_setup = not cached
        weights = self.weights if vr.exec_engines is None else vr.exec_weights
        if remote is not None:
            weights = remote.weight_store
            meta["engines"] = engines   # failure flush needs the real queue
        if kind != COMM and weights is not None and weights.handles(v.function):
            cold_setup = not weights.touch(v.function)
            meta["wstore"] = weights
        task = Task(
            kind=kind,
            fn_name=v.function if kind != COMM else "http",
            inputs=inst.inputs,
            context_bytes=v.context_bytes,
            profile=self.profiles.get(v.function),
            cached=cached,
            cold_setup=cold_setup,
            batch_units=v.batch_units if kind == BATCH else 1,
            timeout_s=v.timeout_s,
            attempts=attempts,
            meta=meta,
            on_complete=self._on_task_complete,
            on_failed=self._on_task_failed,
        )
        if attempts > inst.attempts:
            inst.attempts = attempts
        inv.live_tasks[id(task)] = task
        engines.submit(task)
        return task

    def _hedge(self, inv: InvocationRun, vr: VertexRun):
        if inv.failed or vr.n_done == len(vr.instances):
            return
        for inst in vr.instances:
            if not inst.done:
                # the backup rides the instance's REAL attempt count: a
                # hedged straggler must not hand its failures a fresh
                # retry budget
                self._submit_instance(inv, vr, inst, attempts=inst.attempts)

    # ------------------------------------------------------------------
    def _on_task_complete(self, task: Task, outputs: SetDict, ctx):
        # weight refcounts are released in the finally, AFTER successor
        # vertices have been fed and submitted (their touch lands first):
        # a back-to-back decode chain keeps its model's inflight count
        # above zero, so weights survive even at keepalive 0
        try:
            inv: InvocationRun = task.meta["inv"]
            vr: VertexRun = task.meta["vr"]
            inst: InstanceState = task.meta["inst"]
            inv.live_tasks.pop(id(task), None)
            if inv.failed or inst.done:  # hedge loser or dead invocation
                ctx.free()
                return
            inst.done = True
            inst.outputs = outputs
            vr.contexts.append(ctx)
            vr.n_done += 1
            if vr.n_done == len(vr.instances):
                self._vertex_done(inv, vr)
        finally:
            release_task_weights(task)

    def _policy(self, vr: VertexRun) -> RetryPolicy:
        """Effective retry policy: vertex override, else the node-level
        default, else the legacy ``max_retries`` knob (zero backoff,
        timeouts fatal — the historical behavior)."""
        if vr.vertex.retry is not None:
            return vr.vertex.retry
        if self.default_retry is not None:
            return self.default_retry
        return RetryPolicy(max_retries=self.max_retries)

    @staticmethod
    def _comm_idempotent(inst: InstanceState) -> bool:
        """Whether every request payload of a COMM instance is safe to
        re-send. Empty/whitespace payloads carry no method at all — they
        cannot mutate anything, so they count as idempotent (the old
        ``split()[0]`` probe crashed on them instead)."""
        for it in inst.inputs.get("requests", []):
            if not it.data:
                continue
            if isinstance(it.data, HttpRequest):
                method = it.data.method
            else:
                words = str(it.data).split()
                if not words:
                    continue
                method = words[0]
            if method not in IDEMPOTENT_METHODS:
                return False
        return True

    def _on_task_failed(self, task: Task, reason: str):
        # release in the finally: a zero-backoff retry's re-touch must
        # land before this attempt's refcount drops (same rule as
        # _on_task_complete). A backed-off retry re-touches at resubmit
        # time instead — during the wait the task is not in flight, so
        # the weights may legitimately reap and the retry pays the cold
        # term again.
        try:
            inv: InvocationRun = task.meta["inv"]
            vr: VertexRun = task.meta["vr"]
            inst: InstanceState = task.meta["inst"]
            inv.live_tasks.pop(id(task), None)
            if inv.failed or inst.done:
                return
            if task.attempts < inst.attempts:
                # hedge sibling of an attempt that already failed and
                # re-armed: its retry is out — don't double-retry
                return
            kind = FAIL_TIMEOUT if reason == "timeout" else FAIL_ERROR
            policy = self._policy(vr)
            idempotent = (
                self._comm_idempotent(inst) if vr.vertex.kind == COMM
                else True
            )
            if (
                idempotent
                and task.attempts < policy.max_retries
                and policy.retryable(kind)
            ):
                next_attempts = task.attempts + 1
                delay = policy.backoff_s(task.attempts)
                if delay <= 0.0:
                    # synchronous resubmit: the historical event ordering
                    # (an after(0) round-trip through the heap would run
                    # behind events already queued at this instant)
                    self._submit_instance(inv, vr, inst,
                                          attempts=next_attempts)
                else:
                    inst.attempts = next_attempts  # dedupe while waiting

                    def resubmit():
                        if inv.failed or inst.done:
                            return
                        self._submit_instance(inv, vr, inst,
                                              attempts=next_attempts)

                    self.loop.after(delay, resubmit)
            elif reason == "timeout":
                self._fail(inv, f"{vr.vertex.name}: timeout (preempted)",
                           kind=FAIL_TIMEOUT)
            else:
                self._fail(
                    inv,
                    f"{vr.vertex.name}: {reason}"
                    + ("" if idempotent else " (not idempotent; not retried)"),
                    kind=kind,
                )
        finally:
            release_task_weights(task)

    # ------------------------------------------------------------------
    def _vertex_done(self, inv: InvocationRun, vr: VertexRun, merged: bool = False):
        if not merged:
            insts = vr.instances
            if len(insts) == 1:
                # single-instance fast path (every non-fanned vertex):
                # instance output lists are per-invocation already —
                # fresh from the function body or shallow-copied by the
                # payload memo — so they can be taken without re-copying
                io = insts[0].outputs
                vr.outputs = {s: io.get(s) or [] for s in vr.vertex.outputs}
            else:
                vr.outputs = {}
                for s in vr.vertex.outputs:
                    vr.outputs[s] = []
                    for inst in insts:
                        vr.outputs[s].extend(inst.outputs.get(s, []))
        vr.done_t = self.loop.now
        if vr.placed_release is not None:
            vr.placed_release()
            vr.placed_release = None
        if vr.staged:
            for c in vr.staged:
                c.free()
            vr.staged = []

        tmpl = vr.tmpl
        for dst_vertex, dst_set, src_set in tmpl.out_feeds:
            self._feed(inv, dst_vertex, dst_set, vr.outputs[src_set])
        for out_name, src_set in tmpl.out_bindings:
            inv.outputs[out_name] = vr.outputs[src_set]
        if vr.consumers_left <= 0:
            self._free_vertex_contexts(vr)

        inv.remaining -= 1
        if inv.remaining == 0 and not inv.failed:
            inv.done = True
            inv.t_end = self.loop.now
            self.completed_count += 1
            self.active.pop(inv.inv_id, None)
            if inv.on_done:
                inv.on_done(inv)

    def _free_vertex_contexts(self, vr: VertexRun):
        for c in vr.contexts:
            c.free()
        vr.contexts = []

    def _fail(self, inv: InvocationRun, reason: str,
              kind: str = FAIL_ERROR):
        if inv.failed:
            return
        inv.failed = reason
        inv.failure_kind = kind
        self.failed_count += 1
        inv.t_end = self.loop.now
        self.active.pop(inv.inv_id, None)
        # flush still-QUEUED sibling tasks: a dead invocation must not
        # leak its pending work into live engine slots (in-flight tasks
        # keep their already-charged busy time; their callbacks observe
        # inv.failed and release through the normal path)
        for task in list(inv.live_tasks.values()):
            engines = (task.meta.get("engines")          # spread instance
                       or task.meta["vr"].exec_engines or self.engines)
            if id(task) not in engines.inflight_tasks:
                task.cancelled = True
                release_task_weights(task)
                inv.live_tasks.pop(id(task), None)
        # release whatever is still held
        for vr in inv.vertex_runs.values():
            if vr.placed_release is not None:
                vr.placed_release()
                vr.placed_release = None
            for c in vr.staged:
                c.free()
            vr.staged = []
            self._free_vertex_contexts(vr)
        if inv.on_done:
            inv.on_done(inv)

    # ------------------------------------------------------------------
    def cancel(self, inv: InvocationRun) -> bool:
        """Cancel a live invocation. Flushes its queued vertices, marks
        every live engine task ``cancelled`` (queued tasks are skipped at
        dispatch; in-flight tasks free their context without firing a
        callback), balances each task's weight touch exactly once,
        cascades into nested subgraph invocations, and fails the
        invocation with kind ``FAIL_CANCELLED`` — which the cluster never
        restarts. Returns False if the invocation already finished."""
        if inv.done or inv.failed:
            return False
        for vr in inv.vertex_runs.values():
            sub = vr.sub_inv
            if sub is not None and not sub.done and not sub.failed:
                self.cancel(sub)
            for inst in vr.instances:
                inst.done = True   # suppress straggling completions
        # in-flight cancelled tasks never reach a callback, so their
        # weight touch is balanced here (idempotent via the meta pop:
        # callbacks that DO fire release nothing twice)
        for task in list(inv.live_tasks.values()):
            task.cancelled = True
            release_task_weights(task)
        inv.live_tasks.clear()
        self._fail(inv, "cancelled", kind=FAIL_CANCELLED)
        return True
