"""Latency / memory instrumentation shared by benchmarks and tests."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class LatencyStats:
    samples: List[float] = field(default_factory=list)

    def add(self, seconds: float):
        self.samples.append(seconds)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), p))

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0

    @property
    def relative_variance(self) -> float:
        """Variance / mean^2 in percent (the paper's SS7.6 metric)."""
        if len(self.samples) < 2 or self.mean == 0:
            return 0.0
        return float(np.var(self.samples) / self.mean**2) * 100.0

    def summary(self) -> Dict[str, float]:
        return {
            "n": len(self.samples),
            "mean_ms": self.mean * 1e3,
            "p50_ms": self.p50 * 1e3,
            "p95_ms": self.p95 * 1e3,
            "p99_ms": self.p99 * 1e3,
            "rel_var_pct": self.relative_variance,
        }
