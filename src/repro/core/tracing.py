"""Latency / memory instrumentation shared by benchmarks and tests."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class LatencyStats:
    samples: List[float] = field(default_factory=list)

    def add(self, seconds: float):
        self.samples.append(seconds)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), p))

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0

    @property
    def relative_variance(self) -> float:
        """Variance / mean^2 in percent (the paper's SS7.6 metric)."""
        if len(self.samples) < 2 or self.mean == 0:
            return 0.0
        return float(np.var(self.samples) / self.mean**2) * 100.0

    def summary(self) -> Dict[str, float]:
        return {
            "n": len(self.samples),
            "mean_ms": self.mean * 1e3,
            "p50_ms": self.p50 * 1e3,
            "p95_ms": self.p95 * 1e3,
            "p99_ms": self.p99 * 1e3,
            "rel_var_pct": self.relative_variance,
        }


# ===========================================================================
# Simulator wall-clock throughput (the BENCH_simperf.json trajectory)
# ===========================================================================
@dataclass
class ThroughputStats:
    """Events processed per wall-clock second for one simulator segment
    (trace events injected vs. real seconds spent in the event loop)."""

    name: str
    events: int = 0
    wall_s: float = 0.0

    @property
    def events_per_sec(self) -> float:
        return self.events / self.wall_s if self.wall_s > 0 else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "name": self.name,
            "events": self.events,
            "wall_s": self.wall_s,
            "events_per_sec": self.events_per_sec,
        }


# ===========================================================================
# Control-plane instrumentation (Dirigent-style routing + autoscaling)
# ===========================================================================
@dataclass
class NodeCounters:
    """Per-node routing/cache/memory counters the control plane exports."""

    name: str
    routed: int = 0            # invocations this node received
    affinity_routed: int = 0   # ...of which via code-cache affinity
    cache_hits: int = 0
    cache_misses: int = 0
    committed_avg_bytes: float = 0.0

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "node": self.name,
            "routed": self.routed,
            "affinity_routed": self.affinity_routed,
            "cache_hit_rate": self.cache_hit_rate,
            "committed_avg_mb": self.committed_avg_bytes / 1024**2,
        }


@dataclass
class RoutingStats:
    """Cluster-wide routing-decision and scaling-event counters."""

    affinity_hits: int = 0     # routed to a node with warm code cache
    spillover: int = 0         # load-aware fallback (power-of-two-choices)
    scale_ups: int = 0
    scale_downs: int = 0
    drains: int = 0            # nodes that drained in-flight work first
    per_node: Dict[str, NodeCounters] = field(default_factory=dict)

    def node(self, name: str) -> NodeCounters:
        if name not in self.per_node:
            self.per_node[name] = NodeCounters(name)
        return self.per_node[name]

    def record_route(self, node_name: str, affinity: bool):
        nc = self.node(node_name)
        nc.routed += 1
        if affinity:
            nc.affinity_routed += 1
            self.affinity_hits += 1
        else:
            self.spillover += 1

    def summary(self) -> Dict[str, float]:
        total = self.affinity_hits + self.spillover
        return {
            "routed": total,
            "affinity_hit_rate": self.affinity_hits / total if total else 0.0,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "drains": self.drains,
        }


# ===========================================================================
# Cross-node transfer instrumentation (per-link comm-engine charging)
# ===========================================================================
@dataclass
class LinkCounters:
    """One directed inter-node link's transfer accounting."""

    src: str
    dst: str
    transfers: int = 0
    bytes_total: int = 0
    cpu_s: float = 0.0      # sender comm-slot CPU charged
    wire_s: float = 0.0     # modeled latency + bytes/bandwidth time

    def row(self) -> Dict[str, float]:
        return {
            "src": self.src,
            "dst": self.dst,
            "transfers": self.transfers,
            "bytes_total": self.bytes_total,
            "cpu_ms": self.cpu_s * 1e3,
            "wire_ms": self.wire_s * 1e3,
        }


@dataclass
class TransferStats:
    """Cross-node placement + transfer counters (``CrossNodePlacer``).

    One ``LinkCounters`` per directed (src, dst) node pair; every edge of
    a composition whose producer and consumer vertices executed on
    different nodes is charged exactly one transfer task (the invariant
    tests/test_crossnode.py pins down)."""

    local_placements: int = 0    # vertices kept on the routed home node
    remote_placements: int = 0   # vertices placed on a different node
    links: Dict[Tuple[str, str], LinkCounters] = field(default_factory=dict)

    def link(self, src: str, dst: str) -> LinkCounters:
        key = (src, dst)
        if key not in self.links:
            self.links[key] = LinkCounters(src, dst)
        return self.links[key]

    def record_transfer(self, src: str, dst: str, nbytes: int,
                        cpu_s: float, wire_s: float):
        lc = self.link(src, dst)
        lc.transfers += 1
        lc.bytes_total += nbytes
        lc.cpu_s += cpu_s
        lc.wire_s += wire_s

    @property
    def transfers(self) -> int:
        return sum(lc.transfers for lc in self.links.values())

    @property
    def bytes_total(self) -> int:
        return sum(lc.bytes_total for lc in self.links.values())

    def summary(self) -> Dict[str, float]:
        placed = self.local_placements + self.remote_placements
        return {
            "placements": placed,
            "remote_placement_rate": (
                self.remote_placements / placed if placed else 0.0
            ),
            "transfers": self.transfers,
            "transfer_mb": self.bytes_total / 1024**2,
            "links": len(self.links),
        }
