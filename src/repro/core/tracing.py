"""Latency / memory instrumentation shared by benchmarks and tests."""
from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass, field
from typing import Dict, IO, List, Optional, Tuple

import numpy as np


@dataclass
class LatencyStats:
    samples: List[float] = field(default_factory=list)

    def add(self, seconds: float):
        self.samples.append(seconds)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), p))

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0

    @property
    def relative_variance(self) -> float:
        """Variance / mean^2 in percent (the paper's SS7.6 metric)."""
        if len(self.samples) < 2 or self.mean == 0:
            return 0.0
        return float(np.var(self.samples) / self.mean**2) * 100.0

    def summary(self) -> Dict[str, float]:
        return {
            "n": len(self.samples),
            "mean_ms": self.mean * 1e3,
            "p50_ms": self.p50 * 1e3,
            "p95_ms": self.p95 * 1e3,
            "p99_ms": self.p99 * 1e3,
            "rel_var_pct": self.relative_variance,
        }


class StreamingPercentile:
    """O(1)-memory single-quantile estimator (the P² algorithm of Jain &
    Chlamtac): five markers track the running quantile without retaining
    samples, so trace-scale runs can publish live percentiles without the
    O(n) sample lists ``LatencyStats`` keeps.

    Deterministic: the estimate is a pure function of the sample
    sequence. Exact while ``n <= 5``; afterwards a parabolic
    interpolation whose error tests/test_perf_identity.py bounds against
    ``np.percentile`` on the distributions the benchmarks draw."""

    __slots__ = ("p", "n", "_q", "_pos", "_want")

    def __init__(self, p: float):
        if not 0.0 < p < 100.0:
            raise ValueError("percentile must be in (0, 100)")
        self.p = p
        self.n = 0
        self._q: List[float] = []            # marker heights
        self._pos: List[float] = []          # marker positions (1-based)
        self._want: List[float] = []         # desired positions

    def add(self, x: float) -> None:
        q, n = self._q, self.n
        self.n = n + 1
        if n < 5:
            q.append(x)
            q.sort()
            if self.n == 5:
                p = self.p / 100.0
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._want = [1.0, 1 + 2 * p, 1 + 4 * p, 3 + 2 * p, 5.0]
            return
        pos, want = self._pos, self._want
        # which cell the new sample lands in; extremes clamp markers
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = next(i for i in range(4) if q[i] <= x < q[i + 1])
        for i in range(k + 1, 5):
            pos[i] += 1.0
        p = self.p / 100.0
        inc = (0.0, p / 2, p, (1 + p) / 2, 1.0)
        for i in range(5):
            want[i] += inc[i]
        # nudge interior markers toward their desired positions
        for i in range(1, 4):
            d = want[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
               (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                d = 1.0 if d > 0 else -1.0
                qi = self._parabolic(i, d)
                if q[i - 1] < qi < q[i + 1]:
                    q[i] = qi
                else:               # parabolic fit left the bracket
                    q[i] = q[i] + d * (q[i + int(d)] - q[i]) / (
                        pos[i + int(d)] - pos[i]
                    )
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        q, n = self._q, self._pos
        return q[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1])
        )

    @property
    def value(self) -> float:
        if self.n == 0:
            return 0.0
        if self.n <= 5:
            return float(np.percentile(np.asarray(self._q), self.p))
        return self._q[2]


class LiveTelemetry:
    """Incremental metrics publisher in server-sent-events framing.

    Benchmarks run their measurement window in chunks (``platform.run
    (until=t_k)`` checkpoints driven from *outside* the loop — never as
    in-loop daemon events, which would consume sequence numbers and
    break the byte-identity contract) and publish one snapshot per
    checkpoint:

        event: <stream>
        data: {"t": ..., "p50_ttft_ms": ..., "committed_mb": ...}

    The wire format is the standard ``text/event-stream`` one, so the
    emitted file replays through any SSE consumer (or plain ``grep
    '^data:' | jq``). Payload keys are sorted and floats rounded to six
    significant digits, so a telemetry stream from a deterministic run
    is itself deterministic."""

    def __init__(self, sink: IO[str], stream: str = "telemetry"):
        self.sink = sink
        self.stream = stream
        self.events = 0

    @classmethod
    def from_env(cls, var: str, stream: str = "telemetry"
                 ) -> "Optional[LiveTelemetry]":
        """A publisher per the env knob ``var``: unset/empty -> None
        (telemetry off, the default); ``-`` -> stderr; anything else is
        a path to (over)write."""
        dest = os.environ.get(var, "")
        if not dest:
            return None
        if dest == "-":
            return cls(sys.stderr, stream=stream)
        d = os.path.dirname(dest)
        if d:
            os.makedirs(d, exist_ok=True)
        return cls(open(dest, "w"), stream=stream)

    @staticmethod
    def _round(v):
        if isinstance(v, float):
            return float(f"{v:.6g}")
        return v

    def emit(self, payload: Dict[str, object]) -> None:
        body = json.dumps({k: self._round(v) for k, v in payload.items()},
                          sort_keys=True)
        self.sink.write(f"event: {self.stream}\ndata: {body}\n\n")
        self.sink.flush()
        self.events += 1

    def close(self) -> None:
        if self.sink not in (sys.stdout, sys.stderr):
            self.sink.close()


# ===========================================================================
# Simulator wall-clock throughput (the BENCH_simperf.json trajectory)
# ===========================================================================
@dataclass
class ThroughputStats:
    """Events processed per wall-clock second for one simulator segment
    (trace events injected vs. real seconds spent in the event loop)."""

    name: str
    events: int = 0
    wall_s: float = 0.0

    @property
    def events_per_sec(self) -> float:
        return self.events / self.wall_s if self.wall_s > 0 else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "name": self.name,
            "events": self.events,
            "wall_s": self.wall_s,
            "events_per_sec": self.events_per_sec,
        }


# ===========================================================================
# Control-plane instrumentation (Dirigent-style routing + autoscaling)
# ===========================================================================
@dataclass
class NodeCounters:
    """Per-node routing/cache/memory counters the control plane exports."""

    name: str
    routed: int = 0            # invocations this node received
    affinity_routed: int = 0   # ...of which via code-cache affinity
    cache_hits: int = 0
    cache_misses: int = 0
    committed_avg_bytes: float = 0.0

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "node": self.name,
            "routed": self.routed,
            "affinity_routed": self.affinity_routed,
            "cache_hit_rate": self.cache_hit_rate,
            "committed_avg_mb": self.committed_avg_bytes / 1024**2,
        }


@dataclass
class RoutingStats:
    """Cluster-wide routing-decision and scaling-event counters."""

    affinity_hits: int = 0     # routed to a node with warm code cache
    spillover: int = 0         # load-aware fallback (power-of-two-choices)
    scale_ups: int = 0
    scale_downs: int = 0
    drains: int = 0            # nodes that drained in-flight work first
    per_node: Dict[str, NodeCounters] = field(default_factory=dict)
    fn_routed: Dict[str, int] = field(default_factory=dict)  # popularity

    def node(self, name: str) -> NodeCounters:
        if name not in self.per_node:
            self.per_node[name] = NodeCounters(name)
        return self.per_node[name]

    def record_route(self, node_name: str, affinity: bool, fns=()):
        nc = self.node(node_name)
        nc.routed += 1
        if affinity:
            nc.affinity_routed += 1
            self.affinity_hits += 1
        else:
            self.spillover += 1
        for fn in fns:
            self.fn_routed[fn] = self.fn_routed.get(fn, 0) + 1

    def hot_functions(self, k: int) -> List[str]:
        """Top-``k`` most-routed functions — the P2P distributor's "what
        is hot" feed. Deterministic: count descending, name ascending on
        ties."""
        ranked = sorted(self.fn_routed.items(), key=lambda kv: (-kv[1], kv[0]))
        return [fn for fn, _ in ranked[:k]]

    def summary(self) -> Dict[str, float]:
        total = self.affinity_hits + self.spillover
        return {
            "routed": total,
            "affinity_hit_rate": self.affinity_hits / total if total else 0.0,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "drains": self.drains,
        }


# ===========================================================================
# Cross-node transfer instrumentation (per-link comm-engine charging)
# ===========================================================================
@dataclass
class LinkCounters:
    """One directed inter-node link's transfer accounting."""

    src: str
    dst: str
    transfers: int = 0
    bytes_total: int = 0
    cpu_s: float = 0.0      # sender comm-slot CPU charged
    wire_s: float = 0.0     # modeled latency + bytes/bandwidth time

    def row(self) -> Dict[str, float]:
        return {
            "src": self.src,
            "dst": self.dst,
            "transfers": self.transfers,
            "bytes_total": self.bytes_total,
            "cpu_ms": self.cpu_s * 1e3,
            "wire_ms": self.wire_s * 1e3,
        }


@dataclass
class TransferStats:
    """Cross-node placement + transfer counters (``CrossNodePlacer``).

    One ``LinkCounters`` per directed (src, dst) node pair; every edge of
    a composition whose producer and consumer vertices executed on
    different nodes is charged exactly one transfer task (the invariant
    tests/test_crossnode.py pins down)."""

    local_placements: int = 0    # vertices kept on the routed home node
    remote_placements: int = 0   # vertices placed on a different node
    links: Dict[Tuple[str, str], LinkCounters] = field(default_factory=dict)

    def link(self, src: str, dst: str) -> LinkCounters:
        key = (src, dst)
        if key not in self.links:
            self.links[key] = LinkCounters(src, dst)
        return self.links[key]

    def record_transfer(self, src: str, dst: str, nbytes: int,
                        cpu_s: float, wire_s: float):
        lc = self.link(src, dst)
        lc.transfers += 1
        lc.bytes_total += nbytes
        lc.cpu_s += cpu_s
        lc.wire_s += wire_s

    @property
    def transfers(self) -> int:
        return sum(lc.transfers for lc in self.links.values())

    @property
    def bytes_total(self) -> int:
        return sum(lc.bytes_total for lc in self.links.values())

    def summary(self) -> Dict[str, float]:
        placed = self.local_placements + self.remote_placements
        return {
            "placements": placed,
            "remote_placement_rate": (
                self.remote_placements / placed if placed else 0.0
            ),
            "transfers": self.transfers,
            "transfer_mb": self.bytes_total / 1024**2,
            "links": len(self.links),
        }
