# det-lint: file waive[wall-clock] reason=real-exec CLI driver; wall time measures actual training steps, not a modeled path
"""End-to-end training driver.

Runs real steps on the host devices (CPU here; the same code path drives
a TPU slice - only the mesh changes). Includes the full fault-tolerance
loop: async checkpointing every ``--ckpt-every`` steps, automatic restore
from the latest checkpoint at startup, and bitwise-resumable data order.

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-8b --smoke --steps 50 --batch 8 --seq 256
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.parallel import ParallelPlan
from repro.config.shapes import ShapeConfig
from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.launch.mesh import make_mesh
from repro.models.model import build
from repro.sharding.rules import batch_sharding, param_shardings, replicated
from repro.training.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.training.data import PrefetchingLoader, make_batch
from repro.training.train_step import (
    abstract_train_state,
    build_train_step,
    init_train_state,
    make_train_state_specs,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    api = build(cfg)
    ndev = len(jax.devices())
    mesh = make_mesh((ndev, 1), ("data", "model"))
    plan = ParallelPlan(
        remat=args.remat,
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
        zero3=ndev > 1,
    ).restrict_to(mesh.axis_names)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch, kind="train")

    print(f"arch={cfg.name} params={api.param_count()/1e6:.1f}M devices={ndev}")

    step_fn = build_train_step(api, plan, lr=args.lr, total_steps=args.steps)
    abstract, state_sh = make_train_state_specs(api, plan, mesh)
    jitted = jax.jit(step_fn, donate_argnums=(0,))

    start_step = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start_step = restore_checkpoint(args.ckpt_dir, None, abstract)
        print(f"restored checkpoint at step {start_step}")
    else:
        state = init_train_state(api, jax.random.PRNGKey(args.seed), plan)

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    loader = PrefetchingLoader(
        cfg, shape, start_step=start_step,
        num_steps=args.steps - start_step, seed=args.seed,
    )

    t0 = time.time()
    tokens_done = 0
    for step, host_batch in loader:
        batch = jax.tree_util.tree_map(jnp.asarray, host_batch)
        if cfg.dtype == "bfloat16":
            for k in ("frames", "patches"):
                if k in batch:
                    batch[k] = batch[k].astype(jnp.bfloat16)
        state, metrics = jitted(state, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = time.time() - t0
            print(
                f"step {step+1:5d} loss {loss:7.4f} grad_norm {gn:8.3f} "
                f"tok/s {tokens_done/dt:,.0f}"
            )
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.close()
        print(f"final checkpoint at {args.ckpt_dir}")
    print("training done")


if __name__ == "__main__":
    main()
