"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state - the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and everything else must see the real single-device CPU.
"""
from __future__ import annotations

import jax
import numpy as np


def _auto(n):
    from jax.sharding import AxisType

    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests (e.g. (2,2) on 4 forced host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=_auto(len(axes)))


def mesh_devices(mesh) -> int:
    return int(np.prod(mesh.devices.shape))
