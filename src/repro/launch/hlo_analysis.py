"""Post-SPMD HLO analysis: collective-byte accounting + roofline terms.

``cost_analysis()`` does not report collective traffic, so we parse the
optimized HLO text: every instruction's output shape gives a name->bytes
map; collective instructions then contribute their operand/output bytes.

Per-chip link-traffic model (ring schedules on a 2D/3D torus):
    all-reduce        2 x bytes   (reduce-scatter + all-gather phases)
    all-gather        1 x output bytes
    reduce-scatter    1 x operand bytes
    all-to-all        1 x operand bytes
    collective-permute 1 x operand bytes
The assignment-literal term (sum of operand sizes / (chips x link_bw)) is
reported alongside; the ring-model term is used for bottleneck calls.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# all-gather-start, all-reduce-start etc. (async) share the prefix match.


def _shape_bytes(type_str: str) -> float:
    """Bytes of one HLO type string (handles tuples)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, num_devices: int) -> int:
    """Parse replica_groups to the participant count per group."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return num_devices


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    operand_bytes: Dict[str, float] = field(default_factory=dict)
    output_bytes: Dict[str, float] = field(default_factory=dict)
    link_bytes: Dict[str, float] = field(default_factory=dict)
    group_sizes: Dict[str, List[int]] = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())

    def to_json(self) -> dict:
        return {
            "counts": self.counts,
            "operand_bytes": self.operand_bytes,
            "output_bytes": self.output_bytes,
            "link_bytes": self.link_bytes,
            "total_operand_bytes": self.total_operand_bytes,
            "total_link_bytes": self.total_link_bytes,
        }


def parse_collectives(hlo_text: str, num_devices: int = 1) -> CollectiveStats:
    """Scan optimized HLO for collective ops and account their bytes."""
    from repro.launch.hlo_counter import split_rhs

    # pass 1: name -> output bytes (tuple-typed outputs handled by split_rhs)
    sizes: Dict[str, float] = {}
    parsed = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1).lstrip("%"), m.group(2)
        type_str, opcode, operands, _ = split_rhs(rhs)
        sizes[name] = _shape_bytes(type_str)
        parsed.append((name, opcode, operands, line))

    stats = CollectiveStats()
    for name, op, operand_names, line in parsed:
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        ob = sum(sizes.get(o, 0.0) for o in operand_names)
        out_b = sizes.get(name, 0.0)
        gs = _group_size(line, num_devices)

        stats.counts[base] = stats.counts.get(base, 0) + 1
        stats.operand_bytes[base] = stats.operand_bytes.get(base, 0.0) + ob
        stats.output_bytes[base] = stats.output_bytes.get(base, 0.0) + out_b
        stats.group_sizes.setdefault(base, []).append(gs)
        if base == "all-reduce":
            link = 2.0 * out_b * max(0, gs - 1) / max(1, gs)
        elif base == "all-gather":
            link = out_b * max(0, gs - 1) / max(1, gs)
        elif base == "reduce-scatter":
            link = ob * max(0, gs - 1) / max(1, gs)
        else:  # all-to-all / collective-permute
            link = ob
        stats.link_bytes[base] = stats.link_bytes.get(base, 0.0) + link
    return stats


@dataclass
class RooflineTerms:
    """Three-term roofline for one compiled (arch x shape x mesh) cell.

    All *_s values are seconds for one step execution on the target HW.
    FLOPs/bytes from cost_analysis are per-device (the SPMD module);
    global = per_device x chips.
    """

    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_link_bytes_per_device: float
    collective_operand_bytes_per_device: float
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    model_flops: float = 0.0  # 6*N*D useful-compute reference

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_link_bytes_per_device / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        if self.model_flops <= 0:
            return 0.0
        return self.model_flops / (self.flops_per_device * self.chips)

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips x peak x roofline step time)."""
        t = self.step_time_s
        if t <= 0 or self.model_flops <= 0:
            return 0.0
        return self.model_flops / (self.chips * self.peak_flops * t)

    def to_json(self) -> dict:
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_link_bytes_per_device": self.collective_link_bytes_per_device,
            "collective_operand_bytes_per_device": self.collective_operand_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu": self.mfu,
        }


# ===========================================================================
# Serving cost models (serving-on-Dandelion: weight cold start + step terms)
# ===========================================================================
def count_hlo_ops(hlo_text: str) -> int:
    """Instruction count of an (optimized) HLO module — the compile-time
    proxy ``weight_coldstart_estimate`` consumes: XLA compile latency is
    dominated by per-instruction passes, so seconds-per-op over the op
    count is a serviceable first-order model."""
    return sum(1 for line in hlo_text.splitlines() if _DEF_RE.match(line))


@dataclass(frozen=True)
class WeightColdStart:
    """Model-weight cold-start terms for a serving function.

    The FaaSNet observation (PAPERS.md): for inference functions the
    dominant provisioning cost is not the sandbox but moving and
    preparing the model — reading ``param_bytes`` from the code store
    (disk / object storage) plus (re)building the executable, priced
    from the HLO instruction count. ``total_s`` feeds the function's
    ``ColdStartProfile.cold_setup_s``, charged only when the executing
    node does not already hold the weights (``core.workloads.WeightStore``).
    """

    param_bytes: float
    disk_bandwidth_bps: float = 2e9        # NVMe-class read rate
    hlo_ops: int = 0
    compile_s_per_op: float = 2e-3         # XLA pass cost per instruction

    @property
    def load_s(self) -> float:
        return self.param_bytes / self.disk_bandwidth_bps

    @property
    def compile_s(self) -> float:
        return self.hlo_ops * self.compile_s_per_op

    @property
    def total_s(self) -> float:
        return self.load_s + self.compile_s


def weight_coldstart_estimate(
    param_bytes: float,
    *,
    hlo_text: Optional[str] = None,
    hlo_ops: Optional[int] = None,
    disk_bandwidth_bps: float = 2e9,
    compile_s_per_op: float = 2e-3,
) -> WeightColdStart:
    """Build a ``WeightColdStart`` from either a real optimized-HLO dump
    (``hlo_text``, counted with ``count_hlo_ops``) or a caller-supplied
    op-count estimate (e.g. layers x ops-per-layer for configs too big
    to lower on this host)."""
    ops = count_hlo_ops(hlo_text) if hlo_text is not None else int(hlo_ops or 0)
    return WeightColdStart(
        param_bytes=param_bytes,
        disk_bandwidth_bps=disk_bandwidth_bps,
        hlo_ops=ops,
        compile_s_per_op=compile_s_per_op,
    )


def serving_step_terms(
    *,
    param_bytes: float,
    flops_per_seq: float,
    kv_bytes_per_seq: float,
    batch: int,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float = 1.0,
    chips: int = 1,
) -> RooflineTerms:
    """Roofline terms for ONE decode (or prefill) step over ``batch``
    co-resident sequences on one replica: each sequence adds its own
    FLOPs and KV traffic while the weight read is paid once per step —
    the amortization continuous batching exists to exploit. The
    ``step_time_s`` of the returned terms is what the platform's
    ``core.workloads.BatchStepModel`` reproduces as ``step_s(batch)``
    (minus the per-step overhead floor the platform adds)."""
    return RooflineTerms(
        chips=chips,
        flops_per_device=batch * flops_per_seq,
        hbm_bytes_per_device=param_bytes + batch * kv_bytes_per_seq,
        collective_link_bytes_per_device=0.0,
        collective_operand_bytes_per_device=0.0,
        peak_flops=peak_flops,
        hbm_bw=hbm_bw,
        ici_bw=ici_bw,
        model_flops=batch * flops_per_seq,
    )
