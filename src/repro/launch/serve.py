# det-lint: file waive[wall-clock] reason=real-exec CLI driver; wall time measures actual serving steps, not a modeled path
"""End-to-end serving driver: batched requests through the Dandelion
platform with the continuous-batching LM engine as the compute payload.

Demonstrates the paper's architecture end to end: client requests enter
the node frontend as composition invocations; prefill/decode steps are
registered pure compute functions; the platform cold-starts a context per
request and multiplexes engines under the PI controller.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch granite-8b --smoke --requests 16 --max-new 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models.model import build
from repro.serving.batching import ContinuousBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    api = build(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng)
    print(f"arch={cfg.name} params={api.param_count()/1e6:.1f}M")

    def extras_fn(rid):
        if cfg.family == "encdec":
            return {"frames": jnp.zeros((1, 16, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "vlm":
            return {"patches": jnp.zeros((1, cfg.num_patches or 8, cfg.d_model), jnp.bfloat16)}
        return {}

    batcher = ContinuousBatcher(
        api, params, num_slots=args.slots, cache_len=args.cache_len,
        extras_fn=extras_fn,
    )

    host = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(host.integers(4, min(24, args.cache_len)))
        prompt = host.integers(0, cfg.vocab_size, plen).tolist()
        batcher.submit(Request(rid, prompt, max_new_tokens=args.max_new))
    results = batcher.run_to_completion()
    dt = time.time() - t0

    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid][:10]}")


if __name__ == "__main__":
    main()
