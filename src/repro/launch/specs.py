"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

``input_specs`` returns abstract inputs only - weak-type-correct,
shardable, zero device allocation - exactly what ``jax.jit(...).lower()``
needs for the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.config.shapes import ShapeConfig
from repro.models.model import ModelApi, build


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def extras_for(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    """Modality-frontend stubs: precomputed frame/patch embeddings."""
    if cfg.family == "encdec":
        return {"frames": _sds((batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)}
    if cfg.family == "vlm":
        return {"patches": _sds((batch, cfg.num_patches, cfg.d_model), cfg.dtype)}
    return {}


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((b, s), "int32"),
        "targets": _sds((b, s), "int32"),
    }
    batch.update(extras_for(cfg, b))
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Any, ...]:
    """(tokens, prompt_lens, *extras) for the prefill serve_step."""
    b, s = shape.global_batch, shape.seq_len
    base = (
        _sds((b, s), "int32"),
        _sds((b,), "int32"),
    )
    return base + tuple(extras_for(cfg, b).values())


def decode_input_specs(api: ModelApi, shape: ShapeConfig) -> Tuple[Any, Any]:
    """(cache, tokens) for the single-new-token serve_step.

    The cache covers ``seq_len`` context per the assignment ("one new token
    with a KV cache of seq_len").
    """
    b, s = shape.global_batch, shape.seq_len
    cache = api.abstract_cache(b, s)
    tokens = _sds((b,), "int32")
    return cache, tokens


def input_specs(cfg: ModelConfig, shape: ShapeConfig, api: ModelApi = None):
    """Uniform entry: returns a dict keyed by step-input name."""
    api = api or build(cfg)
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        toks, plens, *extras = prefill_input_specs(cfg, shape)
        out = {"tokens": toks, "prompt_lens": plens}
        for name, v in zip(extras_for(cfg, shape.global_batch), extras):
            out[name] = v
        return out
    cache, tokens = decode_input_specs(api, shape)
    return {"cache": cache, "tokens": tokens}
