"""While-corrected HLO FLOP/byte accounting.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program (all our train steps) under-reports FLOPs by
~num_layers x. This module re-derives compute/memory totals from the
optimized HLO text with loop trip counts applied:

  * The module is split into named computations; a call graph is built
    from ``while`` (body/condition), ``fusion`` (calls=), ``call``
    (to_apply=) edges, and multiplicities are propagated from ENTRY with
    while-trip counts parsed from each loop condition's ROOT compare
    against an integer constant.
  * FLOPs: every ``dot`` contributes 2 * prod(output dims) * prod(lhs
    contracting dims) (batched dims fall out naturally since they appear
    in the output). Elementwise FLOPs are ignored (sub-1% for these
    models).
  * Bytes: per computation, the sum of operand + output bytes over its
    *top-level* instructions only - fusion instructions count as single
    ops (their internals never touch HBM), which models TPU HBM traffic
    far better than the unfused per-op accounting cost_analysis does.

Validated against analytic 6*N*D in tests (within ~15% for dense LMs).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def split_rhs(rhs: str) -> Tuple[str, str, List[str], str]:
    """Split an instruction RHS into (type_str, opcode, operands, attrs).

    Handles tuple-typed outputs: ``(bf16[..], s32[..]) while(%t), body=..``.
    """
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        type_end = len(rhs)
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_end = i + 1
                    break
        type_str, rest = rhs[:type_end], rhs[type_end:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return rhs, "", [], ""
        type_str, rest = rhs[:sp], rhs[sp + 1 :].strip()
    paren = rest.find("(")
    if paren < 0:
        return type_str, rest.strip(), [], ""
    opcode = rest[:paren].strip()
    depth, end = 1, len(rest)
    for i in range(paren + 1, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = re.findall(r"%([\w\.\-]+)", rest[paren + 1 : end])
    attrs = rest[end + 1 :]
    return type_str, opcode, operands, attrs


def _nbytes(shapes: List[Tuple[str, List[int]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    out_shapes: List[Tuple[str, List[int]]]
    operands: List[str]
    rhs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    is_entry: bool = False


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            # computation headers are lines ending in '{' containing '->'
            # (params may contain nested tuple parens and /*index=N*/
            # comments, so match only the leading name)
            if line.endswith("{") and "->" in line:
                m = _COMP_HDR.match(line)
                if m:
                    cur = Computation(
                        m.group(2), is_entry=bool(m.group(1))
                        or line.lstrip().startswith("ENTRY"),
                    )
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        type_str, opcode, operands, _attrs = split_rhs(rhs)
        out_shapes = _shapes_in(type_str)
        cur.instrs.append(Instr(name, opcode, out_shapes, operands, rhs))
    return comps


def _attr_comp(rhs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", rhs)
    return m.group(1) if m else None


def _trip_count(cond: Computation, comps: Optional[Dict[str, "Computation"]] = None) -> int:
    """Trip count from the loop condition's compare-against-constant.

    Handles both a direct ``compare`` ROOT and the common post-optimization
    form where the compare is wrapped in a kLoop fusion
    (``ROOT %wrapped_compare = pred[] fusion(%iter, %const), calls=...``).
    Falls back to the largest integer constant in the condition, which for
    canonical 0..N-1 counted loops is N.
    """
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.rhs)
            if m:
                consts[ins.name] = int(m.group(1))

    def from_compare(ins: Instr) -> Optional[int]:
        vals = [consts[o] for o in ins.operands if o in consts]
        if not vals:
            return None
        direction = re.search(r"direction=(\w+)", ins.rhs)
        d = direction.group(1) if direction else "LT"
        v = max(vals)
        return v + 1 if d == "LE" else v

    direction_hint = "LT"
    for ins in reversed(cond.instrs):
        if ins.opcode == "compare":
            got = from_compare(ins)
            if got is not None:
                return got
        if ins.opcode == "fusion" and comps is not None:
            callee = _attr_comp(ins.rhs, "calls")
            if callee in comps:
                for sub in comps[callee].instrs:
                    if sub.opcode == "compare":
                        m = re.search(r"direction=(\w+)", sub.rhs)
                        if m:
                            direction_hint = m.group(1)
    if consts:
        v = max(consts.values())
        return v + 1 if direction_hint == "LE" else max(v, 1)
    return 1


def _dot_flops(ins: Instr, sizes: Dict[str, List[Tuple[str, List[int]]]]) -> float:
    out_n = 1.0
    for _, dims in ins.out_shapes:
        for d in dims:
            out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    # lhs shape: inline operand type if present, else lookup by name
    type_str, _, _, _ = split_rhs(ins.rhs)
    opseg = ins.rhs[len(type_str) :]
    paren = opseg.find("(")
    inline = _shapes_in(opseg[paren:]) if paren >= 0 else []
    lhs_dims: List[int] = []
    if inline:
        lhs_dims = inline[0][1]
    elif ins.operands:
        got = sizes.get(ins.operands[0])
        if got:
            lhs_dims = got[0][1]
    k = 1.0
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_n * k


_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ops that move no HBM bytes (views, tuple plumbing, metadata)
_FREE_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
})


def _collective_base(opcode: str) -> Optional[str]:
    for c in _COLLECTIVES:
        if opcode == c or opcode == c + "-start":
            return c
    return None


def _group_size(rhs: str, num_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rhs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rhs)
    if m:
        return len(m.group(1).split(","))
    return num_devices


def _merge_coll(dst: Dict[str, List[float]], src: Dict[str, List[float]],
                scale: float = 1.0):
    for k, v in src.items():
        cur = dst.setdefault(k, [0.0, 0.0, 0.0, 0.0])
        for i in range(4):
            cur[i] += v[i] * scale


def _fusion_bytes(
    callee: Computation,
    sizes: Dict[str, List[Tuple[str, List[int]]]],
) -> float:
    """HBM traffic of one fusion execution.

    Reads: each parameter counts at full size UNLESS every consumer inside
    the fusion is a slicing op (then only the slices are read - XLA fuses
    dynamic-slice into the loop body so the full loop-carried stack is
    never touched). Writes: the root output, except dynamic-update-slice
    roots which alias in place and write only the update.

    TPU-faithfulness: XLA *CPU* legalizes bf16 by round-tripping through
    f32 (convert -> dynamic-update-slice -> convert over the whole
    loop-carried KV stack, breaking in-place aliasing). TPUs execute bf16
    natively and alias the DUS, so when the root reduces - through
    convert/bitcast only - to a DUS whose target chain reduces to a
    parameter, the fusion is charged 2 x update bytes (the in-place
    semantics), not the full-stack round trip.
    """
    by_name = {ins.name: ins for ins in callee.instrs}

    def resolve(name: str) -> Optional[Instr]:
        ins = by_name.get(name)
        while ins is not None and ins.opcode in ("convert", "bitcast", "copy"):
            if not ins.operands:
                return ins
            ins = by_name.get(ins.operands[0])
        return ins

    consumers: Dict[str, List[Instr]] = {}
    for ins in callee.instrs:
        for o in ins.operands:
            consumers.setdefault(o, []).append(ins)

    root = callee.instrs[-1] if callee.instrs else None
    aliased_dus = None
    if root is not None:
        r = resolve(root.name)
        if r is not None and r.opcode in ("dynamic-update-slice", "scatter") \
                and r.operands:
            target = resolve(r.operands[0])
            if target is not None and target.opcode == "parameter":
                aliased_dus = (r, target)

    if aliased_dus is not None:
        r, target = aliased_dus
        update = (
            _nbytes(sizes.get(r.operands[1], []))
            if len(r.operands) > 1 else 0.0
        )
        # other parameters still count (e.g. the update value, indices)
        extra = 0.0
        for ins in callee.instrs:
            if ins.opcode == "parameter" and ins.name != target.name:
                extra += min(_nbytes(ins.out_shapes), update or
                             _nbytes(ins.out_shapes))
        return 2.0 * update + extra

    reads = 0.0
    for ins in callee.instrs:
        if ins.opcode != "parameter":
            continue
        cons = consumers.get(ins.name, [])
        if cons and all(
            c.opcode in ("slice", "dynamic-slice", "gather") for c in cons
        ):
            reads += sum(_nbytes(c.out_shapes) for c in cons)
        else:
            reads += _nbytes(ins.out_shapes)
    writes = 0.0
    if root is not None:
        if root.opcode in ("dynamic-update-slice", "scatter") and len(root.operands) > 1:
            writes = _nbytes(sizes.get(root.operands[1], []))
        else:
            writes = _nbytes(root.out_shapes)
    return reads + writes


@dataclass
class CorrectedCosts:
    """While-corrected per-device totals for one compiled module.

    collectives: base-op -> [count, operand_bytes, output_bytes, link_bytes]
    (link bytes use the ring-schedule model; see hlo_analysis).
    """

    flops: float
    hbm_bytes: float
    collectives: Dict[str, List[float]] = field(default_factory=dict)
    warnings: List[str] = field(default_factory=list)

    @property
    def collective_link_bytes(self) -> float:
        return sum(v[3] for v in self.collectives.values())

    @property
    def collective_operand_bytes(self) -> float:
        return sum(v[1] for v in self.collectives.values())

    def collectives_json(self) -> dict:
        return {
            k: {
                "count": v[0], "operand_bytes": v[1],
                "output_bytes": v[2], "link_bytes": v[3],
            }
            for k, v in self.collectives.items()
        }


def corrected_costs(hlo: str, num_devices: int = 1) -> CorrectedCosts:
    comps = parse_module(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return CorrectedCosts(0.0, 0.0, warnings=["no ENTRY computation found"])

    # name -> output shapes (module-wide; HLO names are unique)
    sizes: Dict[str, List[Tuple[str, List[int]]]] = {}
    for c in comps.values():
        for ins in c.instrs:
            sizes[ins.name] = ins.out_shapes

    memo: Dict[str, Tuple[float, float, Dict[str, List[float]]]] = {}
    warnings: List[str] = []
    in_progress: set = set()

    def visit(comp_name: str) -> Tuple[float, float, Dict[str, List[float]]]:
        """(flops, hbm_bytes, collectives) for ONE execution of the
        computation, including callees with loop multiplicities."""
        if comp_name in memo:
            return memo[comp_name]
        if comp_name in in_progress or comp_name not in comps:
            return 0.0, 0.0, {}
        in_progress.add(comp_name)
        comp = comps[comp_name]
        fl = 0.0
        by = 0.0
        coll: Dict[str, List[float]] = {}
        for ins in comp.instrs:
            if ins.opcode in _FREE_OPS:
                continue
            # HBM-traffic model per top-level op (fusions are single ops):
            #   slicing reads/writes only the slice; dynamic-update-slice
            #   is aliased in place (touches 2x the update, not the buffer);
            #   tuple plumbing (gte/tuple/bitcast/while carry) is free.
            if ins.opcode in ("slice", "dynamic-slice", "gather"):
                by += 2.0 * _nbytes(ins.out_shapes)
            elif ins.opcode in ("dynamic-update-slice", "scatter"):
                upd = (
                    _nbytes(sizes.get(ins.operands[1], []))
                    if len(ins.operands) > 1
                    else _nbytes(ins.out_shapes)
                )
                by += 2.0 * upd
            elif ins.opcode == "broadcast":
                by += _nbytes(ins.out_shapes) + sum(
                    _nbytes(sizes.get(o, [])) for o in ins.operands
                )
            elif ins.opcode == "fusion":
                callee_name = _attr_comp(ins.rhs, "calls")
                if callee_name in comps:
                    by += _fusion_bytes(comps[callee_name], sizes)
                else:
                    by += _nbytes(ins.out_shapes) + sum(
                        _nbytes(sizes.get(o, [])) for o in ins.operands
                    )
            elif ins.opcode not in ("while", "conditional", "call"):
                by += _nbytes(ins.out_shapes)
                for o in ins.operands:
                    by += _nbytes(sizes.get(o, []))

            base = _collective_base(ins.opcode)
            if base is not None:
                ob = sum(_nbytes(sizes.get(o, [])) for o in ins.operands)
                out_b = _nbytes(ins.out_shapes)
                gs = _group_size(ins.rhs, num_devices)
                if base == "all-reduce":
                    link = 2.0 * out_b * max(0, gs - 1) / max(1, gs)
                elif base == "all-gather":
                    link = out_b * max(0, gs - 1) / max(1, gs)
                elif base == "reduce-scatter":
                    link = ob * max(0, gs - 1) / max(1, gs)
                else:
                    link = ob
                _merge_coll(coll, {base: [1.0, ob, out_b, link]})

            if ins.opcode == "dot":
                fl += _dot_flops(ins, sizes)
            elif ins.opcode in ("while", "while-start"):
                body = _attr_comp(ins.rhs, "body")
                cond = _attr_comp(ins.rhs, "condition")
                trips = _trip_count(comps[cond], comps) if cond in comps else 1
                if body:
                    bf, bb, bc = visit(body)
                    fl += bf * trips
                    by += bb * trips
                    _merge_coll(coll, bc, trips)
                if cond in comps:
                    cf, cb, _ = visit(cond)
                    fl += cf * trips
            elif ins.opcode == "fusion":
                callee = _attr_comp(ins.rhs, "calls")
                if callee:
                    cf, _, cc = visit(callee)  # bytes: fusion = single op
                    fl += cf
                    _merge_coll(coll, cc)
            elif ins.opcode in ("call", "custom-call", "reduce", "map",
                                "scatter", "sort", "reduce-window",
                                "select-and-scatter", "all-reduce",
                                "reduce-scatter", "async-start"):
                callee = _attr_comp(ins.rhs, "to_apply") or _attr_comp(
                    ins.rhs, "calls"
                )
                if callee:
                    cf, cb, cc = visit(callee)
                    fl += cf
                    _merge_coll(coll, cc)
                    if ins.opcode in ("call", "async-start"):
                        by += cb
            elif ins.opcode == "conditional":
                for key in ("true_computation", "false_computation"):
                    callee = _attr_comp(ins.rhs, key)
                    if callee:
                        cf, cb, cc = visit(callee)
                        fl += cf
                        by += cb
                        _merge_coll(coll, cc)
        in_progress.discard(comp_name)
        memo[comp_name] = (fl, by, coll)
        return fl, by, coll

    fl, by, coll = visit(entry.name)
    return CorrectedCosts(
        flops=fl, hbm_bytes=by, collectives=coll, warnings=warnings
    )
