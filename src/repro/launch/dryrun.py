# det-lint: file waive[wall-clock] reason=real compile/lowering timing in a CLI driver; reported to the operator, never journaled
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) and extracts the roofline
inputs: cost_analysis FLOPs/bytes + collective bytes parsed from the
optimized HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.config import ModelConfig
from repro.config.parallel import TPU_V5E, HardwareSpec, ParallelPlan
from repro.config.shapes import SHAPES, SHAPE_ORDER, ShapeConfig, applicability
from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import RooflineTerms, parse_collectives
from repro.launch.hlo_counter import corrected_costs
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.launch.specs import (
    extras_for,
    prefill_input_specs,
    train_batch_specs,
)
from repro.models.model import ModelApi, build
from repro.serving.engine import jit_serve_steps
from repro.training.train_step import jit_train_step


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    return 2.0 * n * shape.tokens


def default_plan(shape: ShapeConfig, mesh) -> ParallelPlan:
    if shape.kind == "train":
        plan = ParallelPlan(remat="full", zero3=True)
    else:
        plan = ParallelPlan(remat="none", zero3=False)
    return plan.restrict_to(mesh.axis_names)


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    plan: Optional[ParallelPlan] = None,
    constrain_acts: bool = False,
):
    """Build + lower one cell; returns the jax ``Lowered``.

    ``constrain_acts`` enables the beyond-paper activation sharding
    constraints (repro.sharding.constraints) at trace time.
    """
    import contextlib

    from repro.sharding.constraints import activation_constraints

    api = build(cfg)
    plan = plan or default_plan(shape, mesh)
    extras = tuple(extras_for(cfg, shape.global_batch).keys())
    ctx = (
        activation_constraints(mesh, plan)
        if constrain_acts
        else contextlib.nullcontext()
    )
    with mesh, ctx:
        if shape.kind == "train":
            fn, abstract_state, _, _ = jit_train_step(
                api, plan, mesh, train_batch_specs(cfg, shape)
            )
            return fn.lower(abstract_state, train_batch_specs(cfg, shape))
        prefill_jit, decode_jit, _ = jit_serve_steps(
            api, plan, mesh, shape.global_batch, shape.seq_len, extras=extras
        )
        ap = api.abstract_params()
        if shape.kind == "prefill":
            return prefill_jit.lower(ap, *prefill_input_specs(cfg, shape))
        cache = api.abstract_cache(shape.global_batch, shape.seq_len)
        tokens = jax.ShapeDtypeStruct((shape.global_batch,), jax.numpy.int32)
        return decode_jit.lower(ap, cache, tokens)


def analyse_compiled(
    compiled, mesh, cfg: ModelConfig, shape: ShapeConfig, hw: HardwareSpec
) -> Dict[str, Any]:
    ndev = mesh_devices(mesh)
    out: Dict[str, Any] = {"devices": ndev}

    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        arg = out["memory"]["argument_bytes"] or 0
        outb = out["memory"]["output_bytes"] or 0
        tmp = out["memory"]["temp_bytes"] or 0
        alias = out["memory"]["alias_bytes"] or 0
        out["memory"]["peak_bytes_per_device"] = arg + outb + tmp - alias
    except Exception as e:  # CPU backend may not implement it
        out["memory"] = {"error": str(e)}

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    out["cost_raw"] = {"flops": raw_flops, "bytes_accessed": raw_bytes}

    hlo = compiled.as_text()
    # flat (uncorrected) collective scan - kept for comparison
    stats = parse_collectives(hlo, ndev)
    out["collectives_flat"] = stats.to_json()
    # while-corrected accounting: scan bodies x trip count (raw
    # cost_analysis counts each while body ONCE - see hlo_counter docs)
    cc = corrected_costs(hlo, ndev)
    out["cost_corrected"] = {
        "flops": cc.flops,
        "hbm_bytes": cc.hbm_bytes,
        "collectives": cc.collectives_json(),
    }

    terms = RooflineTerms(
        chips=ndev,
        flops_per_device=cc.flops,
        hbm_bytes_per_device=cc.hbm_bytes,
        collective_link_bytes_per_device=cc.collective_link_bytes,
        collective_operand_bytes_per_device=cc.collective_operand_bytes,
        peak_flops=hw.peak_flops,
        hbm_bw=hw.hbm_bandwidth,
        ici_bw=hw.ici_bandwidth,
        model_flops=model_flops(cfg, shape),
    )
    out["roofline"] = terms.to_json()
    return out


def run_cell(
    arch_id: str,
    shape_name: str,
    mesh,
    mesh_name: str,
    hw: HardwareSpec = TPU_V5E,
    plan: Optional[ParallelPlan] = None,
    keep_hlo: Optional[str] = None,
    constrain_acts: bool = False,
) -> Dict[str, Any]:
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    cell = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name}
    skip = applicability(cfg, shape)
    if skip:
        cell["skipped"] = skip
        return cell
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, plan, constrain_acts=constrain_acts)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    cell.update(analyse_compiled(compiled, mesh, cfg, shape, hw))
    cell["lower_s"] = round(t1 - t0, 2)
    cell["compile_s"] = round(t2 - t1, 2)
    if keep_hlo:
        with open(keep_hlo, "w") as f:
            f.write(compiled.as_text())
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--constrain-acts", action="store_true",
                    help="enable activation sharding constraints (SSPerf)")
    ap.add_argument("--pure-dp", action="store_true",
                    help="drop tensor parallelism (small-model plan: the "
                         "model axis joins data; SSPerf mamba2 iteration)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPE_ORDER) if args.shape == "all" else args.shape.split(",")
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}_{shape_name}_{mesh_name}".replace(".", "p")
                path = os.path.join(args.out, tag + ".json")
                import dataclasses

                plan = None
                shape = SHAPES[shape_name]
                overrides = {}
                if args.remat and shape.kind == "train":
                    overrides["remat"] = args.remat
                if args.grad_accum and shape.kind == "train":
                    overrides["grad_accum"] = args.grad_accum
                if args.pure_dp:
                    # fold the model axis into data parallelism: no TP
                    overrides["tensor_axes"] = ()
                    overrides["expert_axes"] = ()
                    overrides["data_axes"] = ("pod", "data", "model")
                    overrides["fsdp_axes"] = ("pod", "data", "model")
                if overrides:
                    plan = dataclasses.replace(
                        default_plan(shape, mesh), **overrides
                    ).restrict_to(mesh.axis_names)
                try:
                    cell = run_cell(
                        arch, shape_name, mesh, mesh_name,
                        plan=plan,
                        keep_hlo=(
                            os.path.join(args.out, tag + ".hlo.txt")
                            if args.keep_hlo else None
                        ),
                        constrain_acts=args.constrain_acts,
                    )
                    if "skipped" in cell:
                        n_skip += 1
                        print(f"SKIP {tag}: {cell['skipped']}")
                    else:
                        n_ok += 1
                        r = cell["roofline"]
                        print(
                            f"OK   {tag}: compute={r['compute_s']:.3e}s "
                            f"memory={r['memory_s']:.3e}s "
                            f"collective={r['collective_s']:.3e}s "
                            f"bottleneck={r['bottleneck']} "
                            f"(lower {cell['lower_s']}s compile {cell['compile_s']}s)"
                        )
                except Exception as e:
                    n_fail += 1
                    cell = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                with open(path, "w") as f:
                    json.dump(cell, f, indent=1)
    print(f"\ndry-run done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
