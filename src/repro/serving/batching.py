"""Continuous batching: slot-based scheduler over a shared decode cache.

The decode cache is a fixed [L, B_slots, S, ...] tree; requests are
assigned to free slots on arrival, prefilled individually (batch-1 prefill
against the same cache length), scattered into their slot, and then decoded
together with every other active slot in a single decode step per token.
Finished slots (EOS or token budget) are freed immediately, so the batch
composition changes every step - the vLLM-style iteration-level scheduling
that Dandelion's "cold start per request is fine" philosophy matches: a new
request never waits for the current batch to drain.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import is_spec
from repro.models.model import ModelApi


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: int = -1
    arrival: float = 0.0
    # filled by the scheduler
    generated: List[int] = field(default_factory=list)
    done: bool = False


def _batch_axis_tree(api: ModelApi, batch: int, cache_len: int):
    """For each cache leaf, the index of its batch dim (from logical axes)."""
    spec = api.cache_spec(batch, cache_len)

    def ax(s):
        return s.axes.index("batch") if "batch" in s.axes else None

    return jax.tree_util.tree_map(ax, spec, is_leaf=is_spec)


def insert_slot(cache, one, slot: int, batch_axes):
    """Scatter a batch-1 cache tree into ``slot`` of the batched cache."""

    def put(c, o, bax):
        if bax is None:
            return c
        idx = [slice(None)] * c.ndim
        idx[bax] = slice(slot, slot + 1)
        return c.at[tuple(idx)].set(o.astype(c.dtype))

    return jax.tree_util.tree_map(put, cache, one, batch_axes)


class ContinuousBatcher:
    """Iteration-level scheduler. Host-side control, device-side steps."""

    def __init__(
        self,
        api: ModelApi,
        params,
        *,
        num_slots: int,
        cache_len: int,
        extras_fn=None,
    ):
        self.api = api
        self.params = params
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.extras_fn = extras_fn  # rid -> dict of prefill extras
        self.cache = api.init_cache(num_slots, cache_len)
        self.batch_axes = _batch_axis_tree(api, num_slots, cache_len)
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.cur_tokens = np.zeros((num_slots,), np.int32)
        self.waiting: List[Request] = []
        self._decode = jax.jit(api.decode_step)
        self._prefill = jax.jit(
            lambda p, t, pl, **kw: api.prefill(p, t, pl, **kw)
        )
        self._steps = 0
        self.all_requests: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)
        self.all_requests.append(req)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _admit(self):
        while self.waiting:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.waiting.pop(0)
            prompt = req.prompt[: self.cache_len]
            pad = self.cache_len - len(prompt)
            tokens = jnp.asarray([prompt + [0] * pad], jnp.int32)
            plens = jnp.asarray([len(prompt)], jnp.int32)
            kw = self.extras_fn(req.rid) if self.extras_fn else {}
            logits, one_cache = self._prefill(self.params, tokens, plens, **kw)
            first = int(jnp.argmax(logits[0]))
            self.cache = insert_slot(self.cache, one_cache, slot, self.batch_axes)
            self.slots[slot] = req
            req.generated.append(first)
            self.cur_tokens[slot] = first
            self._maybe_finish(slot)

    def _maybe_finish(self, slot: int):
        req = self.slots[slot]
        if req is None:
            return
        if len(req.generated) >= req.max_new_tokens or (
            req.eos_id >= 0 and req.generated and req.generated[-1] == req.eos_id
        ):
            req.done = True
            self.slots[slot] = None

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """Admit waiting requests, run one decode step, emit (rid, token)."""
        self._admit()
        if self.active == 0:
            return []
        tokens = jnp.asarray(self.cur_tokens)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.generated.append(tok)
            self.cur_tokens[i] = tok
            out.append((req.rid, tok))
            self._maybe_finish(i)
        self._steps += 1
        return out

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.waiting and self.active == 0:
                break
            self.step()
        return {req.rid: req.generated for req in self.all_requests}
