# det-lint: file waive[wall-clock] reason=real-exec calibration capture; measures actual jitted step times to fit the modeled BatchStepModel
"""Trace capture: measure real prefill/decode step timings to calibrate
the platform's serving cost models.

The serving simulation (``benchmarks/fig13_serving.py``) runs on
analytic rooflines so its outputs are byte-stable; this shim is the
bridge back to reality: it drives the *real* jitted serving steps
(``repro.serving.engine``) on a small config, records wall-clock step
times per batch size, and fits them to the platform's
``core.workloads.BatchStepModel`` shape — ``step_s(n) = fixed + n *
per_seq`` (the decode roofline is memory-bound at CI scale, so the
affine fit is the right functional form). Use it to sanity-check the
analytic model's *shape* (fixed-cost amortization over the batch), or to
produce a host-calibrated model for what-if runs:

    timings = capture_step_timings(api, params, batches=(1, 4))
    model = calibrated_batch_model(timings)

Wall-clock numbers are machine-dependent by construction: nothing in the
committed benchmark path calls this module (determinism contract), and
the calibration runs real compiles — keep configs at smoke scale.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workloads import BatchStepModel
from repro.models.model import ModelApi


@dataclass(frozen=True)
class StepTiming:
    """Median wall-clock seconds for one prefill + one decode step at a
    given batch size (post-warmup: compile excluded)."""

    batch: int
    prefill_s: float
    decode_s: float


def capture_step_timings(
    api: ModelApi,
    params,
    *,
    batches: Sequence[int] = (1, 2, 4),
    cache_len: int = 32,
    prompt_len: int = 8,
    samples: int = 3,
    seed: int = 0,
) -> List[StepTiming]:
    """Run the real jitted steps per batch size and record medians.

    One warmup call per (shape, step) pays the compile before timing, so
    the medians measure steady-state step latency — the quantity the
    ``BatchStepModel`` roofline predicts."""
    prefill = jax.jit(api.prefill)
    decode = jax.jit(api.decode_step)
    rng = np.random.default_rng(seed)
    out: List[StepTiming] = []
    for b in batches:
        toks = jnp.asarray(
            rng.integers(1, 100, size=(b, cache_len)), jnp.int32
        )
        plens = jnp.full((b,), prompt_len, jnp.int32)
        logits, cache = prefill(params, toks, plens)          # warmup/compile
        step_toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        decode(params, cache, step_toks)                      # warmup/compile

        pf, dc = [], []
        for _ in range(samples):
            t0 = time.perf_counter()
            logits, cache = prefill(params, toks, plens)
            jax.block_until_ready(logits)
            pf.append(time.perf_counter() - t0)

            t0 = time.perf_counter()
            logits, cache = decode(params, cache, step_toks)
            jax.block_until_ready(logits)
            dc.append(time.perf_counter() - t0)
        out.append(StepTiming(
            batch=int(b),
            prefill_s=float(np.median(pf)),
            decode_s=float(np.median(dc)),
        ))
    return out


def fit_affine(timings: Sequence[StepTiming]) -> Tuple[float, float]:
    """Least-squares ``decode_s ~ fixed + batch * per_seq`` fit. With a
    single batch size the whole cost is attributed to the fixed term
    (per_seq = 0) — enough for a smoke check, not a calibration."""
    if not timings:
        raise ValueError("no timings to fit")
    if len(timings) == 1:
        return timings[0].decode_s, 0.0
    xs = np.asarray([t.batch for t in timings], np.float64)
    ys = np.asarray([t.decode_s for t in timings], np.float64)
    per_seq, fixed = np.polyfit(xs, ys, 1)
    return float(max(fixed, 0.0)), float(max(per_seq, 0.0))


def calibrated_batch_model(
    timings: Sequence[StepTiming],
    *,
    reference_bw: float = 1.0,
) -> BatchStepModel:
    """Host-calibrated ``BatchStepModel``: the affine fit is encoded as a
    pure memory-roofline model (``fixed_bytes/hbm_bw = fixed``,
    ``bytes_per_seq/hbm_bw = per_seq``) with the compute term zeroed, so
    ``step_s(n)`` reproduces the measured affine curve exactly."""
    fixed_s, per_seq_s = fit_affine(timings)
    return BatchStepModel(
        flops_per_seq=0.0,
        fixed_bytes=fixed_s * reference_bw,
        bytes_per_seq=per_seq_s * reference_bw,
        peak_flops=1.0,
        hbm_bw=reference_bw,
        overhead_s=0.0,
    )


def calibration_residuals(
    timings: Sequence[StepTiming],
    model: BatchStepModel,
) -> List[Tuple[int, float]]:
    """Per-batch relative error of ``model`` against measured decode
    times: ``(batch, (predicted - measured) / measured)``. Prices every
    batch size in one vectorized ``step_s_batch`` call — the same path
    the batch engine uses — so a calibration report also exercises the
    code it certifies. Large residuals mean the affine form no longer
    fits (e.g. the real steps went compute-bound): recapture with more
    batch sizes before trusting what-if runs."""
    if not timings:
        raise ValueError("no timings to score")
    predicted = model.step_s_batch([t.batch for t in timings])
    return [
        (t.batch, float((p - t.decode_s) / t.decode_s))
        for t, p in zip(timings, predicted)
    ]
