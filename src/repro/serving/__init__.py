"""Serving substrate: step builders, continuous batching, generation."""
from repro.serving.engine import (
    ServeSteps,
    build_serve_steps,
    jit_serve_steps,
)

__all__ = ["ServeSteps", "build_serve_steps", "jit_serve_steps"]
