"""Prefill/decode step builders with explicit shardings + generation loop.

``serve_step`` naming per the assignment: the decode shapes lower a
single-new-token step against a KV cache of ``seq_len``; prefill shapes
lower the full prompt pass.

Sampling is greedy or temperature-categorical, computed inside the jitted
step so logits never leave the device.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.config.parallel import ParallelPlan
from repro.models.model import ModelApi
from repro.sharding.rules import (
    batch_sharding,
    cache_shardings,
    param_shardings,
    replicated,
)


class ServeSteps(NamedTuple):
    prefill: Callable   # (params, tokens, prompt_lens, *extras) -> (logits, cache)
    decode: Callable    # (params, cache, tokens) -> (logits, next_tokens, cache)
    sample: Callable    # (logits, rng, temperature) -> tokens


def _sample(logits: jax.Array, rng: jax.Array, temperature: float) -> jax.Array:
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


def build_serve_steps(api: ModelApi, *, temperature: float = 0.0) -> ServeSteps:
    def prefill(params, tokens, prompt_lens, **extras):
        return api.prefill(params, tokens, prompt_lens, **extras)

    def decode(params, cache, tokens):
        logits, cache = api.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, nxt, cache

    return ServeSteps(prefill=prefill, decode=decode, sample=_sample)


def serve_shardings(
    api: ModelApi,
    plan: ParallelPlan,
    mesh: Mesh,
    batch: int,
    cache_len: int,
):
    """(param_shardings, cache_shardings, token_sharding)."""
    psh = param_shardings(api.param_template, mesh, plan, kind="serve")
    csh = cache_shardings(api.cache_spec(batch, cache_len), mesh, plan)
    tsh = batch_sharding(plan, mesh, batch)
    return psh, csh, tsh


def jit_serve_steps(
    api: ModelApi,
    plan: ParallelPlan,
    mesh: Mesh,
    batch: int,
    cache_len: int,
    *,
    extras: Tuple[str, ...] = (),
):
    """Jitted prefill/decode with explicit in/out shardings.

    ``extras``: names of additional prefill inputs ("frames" / "patches"),
    sharded over the data axes on dim 0.
    """
    steps = build_serve_steps(api)
    psh, csh, tsh = serve_shardings(api, plan, mesh, batch, cache_len)
    rep = replicated(mesh)

    def prefill(params, tokens, prompt_lens, *extra_vals):
        kw = dict(zip(extras, extra_vals))
        return steps.prefill(params, tokens, prompt_lens, **kw)

    extra_sh = tuple(tsh for _ in extras)
    prefill_jit = jax.jit(
        prefill,
        in_shardings=(psh, tsh, tsh) + extra_sh,
        out_shardings=(tsh, csh),
    )
    decode_jit = jax.jit(
        steps.decode,
        in_shardings=(psh, csh, tsh),
        out_shardings=(tsh, tsh, csh),
        donate_argnums=(1,),
    )
    return prefill_jit, decode_jit, (psh, csh, tsh)


def generate(
    api: ModelApi,
    params,
    prompts: jax.Array,
    prompt_lens: jax.Array,
    max_new_tokens: int,
    *,
    rng: Optional[jax.Array] = None,
    temperature: float = 0.0,
    eos_id: int = -1,
    extras: Optional[Dict[str, jax.Array]] = None,
) -> jax.Array:
    """Simple whole-batch generation loop (examples/tests; the production
    path is the continuous-batching scheduler in repro.serving.batching)."""
    extras = extras or {}
    logits, cache = api.prefill(params, prompts, prompt_lens, **extras)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    tok = _sample(logits, rng, temperature)
    out = [tok]
    decode = jax.jit(api.decode_step)
    for i in range(max_new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        rng, sub = jax.random.split(rng)
        tok = _sample(logits, sub, temperature)
        out.append(tok)
    return jnp.stack(out, axis=1)  # [B, max_new_tokens]
