"""Flash-decode Pallas kernel: one query token vs a (ring) KV cache.

At q_len=1 the MXU would idle on a single query row, so the GQA query
group (G = Hq/Hkv rows) is packed into the sublane dimension: each grid
cell computes a (G, dh) x (dh, kv_block) score tile. The kv dimension is
the innermost grid axis, carried across steps by VMEM scratch (m, l, acc)
- the same online softmax as prefill flash, which is exactly the
"partial softmax + combine" structure flash-decode uses on GPUs, expressed
TPU-natively as a sequentially-revisited grid.

Slot-position masking supports ring buffers (sliding-window caches): a
slot is valid iff ``0 <= slot_pos <= cur_pos`` and, with a window,
``cur_pos - slot_pos < window``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    q_ref,      # [1, G, dh]
    k_ref,      # [1, kb, dh]
    v_ref,      # [1, kb, dh]
    slot_ref,   # [1, kb] int32
    pos_ref,    # [1] int32
    o_ref,      # [1, G, dh]
    m_ref,      # scratch [G]
    l_ref,      # scratch [G]
    acc_ref,    # scratch [G, dh]
    *,
    scale: float,
    window: int,
    nk: int,
):
    ik = pl.program_id(1)
    g, dh = q_ref.shape[1], q_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # [G, dh]
    k = k_ref[0].astype(jnp.float32)                       # [kb, dh]
    v = v_ref[0].astype(jnp.float32)
    slot = slot_ref[0]                                     # [kb]
    cur = pos_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                              # [G, kb]
    valid = (slot >= 0) & (slot <= cur)
    if window:
        valid &= cur - slot < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None])[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "kv_block", "interpret")
)
def decode_attention(
    q: jax.Array,          # [B, Hq, dh]
    k_cache: jax.Array,    # [B, S, Hkv, dh]
    v_cache: jax.Array,
    slot_pos: jax.Array,   # [B, S] int32 (-1 = empty slot)
    cur_pos: jax.Array,    # [B] int32
    *,
    window: int = 0,
    scale: Optional[float] = None,
    kv_block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, hq, dh = q.shape
    _, s, hkv, _ = k_cache.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = float(scale if scale is not None else dh**-0.5)

    kb = min(kv_block, s)
    pad = (-s) % kb
    kk = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)
    vv = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)
    sp = slot_pos
    if pad:
        kk = jnp.pad(kk, ((0, 0), (0, pad), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, pad), (0, 0)))
        sp = jnp.pad(slot_pos, ((0, 0), (0, pad)), constant_values=-1)
    sp_ = sp.astype(jnp.int32)
    nk = (s + pad) // kb
    qg = q.reshape(b * hkv, g, dh)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window, nk=nk),
        grid=(b * hkv, nk),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda bk, ik: (bk, 0, 0)),
            pl.BlockSpec((1, kb, dh), lambda bk, ik: (bk, ik, 0)),
            pl.BlockSpec((1, kb, dh), lambda bk, ik: (bk, ik, 0)),
            pl.BlockSpec((1, kb), lambda bk, ik, _hkv=hkv: (bk // _hkv, ik)),
            pl.BlockSpec((1,), lambda bk, ik, _hkv=hkv: (bk // _hkv,)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda bk, ik: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kk, vv, sp_, cur_pos.astype(jnp.int32))
    return out.reshape(b, hq, dh)
