"""Flash attention (prefill/train) Pallas TPU kernel.

TPU-native adaptation (DESIGN.md SS6): no warp-level shuffles - the online
softmax is blocked for VMEM residency and the MXU sees
(G*q_block, dh) x (dh, kv_block) matmuls. GQA is handled by packing the
q-head *group* into the sublane dimension (G*q_block rows), so a kv_head's
whole query group rides one grid cell and K/V tiles are loaded once per
group rather than once per query head.

Grid: (B*Hkv, num_q_blocks, num_kv_blocks); the kv dimension is innermost
(sequentially revisited on TPU), carrying the running max / denominator /
accumulator in VMEM scratch. Causal and sliding-window masks skip fully
masked kv blocks via ``pl.when``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,    # [1, G, qb, dh]
    k_ref,    # [1, kb, dh]
    v_ref,    # [1, kb, dh]
    o_ref,    # [1, G, qb, dh]
    m_ref,    # scratch [G*qb]
    l_ref,    # scratch [G*qb]
    acc_ref,  # scratch [G*qb, dh]
    *,
    scale: float,
    causal: bool,
    window: int,
    qb: int,
    kb: int,
    nk: int,
    sk_valid: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    g = q_ref.shape[1]
    dh = q_ref.shape[3]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = iq * qb + jax.lax.iota(jnp.int32, qb)
    kpos = ik * kb + jax.lax.iota(jnp.int32, kb)

    # block-level early exit for fully-masked tiles
    run = jnp.asarray(ik * kb < sk_valid)  # kv block entirely padding
    if causal:
        run &= (ik * kb) <= (iq * qb + qb - 1)
    if window:
        run &= (iq * qb) - (ik * kb + kb - 1) < window

    @pl.when(run)
    def _body():
        q = q_ref[0].reshape(g * qb, dh).astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # [G*qb, kb]

        mask = jnp.broadcast_to(kpos[None, :] < sk_valid, (qb, kb))
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        maskg = jnp.broadcast_to(mask[None], (g, qb, kb)).reshape(g * qb, kb)
        s = jnp.where(maskg, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = (acc_ref[...] / l[:, None]).reshape(1, g, qb, dh)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_block", "kv_block", "interpret"),
)
def flash_attention(
    q: jax.Array,   # [B, Sq, Hq, dh]
    k: jax.Array,   # [B, Sk, Hkv, dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = float(scale if scale is not None else dh**-0.5)

    qb = min(q_block, sq)
    kb = min(kv_block, sk)
    pad_q = (-sq) % qb
    pad_k = (-sk) % kb
    # [B, K, G, Sq, dh] with padded sequence
    qg = q.reshape(b, sq, hkv, g, dh).transpose(0, 2, 3, 1, 4)
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    kk = k.transpose(0, 2, 1, 3)  # [B, K, Sk, dh]
    vv = v.transpose(0, 2, 1, 3)
    if pad_k:
        kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sqp, skp = sq + pad_q, sk + pad_k
    nq, nk = sqp // qb, skp // kb

    qg = qg.reshape(b * hkv, g, sqp, dh)
    kk = kk.reshape(b * hkv, skp, dh)
    vv = vv.reshape(b * hkv, skp, dh)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            window=window,
            qb=qb,
            kb=kb,
            nk=nk,
            sk_valid=sk,  # padded kv rows are masked in-kernel
        ),
        grid=(b * hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g, qb, dh), lambda bk, iq, ik: (bk, 0, iq, 0)),
            pl.BlockSpec((1, kb, dh), lambda bk, iq, ik: (bk, ik, 0)),
            pl.BlockSpec((1, kb, dh), lambda bk, iq, ik: (bk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, qb, dh), lambda bk, iq, ik: (bk, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, sqp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * qb,), jnp.float32),
            pltpu.VMEM((g * qb,), jnp.float32),
            pltpu.VMEM((g * qb, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kk, vv)

    out = out.reshape(b, hkv, g, sqp, dh)[:, :, :, :sq]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
