"""Pure-jnp oracles for every Pallas kernel.

These are the semantics; the kernels must match them (tests sweep shapes
and dtypes with ``assert_allclose`` in interpret mode). Where the model
code already contains the reference implementation (attention, SSD), we
re-export it so there is exactly one source of truth.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention as decode_attention_ref
from repro.models.attention import naive_attention
from repro.models.layers import rms_norm as _rms_norm_layers
from repro.models.ssm import ssd_chunked


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """[..., D] -> [..., D]; f32 statistics regardless of dtype."""
    return _rms_norm_layers(x, scale, eps)


def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, dh]
    k: jax.Array,  # [B, Sk, Hkv, dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    return naive_attention(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention(
    q: jax.Array,          # [B, Hq, dh]
    k_cache: jax.Array,    # [B, S, Hkv, dh]
    v_cache: jax.Array,
    slot_pos: jax.Array,   # [B, S] int32, -1 = empty
    cur_pos: jax.Array,    # [B] int32
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    return decode_attention_ref(
        q, k_cache, v_cache, slot_pos, cur_pos, window=window, scale=scale
    )


def ssd(
    x: jax.Array,   # [B, S, H, P] (dt-weighted inputs)
    a: jax.Array,   # [B, S, H]    log-decay per step
    b: jax.Array,   # [B, S, N]
    c: jax.Array,   # [B, S, N]
    chunk: int,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked state-space dual form; returns (y [B,S,H,P], h_final)."""
    return ssd_chunked(x, a, b, c, chunk, h0)


def ssd_sequential(x, a, b, c, h0=None):
    """O(S) sequential recurrence - the ground-truth semantics of SSD:
    h_t = exp(a_t) h_{t-1} + b_t^T x_t ; y_t = c_t h_t."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(carry, inp):
        xt, at, bt, ct = inp
        decay = jnp.exp(at.astype(jnp.float32))  # [B, H]
        upd = jnp.einsum(
            "bhp,bn->bhpn", xt.astype(jnp.float32), bt.astype(jnp.float32)
        )
        hn = carry * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", hn, ct.astype(jnp.float32))
        return hn, yt

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(a, 1, 0),
        jnp.moveaxis(b, 1, 0),
        jnp.moveaxis(c, 1, 0),
    )
    hf, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hf


def moe_gmm(
    xe: jax.Array,  # [E, C, D] expert-dispatched tokens
    we: jax.Array,  # [E, D, F] per-expert weights
) -> jax.Array:
    """Grouped (per-expert batched) matmul -> [E, C, F]."""
    return jnp.einsum("ecd,edf->ecf", xe, we)
