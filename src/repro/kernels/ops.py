"""Unified kernel entry points with a pallas/reference switch.

Model code calls these; ``use_pallas`` selects the Pallas TPU kernel
(default on TPU) or the pure-jnp chunked reference (default on CPU, and
what the dry-run lowers so roofline bytes stay honest). ``interpret``
forces the Pallas interpreter - how the CPU test suite validates the
kernels' semantics.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import (
    decode_attention as _decode_k,
    flash_attention as _flash_k,
    moe_gmm as _gmm_k,
    rmsnorm as _rms_k,
    ssd_scan as _ssd_k,
)
from repro.kernels import ref as _ref
from repro.models.attention import chunked_attention as _chunked_ref


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def rmsnorm(x, scale, *, eps: float = 1e-5, use_pallas: Optional[bool] = None,
            interpret: bool = False):
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _rms_k.rmsnorm(x, scale, eps=eps, interpret=interpret)
    return _ref.rmsnorm(x, scale, eps)


def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    scale: Optional[float] = None, use_pallas: Optional[bool] = None,
    interpret: bool = False, q_block: int = 128, kv_block: int = 128,
):
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _flash_k.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            q_block=q_block, kv_block=kv_block, interpret=interpret,
        )
    # CPU / lowering path: O(S) chunked reference (same math)
    return _chunked_ref(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention(
    q, k_cache, v_cache, slot_pos, cur_pos, *, window: int = 0,
    scale: Optional[float] = None, use_pallas: Optional[bool] = None,
    interpret: bool = False, kv_block: int = 256,
):
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _decode_k.decode_attention(
            q, k_cache, v_cache, slot_pos, cur_pos, window=window,
            scale=scale, kv_block=kv_block, interpret=interpret,
        )
    return _ref.decode_attention(
        q, k_cache, v_cache, slot_pos, cur_pos, window=window, scale=scale
    )


def ssd(
    x, a, b, c, *, chunk: int = 128, use_pallas: Optional[bool] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _ssd_k.ssd(x, a, b, c, chunk=chunk, interpret=interpret)
    return _ref.ssd(x, a, b, c, chunk)


def moe_gmm(
    xe, we, *, use_pallas: Optional[bool] = None, interpret: bool = False,
    block_c: int = 128, block_f: int = 128, block_d: int = 256,
):
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _gmm_k.moe_gmm(
            xe, we, block_c=block_c, block_f=block_f, block_d=block_d,
            interpret=interpret,
        )
    return _ref.moe_gmm(xe, we)
