# Pallas TPU kernels for the model-payload hot spots: flash/decode
# attention, Mamba2 SSD scan, MoE grouped matmul, fused RMSNorm.
# Each <name>.py is a pl.pallas_call with explicit BlockSpec VMEM tiling;
# ops.py is the jit'd dispatch layer; ref.py holds the pure-jnp oracles.
# (The Dandelion paper itself has no kernel-level contribution - these
# cover the compute layers its platform serves; see DESIGN.md SS6.)
