"""Grouped (per-expert) matmul Pallas kernel for MoE expert FFNs.

MegaBlocks-style grouped GEMM adapted to the MXU: the expert dimension is
the outermost grid axis, and each expert's [capacity, D] x [D, F] product
is tiled into (128-aligned) VMEM blocks with a f32 accumulator carried
across the contraction grid axis. On TPU the expert loop costs nothing
extra when an expert's capacity block is empty of real tokens - dispatch
produces zero rows, and 0-blocks multiply to 0 - so no ragged-boundary
bookkeeping is needed at the kernel level (the dispatch layer owns it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                # [cb, db]
    w = w_ref[0]                # [db, fb]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(idd == nd - 1)
    def _final():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def moe_gmm(
    xe: jax.Array,  # [E, C, D]
    we: jax.Array,  # [E, D, F]
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = xe.shape
    _, _, f = we.shape
    cb, fb, db = min(block_c, c), min(block_f, f), min(block_d, d)

    pad_c, pad_f, pad_d = (-c) % cb, (-f) % fb, (-d) % db
    if pad_c or pad_d:
        xe = jnp.pad(xe, ((0, 0), (0, pad_c), (0, pad_d)))
    if pad_d or pad_f:
        we = jnp.pad(we, ((0, 0), (0, pad_d), (0, pad_f)))
    cp, dp, fp = c + pad_c, d + pad_d, f + pad_f
    nc, nf, nd = cp // cb, fp // fb, dp // db

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, nd=nd),
        grid=(e, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, cb, db), lambda ie, ic, if_, id_: (ie, ic, id_)),
            pl.BlockSpec((1, db, fb), lambda ie, ic, if_, id_: (ie, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, cb, fb), lambda ie, ic, if_, id_: (ie, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), xe.dtype),
        scratch_shapes=[pltpu.VMEM((cb, fb), jnp.float32)],
        interpret=interpret,
    )(xe, we)
    if pad_c or pad_f:
        out = out[:, :c, :f]
    return out
