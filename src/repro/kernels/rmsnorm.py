"""Fused RMSNorm Pallas kernel.

Rows are tiled into VMEM blocks of (block_rows, D); statistics and the
scale multiply happen in one pass in f32, so the row is read once and
written once (the fusion XLA does not always get right when the norm sits
between remat boundaries).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # [rows, D]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x [..., D] -> [..., D]."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // br

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
