"""Mamba2 SSD (state-space dual) chunked-scan Pallas kernel.

TPU adaptation of the SSD algorithm (Dao & Gu 2024): within a chunk the
recurrence is evaluated in its quadratic dual form - three MXU matmuls on
(chunk x chunk) / (chunk x P) tiles resident in VMEM - while the
inter-chunk state recurrence rides the innermost (sequential) grid
dimension, carrying the [P, N] state in VMEM scratch. Chunk length is the
natural 128 so every matmul dimension is MXU-aligned.

Grid: (B, H, num_chunks). B/C projections are shared across heads
(ngroups=1), expressed through index maps that ignore the head axis.
Inputs follow ``repro.models.ssm.ssd_chunked``: x is dt-weighted, ``a`` is
the per-step log decay.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref,     # [1, l, 1, P]
    a_ref,     # [1, l, 1]
    b_ref,     # [1, l, N]
    c_ref,     # [1, l, N]
    y_ref,     # [1, l, 1, P]
    hf_ref,    # [1, 1, P, N] final state (written on the last chunk)
    h_ref,     # scratch [P, N] f32
    *,
    nc: int,
):
    ic = pl.program_id(2)
    l = x_ref.shape[1]
    p = x_ref.shape[3]
    n = b_ref.shape[2]

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [l, P]
    a = a_ref[0, :, 0].astype(jnp.float32)             # [l]
    bm = b_ref[0].astype(jnp.float32)                  # [l, N]
    cm = c_ref[0].astype(jnp.float32)                  # [l, N]

    cum = jnp.cumsum(a)                                # [l]
    # segsum: seg[i, j] = cum[i] - cum[j] for j <= i else -inf
    seg = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.iota(jnp.int32, l)[:, None]
        >= jax.lax.iota(jnp.int32, l)[None, :]
    )
    L = jnp.exp(jnp.where(tri, seg, NEG_INF))          # [l, l]

    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # [l, l]
    y_diag = jax.lax.dot_general(
        L * scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # [l, P]

    h = h_ref[...]                                     # [P, N]
    y_off = jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]                          # [l, P]

    decay_states = jnp.exp(cum[-1] - cum)              # [l]
    state_new = jax.lax.dot_general(
        x * decay_states[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # [P, N]
    h_new = h * jnp.exp(cum[-1]) + state_new
    h_ref[...] = h_new

    y_ref[...] = (y_diag + y_off)[None, :, None, :].astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        hf_ref[...] = h_new[None, None].astype(hf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,   # [B, S, H, P]  dt-weighted inputs
    a: jax.Array,   # [B, S, H]     log decay
    b: jax.Array,   # [B, S, N]
    c: jax.Array,   # [B, S, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l

    y, hf = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, l, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, l, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, l, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, l, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
    return y, hf
