"""The Dandelion declarative SDK: the typed front door to the platform.

The paper's programming model (SS4.1) is *declarative* — applications
are DAGs of pure compute functions and platform communication functions.
This package makes that the user-facing surface:

  1. **typed function declaration** — ``@sdk.function`` /
     ``sdk.declare`` / ``sdk.ref`` capture every ``ComputeFunction``
     metadata field at the definition site (``repro.sdk.functions``);
  2. **declarative composition building** — port-level dataflow
     expressions with ``each``/``key`` fan-out sugar, HTTP comm
     vertices, nested compositions, and eager validation that names the
     offending vertex/edge (``repro.sdk.builder``); compiles to the
     ``repro.core.dag:Composition`` IR unchanged;
  3. **the Platform facade** — one object owning registries, the event
     loop, and a single/pool/elastic execution backend, with a unified
     ``deploy`` / ``invoke -> InvocationHandle`` / ``submit_stream``
     API (``repro.sdk.platform``).

Minimal application:

    from repro import sdk
    from repro.core import Item

    @sdk.function(inputs=("doc",), outputs=("stats",))
    def word_count(ins):
        n = len(ins["doc"][0].data.body.split())
        return {"stats": [Item(f"words={n}".encode())]}

    with sdk.composition("quickstart") as app:
        fetch = sdk.http("fetch", requests=app.input("request"))
        count = word_count(_name="count", doc=fetch.responses)
        app.output("stats", count.stats)

    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=4))
    platform.deploy(app)
    print(platform.invoke(app, {"request": [...]}).result())

Error taxonomy in ``repro.sdk.errors``; full reference in docs/API.md.
"""
from repro.core.artifacts import PrefetchConfig
from repro.core.coldstart import ColdStartProfile, TransferProfile
from repro.core.control_plane import (
    BatchRouter,
    ControlPlaneConfig,
    PredictorConfig,
    ReplicaConfig,
)
from repro.core.dag import RetryPolicy
from repro.core.http import HttpRequest, HttpResponse
from repro.core.items import Item
from repro.core.workloads import BatchStepModel, WeightStore
from repro.sdk.builder import (
    App,
    InputRef,
    Port,
    VertexHandle,
    composition,
    each,
    http,
    key,
    single_function_app,
)
from repro.sdk.errors import (
    DeclarationError,
    DeploymentError,
    InvocationFailed,
    PurityError,
    SDKError,
    UnknownPortError,
    ValidationError,
    WiringError,
)
from repro.sdk.config import DEPRECATED_ENV_ALIASES, PlatformConfig
from repro.sdk.functions import FunctionSpec, declare, function, ref
from repro.sdk.platform import Elastic, InvocationHandle, NodeSpec, Platform
from repro.sdk.verify import verify
from repro.analysis import PurityReport

__all__ = [
    # declaration
    "FunctionSpec",
    "declare",
    "function",
    "ref",
    # composition building
    "App",
    "InputRef",
    "Port",
    "VertexHandle",
    "composition",
    "each",
    "http",
    "key",
    "single_function_app",
    # platform
    "DEPRECATED_ENV_ALIASES",
    "Elastic",
    "InvocationHandle",
    "NodeSpec",
    "Platform",
    "PlatformConfig",
    "PredictorConfig",
    "PrefetchConfig",
    # verification
    "verify",
    "PurityReport",
    # errors
    "DeclarationError",
    "DeploymentError",
    "InvocationFailed",
    "PurityError",
    "SDKError",
    "UnknownPortError",
    "ValidationError",
    "WiringError",
    # convenience re-exports (core types SDK apps touch constantly)
    "BatchRouter",
    "BatchStepModel",
    "ColdStartProfile",
    "ControlPlaneConfig",
    "ReplicaConfig",
    "HttpRequest",
    "HttpResponse",
    "Item",
    "RetryPolicy",
    "TransferProfile",
    "WeightStore",
]
