"""Typed platform configuration: one validated front door for the knobs
that used to live in scattered environment variables.

``PlatformConfig`` is the single place the execution-environment toggles
live — cross-node scheduling, loop sharding, P2P artifact prefetch, and
burst prediction::

    cfg = sdk.PlatformConfig(
        crossnode=True,
        shards=True,
        prefetch=core.PrefetchConfig(hot_k=8),
        predictor=core.PredictorConfig(lead_s=1.0),
    )
    platform = sdk.Platform(elastic=sdk.Elastic(...), config=cfg)

``PlatformConfig.from_env()`` is the one validated parser for the
environment spelling; ``Platform`` calls it when no ``config=`` is
passed, so existing env-driven drivers keep working unchanged. The
legacy variables (``CROSSNODE``, ``CROSSNODE_SPREAD``,
``DANDELION_SHARDS``, ``DANDELION_SHARD_LOOKAHEAD_S``) are **deprecated
aliases**: setting any of them emits one ``DeprecationWarning`` per
process (from the ``Platform`` path), and tests pin that the alias and
the explicit config build identical platforms. The new ``prefetch=`` /
``predictor=`` surface ships only through this object — there is no
``Platform(prefetch=...)`` kwarg.

Env spelling parsed by ``from_env`` (booleans are ``"0"``/``"1"``):

======================================  =====================================
variable                                field
======================================  =====================================
``CROSSNODE``                           ``crossnode`` (deprecated alias)
``CROSSNODE_SPREAD``                    ``crossnode_spread`` (deprecated)
``DANDELION_SHARDS``                    ``shards`` (deprecated alias)
``DANDELION_SHARD_LOOKAHEAD_S``         ``shard_lookahead_s`` (deprecated)
``DANDELION_PREFETCH``                  ``prefetch`` (default PrefetchConfig)
``DANDELION_PREFETCH_HOT_K``            ``prefetch.hot_k``
``DANDELION_PREFETCH_FANOUT``           ``prefetch.fanout``
``DANDELION_PREFETCH_PEER``             ``prefetch.peer``
``DANDELION_PREDICT``                   ``predictor`` (default PredictorConfig)
``DANDELION_PREDICT_BIN_S``             ``predictor.bin_s``
``DANDELION_PREDICT_LEAD_S``            ``predictor.lead_s``
``DANDELION_PREDICT_NODES_AHEAD``       ``predictor.nodes_ahead``
``DANDELION_VERIFY``                    ``verify`` ("off" | "warn" | "strict")
======================================  =====================================

Determinism contract: an all-default ``PlatformConfig`` (every field
None/0.0) builds byte-identically to the legacy env-free path, and a
``from_env`` config reproduces exactly what the scattered env reads did
— fig10–13 outputs do not move (tools/check_bench_identity.py).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Mapping, Optional

from repro.core.artifacts import PrefetchConfig
from repro.core.control_plane import PredictorConfig
from repro.core.sim import EventLoop, ShardedEventLoop
from repro.sdk.errors import DeploymentError

#: legacy environment variables PlatformConfig supersedes
DEPRECATED_ENV_ALIASES = (
    "CROSSNODE",
    "CROSSNODE_SPREAD",
    "DANDELION_SHARDS",
    "DANDELION_SHARD_LOOKAHEAD_S",
)

_warned_deprecated = False


def _parse_bool(env: Mapping[str, str], var: str) -> Optional[bool]:
    raw = env.get(var)
    if raw is None or raw == "":
        return None
    if raw == "1":
        return True
    if raw == "0":
        return False
    raise DeploymentError(f"{var} must be '0' or '1', got {raw!r}")


def _parse_float(env: Mapping[str, str], var: str) -> Optional[float]:
    raw = env.get(var)
    if raw is None or raw == "":
        return None
    try:
        return float(raw)
    except ValueError:
        raise DeploymentError(f"{var} must be a number, got {raw!r}") from None


def _parse_int(env: Mapping[str, str], var: str) -> Optional[int]:
    raw = env.get(var)
    if raw is None or raw == "":
        return None
    try:
        return int(raw)
    except ValueError:
        raise DeploymentError(
            f"{var} must be an integer, got {raw!r}"
        ) from None


@dataclass(frozen=True)
class PlatformConfig:
    """Validated execution-environment configuration for ``Platform``.

    ``None`` means "platform default" everywhere — an all-default config
    is indistinguishable from passing no config at all.
    """

    # cross-node vertex scheduling (cluster shapes)
    crossnode: Optional[bool] = None
    crossnode_spread: Optional[bool] = None
    # node-sharded event loop; lookahead > 0 opts into the conservative
    # window (sound only when cross-node latencies cover it)
    shards: Optional[bool] = None
    shard_lookahead_s: float = 0.0
    # P2P artifact distribution on node join (core.artifacts) — needs a
    # cluster shape
    prefetch: Optional[PrefetchConfig] = None
    # trace-driven burst prediction (core.control_plane.BurstPredictor)
    # — needs the elastic shape
    predictor: Optional[PredictorConfig] = None
    # deploy-time purity verification gate (repro.analysis): None means
    # the platform default ("warn")
    verify: Optional[str] = None

    def __post_init__(self):
        if self.verify not in (None, "off", "warn", "strict"):
            raise DeploymentError(
                f"verify must be one of 'off', 'warn', 'strict', "
                f"got {self.verify!r}"
            )
        if self.shard_lookahead_s < 0.0:
            raise DeploymentError(
                f"shard_lookahead_s must be >= 0, got {self.shard_lookahead_s}"
            )
        if self.shard_lookahead_s > 0.0 and self.shards is not True:
            raise DeploymentError(
                "shard_lookahead_s needs shards=True (the plain EventLoop "
                "has no shard windows)"
            )
        if self.crossnode_spread and self.crossnode is False:
            raise DeploymentError(
                "crossnode_spread=True contradicts crossnode=False"
            )
        if self.prefetch is not None and \
                not isinstance(self.prefetch, PrefetchConfig):
            raise DeploymentError(
                f"prefetch= takes a core.PrefetchConfig, "
                f"got {type(self.prefetch).__name__}"
            )
        if self.predictor is not None and \
                not isinstance(self.predictor, PredictorConfig):
            raise DeploymentError(
                f"predictor= takes a core.PredictorConfig, "
                f"got {type(self.predictor).__name__}"
            )

    # ------------------------------------------------------------- env
    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None, *,
                 warn_deprecated: bool = False) -> "PlatformConfig":
        """Parse the environment spelling (module docstring table) into a
        validated config. Invalid values raise ``DeploymentError``
        instead of being silently coerced to off, which is what the
        scattered ``os.environ.get(...) == "1"`` reads did.

        ``warn_deprecated=True`` (the ``Platform`` default path) emits
        one ``DeprecationWarning`` per process when any legacy alias is
        set."""
        if env is None:
            import os
            env = os.environ
        if warn_deprecated:
            _warn_if_deprecated(env)

        shards = _parse_bool(env, "DANDELION_SHARDS")
        lookahead = _parse_float(env, "DANDELION_SHARD_LOOKAHEAD_S")
        if lookahead is not None and not shards:
            lookahead = None    # legacy reads ignored it without shards

        prefetch = None
        if _parse_bool(env, "DANDELION_PREFETCH"):
            kw = {}
            hot_k = _parse_int(env, "DANDELION_PREFETCH_HOT_K")
            fanout = _parse_int(env, "DANDELION_PREFETCH_FANOUT")
            peer = _parse_bool(env, "DANDELION_PREFETCH_PEER")
            if hot_k is not None:
                kw["hot_k"] = hot_k
            if fanout is not None:
                kw["fanout"] = fanout
            if peer is not None:
                kw["peer"] = peer
            try:
                prefetch = PrefetchConfig(**kw)
            except ValueError as e:
                raise DeploymentError(str(e)) from None

        predictor = None
        if _parse_bool(env, "DANDELION_PREDICT"):
            kw = {}
            bin_s = _parse_float(env, "DANDELION_PREDICT_BIN_S")
            lead_s = _parse_float(env, "DANDELION_PREDICT_LEAD_S")
            ahead = _parse_int(env, "DANDELION_PREDICT_NODES_AHEAD")
            if bin_s is not None:
                kw["bin_s"] = bin_s
            if lead_s is not None:
                kw["lead_s"] = lead_s
            if ahead is not None:
                kw["nodes_ahead"] = ahead
            try:
                predictor = PredictorConfig(**kw)
            except ValueError as e:
                raise DeploymentError(str(e)) from None

        verify = env.get("DANDELION_VERIFY") or None
        if verify not in (None, "off", "warn", "strict"):
            raise DeploymentError(
                f"DANDELION_VERIFY must be 'off', 'warn' or 'strict', "
                f"got {verify!r}"
            )

        return cls(
            crossnode=_parse_bool(env, "CROSSNODE"),
            crossnode_spread=_parse_bool(env, "CROSSNODE_SPREAD"),
            shards=shards,
            shard_lookahead_s=lookahead or 0.0,
            prefetch=prefetch,
            predictor=predictor,
            verify=verify,
        )

    # ------------------------------------------------------------ build
    def build_loop(self) -> EventLoop:
        """The event loop this config asks for: the node-sharded loop
        when ``shards=True`` (exact mode unless ``shard_lookahead_s``
        opts into the conservative window), else the plain
        ``EventLoop`` — exactly the legacy ``DANDELION_SHARDS``
        behavior."""
        if self.shards:
            return ShardedEventLoop(lookahead_s=self.shard_lookahead_s)
        return EventLoop()

    def with_overrides(self, *, crossnode=None, crossnode_spread=None,
                       verify=None) -> "PlatformConfig":
        """This config with explicit ``Platform`` kwargs layered on top
        (an explicit kwarg always beats the config/env value)."""
        out = self
        if crossnode is not None:
            out = replace(out, crossnode=crossnode)
        if crossnode_spread is not None:
            out = replace(out, crossnode_spread=crossnode_spread)
        if verify is not None:
            out = replace(out, verify=verify)
        return out


def _warn_if_deprecated(env: Mapping[str, str]) -> None:
    global _warned_deprecated
    if _warned_deprecated:
        return
    legacy = [v for v in DEPRECATED_ENV_ALIASES if env.get(v)]
    if legacy:
        _warned_deprecated = True
        warnings.warn(
            f"environment variables {', '.join(legacy)} are deprecated "
            f"aliases; pass sdk.PlatformConfig(...) to Platform(config=...) "
            f"instead",
            DeprecationWarning,
            stacklevel=3,
        )
