"""Typed function declaration: the ``@sdk.function`` decorator.

A ``FunctionSpec`` captures *all* ``ComputeFunction`` metadata at the
definition site — declared input/output sets, context bytes, timeout,
the optional jax payload, modeled service time, memoization and
batchability flags, and an optional calibrated ``ColdStartProfile`` —
so registries, compositions, and platforms are configured from one
declaration instead of hand-wired per call site.

Three ways to make one:

  * ``@sdk.function(inputs=("doc",), outputs=("stats",))`` — decorate a
    pure python payload ``fn(inputs: SetDict) -> SetDict``; the spec
    name defaults to the function name;
  * ``sdk.declare(name, fn, inputs=..., outputs=...)`` — programmatic
    form for dynamically generated payloads (benchmark sweeps);
  * ``sdk.ref(name, inputs=..., outputs=...)`` — a *reference* to a
    function registered elsewhere (no payload); compositions may wire
    it, and deployment checks it resolves.

A spec is used two ways:

  * called with port expressions inside ``with sdk.composition(...)``
    it adds a compute vertex and returns its handle
    (``count(doc=fetch.responses)``);
  * called with a plain ``SetDict`` it executes the payload directly
    (handy in tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

from repro.core.coldstart import ColdStartProfile
from repro.core.dag import RetryPolicy
from repro.core.items import SetDict
from repro.sdk.errors import DeclarationError, WiringError

DEFAULT_CONTEXT_BYTES = 1 << 20
DEFAULT_TIMEOUT_S = 60.0


def _retry_from_sugar(
    name: str,
    retry: Optional[RetryPolicy],
    retries: Optional[int],
    backoff_s: float,
    max_backoff_s: float,
    retry_timeouts: bool,
) -> Optional[RetryPolicy]:
    """Fold the ``retries=``/``backoff_s=``/``retry_timeouts=`` sugar into
    a ``RetryPolicy`` (None when nothing was asked for: the platform /
    dispatcher default applies)."""
    if retry is not None:
        if retries is not None or backoff_s or retry_timeouts:
            raise DeclarationError(
                f"{name}: pass retry= OR the retries=/backoff_s=/"
                f"retry_timeouts= sugar, not both"
            )
        return retry
    if retries is None and not backoff_s and not retry_timeouts:
        return None
    try:
        return RetryPolicy(
            max_retries=2 if retries is None else retries,
            base_backoff_s=backoff_s,
            max_backoff_s=max_backoff_s,
            retry_timeouts=retry_timeouts,
        )
    except ValueError as e:
        raise DeclarationError(f"{name}: {e}") from e


def _check_sets(name: str, role: str, sets) -> Tuple[str, ...]:
    if isinstance(sets, str):
        # tuple("doc") would silently split into per-character set names
        raise DeclarationError(
            f"{name}: {role}s must be a tuple of set names, got the "
            f"string {sets!r} (did you mean ({sets!r},)?)"
        )
    sets = tuple(sets)
    for s in sets:
        if not isinstance(s, str) or not s:
            raise DeclarationError(
                f"{name}: {role} set names must be non-empty strings, got {s!r}"
            )
    if len(set(sets)) != len(sets):
        raise DeclarationError(f"{name}: duplicate {role} set names in {sets}")
    return sets


@dataclass
class FunctionSpec:
    """One compute-function declaration (see module docstring)."""

    name: str
    fn: Optional[Callable[[SetDict], SetDict]]
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    context_bytes: int = DEFAULT_CONTEXT_BYTES
    timeout_s: float = DEFAULT_TIMEOUT_S
    # optional jax payload for the AOT cold-start backends
    jax_fn: Optional[Callable] = None
    abstract_args: Tuple[Any, ...] = ()
    # modeled execution time; None -> execute for real and measure
    service_time_s: Optional[float] = None
    memoize: bool = True
    batchable: bool = False
    # calibrated dispatcher profile; Platform.deploy collects these
    profile: Optional[ColdStartProfile] = None
    # per-vertex failure handling; None -> platform/dispatcher default
    retry: Optional[RetryPolicy] = None
    # purity escape hatch: the payload is knowingly impure (stateful
    # batcher, real checkpoint I/O). Verification still runs but its
    # findings are waived and the declaration is recorded in the
    # PurityReport's ``unsafe`` list — an audited opt-out, not a blind
    # spot.
    pure_unsafe: bool = False

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise DeclarationError(
                f"function name must be a non-empty string, got {self.name!r}"
            )
        self.inputs = _check_sets(self.name, "input", self.inputs)
        self.outputs = _check_sets(self.name, "output", self.outputs)
        if self.context_bytes <= 0:
            raise DeclarationError(
                f"{self.name}: context_bytes must be positive, "
                f"got {self.context_bytes}"
            )
        if self.timeout_s <= 0:
            raise DeclarationError(
                f"{self.name}: timeout_s must be positive, got {self.timeout_s}"
            )

    # ------------------------------------------------------------------
    @property
    def is_ref(self) -> bool:
        """True for ``sdk.ref`` specs: no payload, registered elsewhere."""
        return self.fn is None

    def register_into(self, registry):
        """Register the payload into a ``FunctionRegistry`` (the exact
        ``register_function`` call hand-wired code makes)."""
        if self.is_ref:
            raise DeclarationError(
                f"{self.name}: sdk.ref declarations carry no payload to "
                f"register; register the real function (or use sdk.declare)"
            )
        return registry.register_function(
            self.name,
            self.fn,
            context_bytes=self.context_bytes,
            jax_fn=self.jax_fn,
            abstract_args=self.abstract_args,
            service_time_s=self.service_time_s,
            memoize=self.memoize,
            batchable=self.batchable,
            pure_unsafe=self.pure_unsafe,
        )

    # ------------------------------------------------------------------
    def __call__(self, *args, _name: Optional[str] = None,
                 _context_bytes: Optional[int] = None,
                 _timeout_s: Optional[float] = None,
                 _retry: Optional[RetryPolicy] = None,
                 _batch_units: Optional[int] = None, **ports):
        """Inside ``with sdk.composition(...)``: add a compute vertex fed
        by ``ports`` (output ports / ``app.input`` refs / ``each``/``key``
        wrappers) and return its handle. ``_name`` overrides the vertex
        name (default: the function name); ``_context_bytes``,
        ``_timeout_s``, and ``_retry`` override the declared per-vertex
        resources / failure policy; ``_batch_units`` declares how many
        units of a coalesced BATCH step this vertex occupies when the
        function is batchable (chunked prefill spans several).

        Called with a single ``SetDict`` positional argument instead, the
        payload executes directly (no platform involved).
        """
        if args:
            if len(args) == 1 and isinstance(args[0], dict) and not ports:
                if self.is_ref:
                    raise DeclarationError(
                        f"{self.name}: reference spec has no payload to run"
                    )
                return self.fn(args[0])
            raise WiringError(
                f"{self.name}: pass ports as keyword arguments "
                f"(e.g. {self.name}({self.inputs[0] if self.inputs else 'x'}"
                f"=other.out)) or a single SetDict to execute the payload"
            )
        from repro.sdk.builder import current_app

        app = current_app()
        return app._add_compute(
            self, name=_name, context_bytes=_context_bytes,
            timeout_s=_timeout_s, retry=_retry, ports=ports,
            batch_units=_batch_units,
        )


def function(
    inputs: Tuple[str, ...],
    outputs: Tuple[str, ...],
    *,
    name: Optional[str] = None,
    context_bytes: int = DEFAULT_CONTEXT_BYTES,
    timeout_s: float = DEFAULT_TIMEOUT_S,
    jax_fn: Optional[Callable] = None,
    abstract_args: Tuple[Any, ...] = (),
    service_time_s: Optional[float] = None,
    memoize: bool = True,
    batchable: bool = False,
    profile: Optional[ColdStartProfile] = None,
    retry: Optional[RetryPolicy] = None,
    retries: Optional[int] = None,      # sugar: RetryPolicy.max_retries
    backoff_s: float = 0.0,             # sugar: capped exponential base
    max_backoff_s: float = 30.0,        # sugar: backoff cap
    retry_timeouts: bool = False,       # sugar: timeouts retryable too
    pure_unsafe: bool = False,          # audited purity opt-out
) -> Callable[[Callable[[SetDict], SetDict]], FunctionSpec]:
    """Decorator form: ``@sdk.function(inputs=..., outputs=...)``.

    Failure handling: pass a full ``sdk.RetryPolicy`` via ``retry=``, or
    the ``retries=``/``backoff_s=``/``retry_timeouts=`` sugar (e.g.
    ``@sdk.function(..., retries=3, backoff_s=0.05, retry_timeouts=True)``
    for 3 resubmissions at 50/100/200ms capped backoff, rescuing
    timeouts). Omit all of them to inherit the platform default."""

    def wrap(fn: Callable[[SetDict], SetDict]) -> FunctionSpec:
        # inputs/outputs validated (incl. the bare-string typo) by
        # FunctionSpec.__post_init__
        return FunctionSpec(
            name=name or fn.__name__, fn=fn,
            inputs=inputs, outputs=outputs,
            context_bytes=context_bytes, timeout_s=timeout_s,
            jax_fn=jax_fn, abstract_args=tuple(abstract_args),
            service_time_s=service_time_s, memoize=memoize,
            batchable=batchable, profile=profile,
            retry=_retry_from_sugar(
                name or fn.__name__, retry, retries, backoff_s,
                max_backoff_s, retry_timeouts,
            ),
            pure_unsafe=pure_unsafe,
        )

    return wrap


def declare(
    name: str,
    fn: Callable[[SetDict], SetDict],
    *,
    inputs: Tuple[str, ...],
    outputs: Tuple[str, ...],
    retries: Optional[int] = None,
    backoff_s: float = 0.0,
    max_backoff_s: float = 30.0,
    retry_timeouts: bool = False,
    **kwargs,
) -> FunctionSpec:
    """Programmatic form of ``@sdk.function`` for generated payloads.
    Accepts the same retry sugar (or a full ``retry=RetryPolicy``)."""
    kwargs["retry"] = _retry_from_sugar(
        name, kwargs.get("retry"), retries, backoff_s, max_backoff_s,
        retry_timeouts,
    )
    return FunctionSpec(name=name, fn=fn, inputs=inputs,
                        outputs=outputs, **kwargs)


def ref(
    name: str,
    *,
    inputs: Tuple[str, ...],
    outputs: Tuple[str, ...],
    context_bytes: int = DEFAULT_CONTEXT_BYTES,
    timeout_s: float = DEFAULT_TIMEOUT_S,
) -> FunctionSpec:
    """A typed reference to a function registered elsewhere (e.g. by
    ``repro.apps.inference_service.register_inference_service``): usable
    in compositions, checked to resolve at deployment."""
    return FunctionSpec(name=name, fn=None, inputs=inputs,
                        outputs=outputs, context_bytes=context_bytes,
                        timeout_s=timeout_s)
