"""The ``Platform`` facade: one front door for every execution shape.

Hand-wired code picks among three divergent entry-point triplets —
``WorkerNode.invoke/invoke_at/invoke_stream`` on a node,
``ClusterManager.invoke/invoke_at/invoke_stream`` on a pool — and wires
``FunctionRegistry``/``ServiceRegistry``/``EventLoop``/node factories by
hand per driver. A ``Platform`` owns all of that behind one object:

    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=8))      # 1 node
    platform = sdk.Platform(pool=[sdk.NodeSpec(...), ...])       # static
    platform = sdk.Platform(elastic=sdk.Elastic(config=cfg))     # elastic

    platform.deploy(app)                 # register functions + graph
    h = platform.invoke(app, inputs)     # -> InvocationHandle (future)
    h.result()                           # run loop until done, or raise
    platform.submit_stream(arrivals)     # bulk trace injection
    platform.run(until=...)

``invoke``/``submit_stream`` behave identically across the three shapes
(same signature, same handle semantics); only the routing underneath
changes. Nodes are built lazily at first use, after deployments, so the
shared profiles dict every node's dispatcher reads is fully populated
when factories run.

Determinism contract: a Platform adds no scheduling, RNG draws, or
timing of its own — it forwards to exactly the node/cluster calls the
hand-wired drivers made, so migrated benchmarks reproduce their
committed CSV rows byte-for-byte (gated by tools/check_bench_identity.py).
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.cluster import ClusterManager
from repro.core.coldstart import ColdStartProfile, TransferProfile
from repro.core.control_plane import ControlPlaneConfig, ElasticControlPlane
from repro.core.dag import Composition, RetryPolicy
from repro.core.http import ServiceRegistry
from repro.core.items import SetDict
from repro.core.node import WorkerNode
from repro.core.registry import FunctionRegistry
from repro.core.sim import EventLoop, ShardedEventLoop
from repro.sdk.builder import App
from repro.sdk.config import PlatformConfig
from repro.sdk.errors import DeploymentError, InvocationFailed, PurityError
from repro.sdk.functions import FunctionSpec


def _safe_eq(a, b) -> bool:
    """Equality that tolerates values whose ``==`` is non-boolean
    (numpy arrays in lambda defaults): identity first, then ``==``
    coerced to bool, treating any comparison error as unequal."""
    if a is b:
        return True
    try:
        r = a == b
        if hasattr(r, "all"):   # elementwise (numpy/jax) comparison
            return (getattr(a, "shape", None) == getattr(b, "shape", None)
                    and bool(r.all()))
        return bool(r)
    except Exception:
        return False


def _same_payload(a, b) -> bool:
    """Whether two payload callables are interchangeable for idempotent
    re-deployment: the same object, or functions from the same
    definition site with equal defaults and closure values (spec
    factories like ``log_processing_specs`` recreate equivalent lambdas
    per call)."""
    if a is b:
        return True
    ca, cb = getattr(a, "__code__", None), getattr(b, "__code__", None)
    if ca is None or cb is None or ca is not cb:
        return False
    da = getattr(a, "__defaults__", None) or ()
    db = getattr(b, "__defaults__", None) or ()
    if len(da) != len(db) or not all(map(_safe_eq, da, db)):
        return False
    fa, fb = a.__closure__, b.__closure__
    if (fa is None) != (fb is None):
        return False
    if fa is not None:
        try:
            va = [c.cell_contents for c in fa]
            vb = [c.cell_contents for c in fb]
        except ValueError:     # unset cell: treat as conflicting
            return False
        if len(va) != len(vb) or not all(map(_safe_eq, va, vb)):
            return False
    return True


def _default_loop() -> EventLoop:
    """The loop a Platform builds when none is passed in.

    ``DANDELION_SHARDS=1`` opts into the node-sharded loop
    (``core.sim.ShardedEventLoop``): every node built by this platform
    schedules on its own shard heap. The default mode is *exact* —
    byte-identical event order to the single merged heap — unless
    ``DANDELION_SHARD_LOOKAHEAD_S`` sets a conservative-window lookahead
    (sound only for topologies whose cross-node ``TRANSFER`` latencies
    are at least the lookahead; see the ShardedEventLoop docstring).
    Unset, the plain ``EventLoop`` remains the zero-risk default."""
    if os.environ.get("DANDELION_SHARDS") == "1":
        la = float(os.environ.get("DANDELION_SHARD_LOOKAHEAD_S", "0.0"))
        return ShardedEventLoop(lookahead_s=la)
    return EventLoop()


def _node_loop(loop, name: str):
    """The loop view a node named ``name`` should schedule on: its shard
    of a ``ShardedEventLoop``, or the shared loop itself otherwise."""
    shard = getattr(loop, "shard", None)
    return loop if shard is None else shard(name)


@dataclass
class NodeSpec:
    """Declarative ``WorkerNode`` shape: everything the constructor
    takes, minus the wiring a Platform owns (registry, services, loop,
    shared profiles). ``weight_store`` may be a ``WeightStore`` instance
    or a zero-argument factory (per-node stores)."""

    num_slots: int = 16
    comm_slots: int = 1
    backend: str = "dandelion"
    controller_enabled: bool = True
    controller_interval_s: float = 0.030
    max_retries: int = 2
    # node-wide RetryPolicy default (vertices may override); None keeps
    # the legacy max_retries behavior: zero backoff, timeouts fatal
    retry: Optional[RetryPolicy] = None
    hedge_after_s: float = 0.0
    cache_miss_rate: float = 0.0
    code_cache_entries: int = 0
    base_bytes: int = 0
    batch_slots: int = 0
    batch_model: Any = None
    # per-fn {fn_name: BatchStepModel}: multiplexed models on one node,
    # and the marker for *elastic* batch capability (work queues on the
    # BATCH engine even while the replica pool is scaled to zero)
    batch_models: Any = None
    max_batch: int = 32
    # RAM arena committed per batch replica (KV/activation working set)
    replica_bytes: int = 0
    weight_store: Any = None
    seed: int = 0
    # None -> auto-named: "node0" single, "node<i>" in a pool, control-
    # plane names ("en<i>") under Elastic
    name: Optional[str] = None

    def build(self, platform: "Platform",
              name: Optional[str] = None) -> WorkerNode:
        ws = self.weight_store() if callable(self.weight_store) \
            else self.weight_store
        name = name or self.name or "node0"
        return WorkerNode(
            platform.registry,
            platform.services,
            loop=_node_loop(platform.loop, name),
            num_slots=self.num_slots,
            comm_slots=self.comm_slots,
            backend=self.backend,
            profiles=platform.profiles,
            controller_enabled=self.controller_enabled,
            controller_interval_s=self.controller_interval_s,
            max_retries=self.max_retries,
            retry_policy=self.retry,
            hedge_after_s=self.hedge_after_s,
            cache_miss_rate=self.cache_miss_rate,
            code_cache_entries=self.code_cache_entries,
            base_bytes=self.base_bytes,
            batch_slots=self.batch_slots,
            batch_model=self.batch_model,
            batch_models=self.batch_models,
            max_batch=self.max_batch,
            replica_bytes=self.replica_bytes,
            weight_store=ws,
            seed=self.seed,
            name=name,
        )


@dataclass
class Elastic:
    """Elastic-cluster shape: an ``ElasticControlPlane`` over nodes built
    from ``node`` (names assigned by the control plane)."""

    config: ControlPlaneConfig = field(default_factory=ControlPlaneConfig)
    node: NodeSpec = field(default_factory=NodeSpec)
    seed: int = 0
    journal: bool = False


class InvocationHandle:
    """Future for one invocation: filled by the dispatcher's completion
    callback; ``result()`` drives the (virtual-time) loop to completion.
    ``cancel()`` revokes the request mid-flight (or before dispatch)."""

    def __init__(self, platform: "Platform", comp: Composition,
                 on_done: Optional[Callable] = None):
        self._platform = platform
        self.comp = comp
        self.invocation = None          # InvocationRun once finished
        self._on_done = on_done
        self._live_inv = None           # current live InvocationRun
        self._cancel_requested = False

    # dispatcher completion callback
    def _complete(self, inv) -> None:
        self.invocation = inv
        self._live_inv = None
        if self._on_done is not None:
            self._on_done(inv)

    # cluster admission callback: fires per attempt (incl. node-death
    # re-executions), so cancel() always reaches the CURRENT run
    def _started(self, inv) -> None:
        self._live_inv = inv
        if self._cancel_requested:
            inv.dispatcher.cancel(inv)

    @property
    def done(self) -> bool:
        """Completed successfully."""
        return self.invocation is not None and self.invocation.done

    @property
    def failed(self) -> Optional[str]:
        """Failure reason (names the failing vertex), or None."""
        return None if self.invocation is None else self.invocation.failed

    @property
    def cancelled(self) -> bool:
        """Cancellation took effect: revoked before dispatch, or the
        live run was torn down with kind "cancelled"."""
        if self.invocation is not None:
            return self.invocation.failure_kind == "cancelled"
        return self._cancel_requested

    def cancel(self) -> bool:
        """Revoke this request. Mid-flight, the dispatcher flushes its
        queued vertices, marks its live engine tasks cancelled, and
        releases contexts and weight refcounts exactly once; before the
        scheduled fire time (``invoke(at=...)``), the dispatch is simply
        skipped. Returns False if the invocation already finished."""
        if self.invocation is not None:
            return False
        self._cancel_requested = True
        inv = self._live_inv
        if inv is None:
            return True     # not fired yet; _fire will skip the dispatch
        return inv.dispatcher.cancel(inv)

    @property
    def outputs(self) -> SetDict:
        return {} if self.invocation is None else self.invocation.outputs

    @property
    def latency(self) -> Optional[float]:
        return None if self.invocation is None else self.invocation.latency

    def result(self, until: Optional[float] = None) -> SetDict:
        """Output sets of the finished invocation; drives the platform
        loop (to ``until``) if still pending. Raises ``InvocationFailed``
        on failure or if the loop drains without completing it."""
        if self.invocation is None:
            self._platform.run(until=until)
        if self.invocation is None:
            if until is not None:
                # not a failure: the horizon cut the run short
                raise InvocationFailed(
                    f"{self.comp.name}: invocation still pending at "
                    f"t={until}; run() further or call result() again"
                )
            raise InvocationFailed(
                f"{self.comp.name}: loop drained without completing the "
                f"invocation"
            )
        if self.invocation.failed:
            raise InvocationFailed(
                f"{self.comp.name}: {self.invocation.failed}"
            )
        return self.invocation.outputs


class Platform:
    """Owns registries, services, the event loop, and one execution
    backend (single node / static pool / elastic cluster). See module
    docstring for the lifecycle."""

    def __init__(
        self,
        *,
        node: Optional[NodeSpec] = None,
        pool: Optional[List[NodeSpec]] = None,
        elastic: Optional[Elastic] = None,
        registry: Optional[FunctionRegistry] = None,
        services: Optional[ServiceRegistry] = None,
        loop: Optional[EventLoop] = None,
        profiles: Optional[Dict[str, ColdStartProfile]] = None,
        crossnode: Optional[bool] = None,
        transfer_links: Optional[Dict[Tuple[str, str], TransferProfile]] = None,
        transfer_profile: Optional[TransferProfile] = None,
        memoize: bool = True,
        restart_attempts: int = 3,
        route_policy: str = "outstanding",
        batch_router: Any = None,
        crossnode_spread: Optional[bool] = None,
        config: Optional[PlatformConfig] = None,
        verify: Optional[str] = None,
    ):
        shapes = [s for s in (node, pool, elastic) if s is not None]
        if len(shapes) > 1:
            raise DeploymentError(
                "pass exactly one of node=, pool=, elastic= (default: one "
                "node)"
            )
        if pool is not None and not pool:
            raise DeploymentError("pool= needs at least one NodeSpec")
        # one validated parse of the env spelling when no explicit config
        # is passed; explicit Platform kwargs layer on top either way
        if config is None:
            config = PlatformConfig.from_env(warn_deprecated=True)
        self.config = config.with_overrides(
            crossnode=crossnode, crossnode_spread=crossnode_spread,
            verify=verify,
        )
        crossnode = self.config.crossnode
        crossnode_spread = self.config.crossnode_spread
        if pool is None and elastic is None and (
            crossnode or transfer_links or transfer_profile
        ):
            raise DeploymentError(
                "crossnode/transfer options need a cluster shape "
                "(pool= or elastic=); a single node has no peers"
            )
        if pool is None and elastic is None and self.config.prefetch:
            raise DeploymentError(
                "PlatformConfig.prefetch needs a cluster shape "
                "(pool= or elastic=); a single node has no peers to warm"
            )
        if elastic is None and self.config.predictor:
            raise DeploymentError(
                "PlatformConfig.predictor needs the elastic shape; "
                "prediction drives node boots"
            )
        self._node_spec = node if shapes else NodeSpec()
        self._pool_specs = list(pool) if pool is not None else None
        self._elastic = elastic
        self.registry = registry or FunctionRegistry(memoize=memoize)
        self.services = services or ServiceRegistry()
        self.loop = loop if loop is not None else self.config.build_loop()
        # shared per-function dispatcher profiles: deploy() merges each
        # spec's calibrated profile in-place, so nodes built later (and
        # the elastic factory's nodes) all read the same dict
        self.profiles: Dict[str, ColdStartProfile] = \
            profiles if profiles is not None else {}
        if route_policy not in ("outstanding", "batch_aware"):
            raise DeploymentError(f"unknown route_policy {route_policy!r}")
        if route_policy != "outstanding" and pool is None and elastic is None:
            raise DeploymentError(
                "route_policy= needs a cluster shape (pool= or elastic=); "
                "a single node has nothing to route over"
            )
        self._crossnode = crossnode
        self._crossnode_spread = crossnode_spread
        self._route_policy = route_policy
        self._batch_router = batch_router
        self._transfer_links = transfer_links
        self._transfer_profile = transfer_profile
        # node-death re-execution budget for cluster shapes
        self._restart_attempts = restart_attempts
        self._worker: Optional[WorkerNode] = None
        self._cluster: Optional[ClusterManager] = None
        self._cp: Optional[ElasticControlPlane] = None
        self._built = False
        # most recent deploy-time PurityReport (None before any deploy
        # or with verify="off")
        self.last_verify_report = None

    # ------------------------------------------------------- deployment
    def service(self, host: str, handler, **kwargs) -> None:
        """Register an external HTTP service endpoint (see
        ``ServiceRegistry.register`` for latency/bandwidth knobs)."""
        self.services.register(host, handler, **kwargs)

    def deploy(self, target, *,
               profiles: Optional[Dict[str, ColdStartProfile]] = None):
        """Make an application invokable: register its function
        declarations (payloads, metadata, calibrated profiles) and its
        validated composition. Accepts an ``App``, a raw IR
        ``Composition`` (functions must already be registered), or a bare
        ``FunctionSpec``. Returns the registered ``Composition`` (or
        ``ComputeFunction`` for a bare spec). ``profiles`` overrides /
        extends the per-function dispatcher profiles."""
        if isinstance(target, FunctionSpec):
            if target.is_ref and target.name not in self.registry.functions:
                raise DeploymentError(
                    f"sdk.ref {target.name!r} does not resolve: no such "
                    f"function registered on this platform"
                )
            self._verify_gate(target)
            cf = self._register_spec(target)
            self._merge_profiles(profiles)
            return cf
        if isinstance(target, App):
            comp = target.compile(self.registry)
            self._verify_gate(target)
            for spec in target.function_specs():
                self._register_spec(spec)
        elif isinstance(target, Composition):
            comp = target
            self._verify_gate(comp)
        else:
            raise DeploymentError(
                f"deploy() takes an App, Composition, or FunctionSpec, "
                f"got {type(target).__name__}"
            )
        try:
            self.registry.register_composition(comp)
        except ValueError as e:
            raise DeploymentError(str(e)) from e
        self._merge_profiles(profiles)
        return comp

    def _verify_gate(self, target) -> None:
        """Deploy-time purity verification (the ``verify=`` knob):
        ``off`` skips analysis entirely, ``warn`` (default) emits one
        ``UserWarning`` naming the violations, ``strict`` raises
        ``sdk.PurityError``. The report (including waived findings and
        ``pure_unsafe`` opt-outs) is kept on ``last_verify_report``."""
        mode = self.config.verify or "warn"
        if mode == "off":
            return
        from repro.sdk.verify import verify as _verify

        report = _verify(
            target, registry=self.registry,
            cluster=self._pool_specs is not None or self._elastic is not None,
            crossnode=bool(self._crossnode),
        )
        self.last_verify_report = report
        if not report.blocking:
            return
        if mode == "strict":
            raise PurityError(report)
        warnings.warn(
            "purity verification found "
            f"{len(report.blocking)} violation(s) "
            "(deploying anyway; Platform(verify='strict') rejects):\n"
            + "\n".join(f.render() for f in report.blocking),
            stacklevel=3,
        )

    def _register_spec(self, spec: FunctionSpec):
        if spec.is_ref:
            # reference to a function registered out-of-band; the
            # composition registration below checks it resolves
            return None
        existing = self.registry.functions.get(spec.name)
        if existing is not None:
            if not _same_payload(existing.fn, spec.fn):
                raise DeploymentError(
                    f"function {spec.name!r} already registered with a "
                    f"different payload; function names are global to a "
                    f"platform"
                )
            cf = existing              # idempotent re-deploy
        else:
            cf = spec.register_into(self.registry)
        if spec.profile is not None:
            self.profiles[spec.name] = spec.profile
        return cf

    def _merge_profiles(self, profiles) -> None:
        if profiles:
            self.profiles.update(profiles)

    # ---------------------------------------------------------- backend
    def _build(self) -> None:
        if self._built:
            return
        self._built = True
        distributor = None
        if self.config.prefetch is not None:
            from repro.core.artifacts import P2PDistributor
            distributor = P2PDistributor(
                self.loop, config=self.config.prefetch
            )
        if self._elastic is not None:
            e = self._elastic
            cp_cfg = e.config
            if self._route_policy == "batch_aware":
                # compose batch-aware routing with node autoscaling; the
                # default "outstanding" leaves the elastic config (and
                # its byte-pinned decision stream) untouched
                cp_cfg = dataclasses.replace(
                    cp_cfg, route_policy="batch_aware",
                    batch_router=self._batch_router or cp_cfg.batch_router,
                )
            self._cp = ElasticControlPlane(
                self.loop,
                lambda name: e.node.build(self, name=name),
                config=cp_cfg,
                seed=e.seed,
                journal=e.journal,
                predictor=self.config.predictor,
                distributor=distributor,
            )
            self._cluster = ClusterManager(
                control_plane=self._cp,
                crossnode=self._crossnode,
                crossnode_spread=self._crossnode_spread,
                transfer_links=self._transfer_links,
                transfer_profile=self._transfer_profile,
                restart_attempts=self._restart_attempts,
                distributor=distributor,
            )
        elif self._pool_specs is not None:
            # auto-name unnamed specs by position; explicit duplicate
            # names would corrupt per-link transfer accounting
            nodes = [
                spec.build(self, name=f"node{i}" if spec.name is None
                           else None)
                for i, spec in enumerate(self._pool_specs)
            ]
            names = [n.name for n in nodes]
            if len(set(names)) != len(names):
                raise DeploymentError(
                    f"pool node names must be unique, got {names}"
                )
            self._cluster = ClusterManager(
                nodes, self.loop,
                crossnode=self._crossnode,
                crossnode_spread=self._crossnode_spread,
                transfer_links=self._transfer_links,
                transfer_profile=self._transfer_profile,
                restart_attempts=self._restart_attempts,
                route_policy=self._route_policy,
                batch_router=self._batch_router,
                distributor=distributor,
            )
        else:
            self._worker = self._node_spec.build(self)

    @property
    def node(self) -> Optional[WorkerNode]:
        """The single worker node (single-node shape only)."""
        self._build()
        return self._worker

    @property
    def nodes(self) -> List[WorkerNode]:
        """All worker nodes currently up."""
        self._build()
        if self._worker is not None:
            return [self._worker]
        return self._cluster.nodes

    @property
    def cluster(self) -> Optional[ClusterManager]:
        self._build()
        return self._cluster

    @property
    def control_plane(self) -> Optional[ElasticControlPlane]:
        self._build()
        return self._cp

    @property
    def placer(self):
        """The ``CrossNodePlacer`` when cross-node scheduling is on."""
        self._build()
        return None if self._cluster is None else self._cluster.placer

    @property
    def distributor(self):
        """The ``P2PDistributor`` when ``PlatformConfig.prefetch`` is
        set, or None."""
        self._build()
        return None if self._cluster is None else self._cluster.distributor

    @property
    def predictor(self):
        """The elastic shape's ``BurstPredictor`` when
        ``PlatformConfig.predictor`` is set, or None."""
        self._build()
        return None if self._cp is None else self._cp.predictor

    @property
    def replica_autoscaler(self):
        """The elastic shape's ``ReplicaAutoscaler`` (batch-replica
        scaling), or None when not configured
        (``ControlPlaneConfig.replicas``)."""
        self._build()
        return None if self._cp is None else self._cp.replica_autoscaler

    @property
    def latency(self):
        """End-to-end latency stats at this platform's front door."""
        self._build()
        if self._worker is not None:
            return self._worker.latency
        return self._cluster.latency

    # -------------------------------------------------------- invocation
    def _comp(self, target) -> Composition:
        if isinstance(target, App):
            return target.compile()
        if isinstance(target, Composition):
            return target
        raise DeploymentError(
            f"expected an App or Composition, got {type(target).__name__}"
        )

    def _fire(self, comp: Composition, inputs: SetDict,
              on_done: Optional[Callable],
              handle: Optional[InvocationHandle] = None) -> None:
        if self._worker is not None:
            inv = self._worker.invoke(comp, inputs, on_done=on_done)
            if handle is not None and not inv.done and not inv.failed:
                handle._started(inv)
        else:
            # on_start fires per admission (including node-death
            # re-executions), keeping handle.cancel() aimed at the
            # current live run
            on_start = None if handle is None else handle._started
            self._cluster.invoke(comp, inputs, on_done=on_done,
                                 on_start=on_start)

    def invoke(
        self,
        app,
        inputs: Optional[SetDict] = None,
        *,
        at: Optional[float] = None,
        on_done: Optional[Callable] = None,
    ) -> InvocationHandle:
        """Invoke an application (now, or at virtual time ``at``) and
        return a handle. Works identically on all three backend shapes;
        ``on_done(inv)`` additionally fires on completion if given."""
        self._build()
        comp = self._comp(app)
        handle = InvocationHandle(self, comp, on_done)
        inputs = inputs or {}
        if at is None:
            self._fire(comp, inputs, handle._complete, handle=handle)
        else:
            def fire():
                if handle._cancel_requested:
                    return      # cancelled before the scheduled dispatch
                self._fire(comp, inputs, handle._complete, handle=handle)

            self.loop.at(at, fire)
        return handle

    def submit_stream(self, arrivals) -> None:
        """Bulk trace injection: ``arrivals`` is a time-sorted iterable
        of ``(t, app, inputs)`` or ``(t, app, inputs, on_done)`` tuples,
        replayed through one heap cursor (``EventLoop.at_stream``) — the
        fast path for trace-scale workloads. No handles are created; use
        per-arrival ``on_done`` callbacks to observe completions."""
        self._build()

        def norm():
            for a in arrivals:
                if len(a) == 3:
                    t, app, inputs = a
                    cb = None
                else:
                    t, app, inputs, cb = a
                yield t, (self._comp(app), inputs, cb)

        self.loop.at_stream(
            norm(), lambda cic: self._fire(cic[0], cic[1], cic[2])
        )

    def run(self, until: Optional[float] = None) -> None:
        """Drive the virtual-time loop (to ``until``, or until idle)."""
        self._build()
        self.loop.run(until=until)
