"""Declarative composition builder: port-level dataflow expressions.

Applications are authored as dataflow over typed function declarations
(the paper's SS4.1 composition language, made first-class):

    @sdk.function(inputs=("doc",), outputs=("stats",))
    def word_count(ins): ...

    with sdk.composition("quickstart") as app:
        fetch = sdk.http("fetch", requests=app.input("request"))
        count = word_count(_name="count", doc=fetch.responses)
        app.output("stats", count.stats)

and compile (``App.compile()``) to the existing ``core/dag.py``
``Composition`` IR *unchanged* — the engine layers below never see the
SDK. Building is eager: every wiring call validates immediately and
raises a ``WiringError`` naming the offending vertex/port, so a typo
fails at its own line, not at invoke time.

Fan-out sugar: wrap a producer port in ``sdk.each(...)`` / ``sdk.key(...)``
to set the edge's distribution keyword (one consumer instance per item /
per distinct item key); at most one such edge may target a vertex —
checked at the wiring call. Plain ports broadcast (``all``).

Multi-feed inputs: pass a list of ports (``toks=[pre.tok, d.tok]``) or
feed an existing vertex handle incrementally (``det.feed(toks=d.tok)``).

Nesting: a finished ``App`` is itself callable inside another builder
and becomes a subgraph vertex whose ports are the inner composition's
input/output bindings.

Vertices are added to the IR in declaration order and edges in wiring
order, so an SDK build can reproduce a hand-built ``Composition``
byte-for-byte (pinned by tests/test_sdk.py) — which is what keeps the
migrated benchmarks' CSV rows identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.dag import Composition, PortRef, RetryPolicy
from repro.sdk.errors import (
    DeclarationError,
    UnknownPortError,
    ValidationError,
    WiringError,
)
from repro.sdk.functions import DEFAULT_CONTEXT_BYTES, FunctionSpec

# stack of App builders entered via ``with``; FunctionSpec.__call__ and
# module-level http()/input() resolve against the innermost one
_STACK: List["App"] = []


def current_app() -> "App":
    if not _STACK:
        raise WiringError(
            "no active composition: declare vertices inside "
            "`with sdk.composition(name) as app:`"
        )
    return _STACK[-1]


# ---------------------------------------------------------------- ports
@dataclass(frozen=True)
class Port:
    """A reference to one output set of a built vertex, optionally
    carrying a fan-out mode (``each``/``key`` sugar)."""

    handle: "VertexHandle"
    set_name: str
    mode: str = "all"

    def __repr__(self):
        tag = f", mode={self.mode!r}" if self.mode != "all" else ""
        return f"Port({self.handle.name}[{self.set_name!r}]{tag})"


@dataclass(frozen=True)
class InputRef:
    """A composition-level input placeholder (``app.input(name)``)."""

    app: "App"
    name: str


def _remode(port: Port, mode: str) -> Port:
    if not isinstance(port, Port):
        raise WiringError(
            f"sdk.{mode}() expects a vertex output port, "
            f"got {type(port).__name__}"
        )
    if port.mode != "all":
        raise WiringError(
            f"{port.handle.name}[{port.set_name!r}]: fan-out mode already "
            f"set to {port.mode!r}; each()/key() cannot be combined"
        )
    return Port(port.handle, port.set_name, mode)


def each(port: Port) -> Port:
    """One consumer instance per item of this output set."""
    return _remode(port, "each")


def key(port: Port) -> Port:
    """One consumer instance per distinct item key of this output set."""
    return _remode(port, "key")


# handle attributes that attribute-style port access would shadow; an
# output set with one of these names must be renamed (eager error below)
_RESERVED_HANDLE_ATTRS = frozenset({"name", "inputs", "outputs", "feed"})


class VertexHandle:
    """Handle to a built vertex: attribute/index access yields output
    ports (``fetch.responses`` / ``fetch["responses"]``), ``feed()``
    wires additional in-edges after creation."""

    def __init__(self, app: "App", name: str, inputs: Tuple[str, ...],
                 outputs: Tuple[str, ...]):
        shadowed = sorted(set(outputs) & _RESERVED_HANDLE_ATTRS)
        if shadowed:
            raise WiringError(
                f"{name}: output set name(s) {shadowed} collide with "
                f"VertexHandle attributes (attribute access would shadow "
                f"the port); rename the set(s)"
            )
        self._app = app
        self.name = name
        self.inputs = tuple(inputs)
        self.outputs = tuple(outputs)

    def __getitem__(self, set_name: str) -> Port:
        if set_name in self.outputs:
            return Port(self, set_name)
        raise UnknownPortError(
            f"{self.name}: no output set {set_name!r}; "
            f"declared outputs: {list(self.outputs)}"
        )

    def __getattr__(self, set_name: str) -> Port:
        # only reached for names not set in __init__; reserved python
        # attributes stay errors, everything else resolves as a port
        if set_name.startswith("_"):
            raise AttributeError(set_name)
        return self[set_name]

    def feed(self, **ports) -> "VertexHandle":
        """Wire additional inputs (multi-feed input sets, forward edges
        declared before their producers)."""
        self._app._wire(self, ports)
        return self

    def __repr__(self):
        return f"VertexHandle({self.name!r} in {self._app.name!r})"


# ------------------------------------------------------------------ app
class App:
    """A composition under construction (and, once built, a reusable
    application: deployable, invokable, nestable as a subgraph)."""

    def __init__(self, name: str):
        if not isinstance(name, str) or not name:
            raise DeclarationError(
                f"composition name must be a non-empty string, got {name!r}"
            )
        self.name = name
        self.comp = Composition(name)
        # function declarations used by this app (insertion-ordered),
        # keyed by function name — what Platform.deploy registers
        self._specs: Dict[str, FunctionSpec] = {}
        self._fan_in: Dict[str, str] = {}   # vertex -> each/key mode used
        self._validated = False

    # ------------------------------------------------------- build scope
    def __enter__(self) -> "App":
        _STACK.append(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        popped = _STACK.pop()
        assert popped is self, "composition builder stack corrupted"
        return False

    # ------------------------------------------------------------ inputs
    def input(self, name: str) -> InputRef:
        """A composition-level input, fed at ``Platform.invoke``; pass it
        as a port argument to exactly one vertex input set."""
        if not isinstance(name, str) or not name:
            raise WiringError(
                f"{self.name}: input name must be a non-empty string, "
                f"got {name!r}"
            )
        return InputRef(self, name)

    def output(self, name: str, port: Port) -> None:
        """Bind a composition-level output to a vertex output port."""
        if not isinstance(port, Port):
            raise WiringError(
                f"{self.name}: output {name!r} must bind a vertex output "
                f"port, got {type(port).__name__}"
            )
        if port.mode != "all":
            raise WiringError(
                f"{self.name}: output {name!r}: each()/key() apply to "
                f"vertex inputs, not composition outputs"
            )
        if port.handle._app is not self:
            raise WiringError(
                f"{self.name}: output {name!r} binds "
                f"{port.handle.name}[{port.set_name!r}] from composition "
                f"{port.handle._app.name!r}"
            )
        if name in self.comp.output_bindings:
            raise WiringError(f"{self.name}: duplicate output {name!r}")
        self.comp.bind_output(name, PortRef(port.handle.name, port.set_name))
        self._validated = False

    # ---------------------------------------------------------- vertices
    def _new_vertex_name(self, vname: str) -> str:
        if vname in self.comp.vertices:
            raise WiringError(
                f"{self.name}: duplicate vertex {vname!r} "
                f"(pass _name=... to disambiguate)"
            )
        return vname

    def _adopt_spec(self, spec: FunctionSpec) -> None:
        known = self._specs.get(spec.name)
        if known is not None and known is not spec:
            raise WiringError(
                f"{self.name}: two different declarations both named "
                f"{spec.name!r} used in one composition"
            )
        self._specs[spec.name] = spec

    def _add_compute(self, spec: FunctionSpec, *, name: Optional[str],
                     context_bytes: Optional[int], timeout_s: Optional[float],
                     ports: dict,
                     retry: Optional[RetryPolicy] = None,
                     batch_units: Optional[int] = None) -> VertexHandle:
        vname = self._new_vertex_name(name or spec.name)
        self._adopt_spec(spec)
        self.comp.compute(
            vname, spec.name, inputs=spec.inputs, outputs=spec.outputs,
            context_bytes=spec.context_bytes if context_bytes is None
            else context_bytes,
            timeout_s=spec.timeout_s if timeout_s is None else timeout_s,
            retry=spec.retry if retry is None else retry,
        )
        if batch_units is not None:
            if batch_units < 1:
                raise WiringError(
                    f"{vname}: _batch_units must be >= 1, got {batch_units}")
            self.comp.vertices[vname].batch_units = batch_units
        handle = VertexHandle(self, vname, spec.inputs, spec.outputs)
        self._wire(handle, ports)
        self._validated = False
        return handle

    def http(self, name: str, requests=None, *,
             context_bytes: int = DEFAULT_CONTEXT_BYTES) -> VertexHandle:
        """The platform HTTP communication function (trusted, SS6.3):
        input set ``requests``, output set ``responses``."""
        vname = self._new_vertex_name(name)
        self.comp.http(vname, context_bytes=context_bytes)
        handle = VertexHandle(self, vname, ("requests",), ("responses",))
        if requests is not None:
            self._wire(handle, {"requests": requests})
        self._validated = False
        return handle

    def _add_subgraph(self, sub: "App", name: Optional[str],
                      ports: dict) -> VertexHandle:
        sub_comp = sub.compile()
        vname = self._new_vertex_name(name or sub.name)
        for spec in sub._specs.values():
            self._adopt_spec(spec)
        self.comp.subgraph(vname, sub_comp)
        handle = VertexHandle(
            self, vname,
            tuple(sub_comp.input_bindings), tuple(sub_comp.output_bindings),
        )
        self._wire(handle, ports)
        self._validated = False
        return handle

    def __call__(self, _name: Optional[str] = None, **ports) -> VertexHandle:
        """Use this (finished) app as a nested composition vertex inside
        the currently building one."""
        outer = current_app()
        if outer is self:
            raise WiringError(f"{self.name}: a composition cannot nest itself")
        return outer._add_subgraph(self, _name, ports)

    # ------------------------------------------------------------ wiring
    def _wire(self, handle: VertexHandle, ports: dict) -> None:
        for set_name, value in ports.items():
            sources = value if isinstance(value, (list, tuple)) else (value,)
            for src in sources:
                self._wire_one(handle, set_name, src)

    def _wire_one(self, handle: VertexHandle, set_name: str, src) -> None:
        if set_name not in handle.inputs:
            raise WiringError(
                f"{handle.name}: no input set {set_name!r}; "
                f"declared inputs: {list(handle.inputs)}"
            )
        if isinstance(src, InputRef):
            if src.app is not self:
                raise WiringError(
                    f"{handle.name}: input ref {src.name!r} belongs to "
                    f"composition {src.app.name!r}, not {self.name!r}"
                )
            bound = self.comp.input_bindings.get(src.name)
            if bound is not None:
                raise WiringError(
                    f"{self.name}: input {src.name!r} already feeds "
                    f"{bound.vertex}[{bound.set_name!r}]; a composition "
                    f"input feeds exactly one port"
                )
            self.comp.bind_input(src.name, PortRef(handle.name, set_name))
        elif isinstance(src, Port):
            if src.handle._app is not self:
                raise WiringError(
                    f"{handle.name}: port {src.handle.name}"
                    f"[{src.set_name!r}] belongs to composition "
                    f"{src.handle._app.name!r}, not {self.name!r}"
                )
            if src.mode in ("each", "key"):
                prev = self._fan_in.get(handle.name)
                if prev is not None:
                    raise WiringError(
                        f"{handle.name}: at most one 'each'/'key' edge may "
                        f"target a vertex (already has a {prev!r} edge)"
                    )
                self._fan_in[handle.name] = src.mode
            self.comp.edge(
                PortRef(src.handle.name, src.set_name),
                PortRef(handle.name, set_name),
                src.mode,
            )
        else:
            raise WiringError(
                f"{handle.name}.{set_name}: expected a vertex output port "
                f"or app.input(...), got {type(src).__name__}"
            )
        self._validated = False

    # ----------------------------------------------------------- compile
    def compile(self, registry=None) -> Composition:
        """Validate and return the underlying IR ``Composition`` (cached;
        the same object every call, so compiled apps are cheap to invoke
        repeatedly). With ``registry``, also checks every compute vertex
        resolves against it or this app's own declarations."""
        if not self._validated:
            try:
                self.comp.validate()
            except ValueError as e:
                raise ValidationError(str(e)) from e
            self._validated = True
        if registry is not None:
            self._check_registry(self.comp, registry)
        return self.comp

    def _check_registry(self, comp: Composition, registry) -> None:
        from repro.core.dag import COMPUTE, SUBGRAPH

        for v in comp.vertices.values():
            if v.kind == COMPUTE and v.function not in registry.functions \
                    and v.function not in self._specs:
                raise ValidationError(
                    f"{comp.name}: compute vertex {v.name!r} references "
                    f"unknown function {v.function!r} (not registered, not "
                    f"declared in this composition)"
                )
            if v.kind == SUBGRAPH and v.subgraph is not None:
                self._check_registry(v.subgraph, registry)

    def function_specs(self) -> Tuple[FunctionSpec, ...]:
        """Declarations used by this app, in first-use order."""
        return tuple(self._specs.values())


def composition(name: str) -> App:
    """Start a declarative composition: ``with sdk.composition(n) as app``."""
    return App(name)


def http(name: str, requests=None, *,
         context_bytes: int = DEFAULT_CONTEXT_BYTES) -> VertexHandle:
    """Add an HTTP communication vertex to the current composition."""
    return current_app().http(name, requests, context_bytes=context_bytes)


def single_function_app(spec: FunctionSpec) -> App:
    """The one-vertex wrapper benchmarks drive single functions through:
    composition ``single_<fn>``, input/output bound straight to the
    function's (single) declared sets."""
    if len(spec.inputs) != 1 or len(spec.outputs) != 1:
        raise DeclarationError(
            f"{spec.name}: single_function_app needs exactly one input and "
            f"one output set, got {spec.inputs} -> {spec.outputs}"
        )
    with composition(f"single_{spec.name}") as app:
        v = spec(**{spec.inputs[0]: app.input(spec.inputs[0])})
        app.output(spec.outputs[0], v[spec.outputs[0]])
    return app
