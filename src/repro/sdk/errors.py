"""SDK error taxonomy.

Every SDK error derives from ``SDKError`` (a ``ValueError``), split by
the phase that raised it — the taxonomy ``docs/API.md`` documents:

  * ``DeclarationError`` — a bad ``@sdk.function`` / ``sdk.declare`` /
    ``sdk.ref`` declaration (empty set names, duplicate ports, missing
    payload);
  * ``WiringError``      — a bad dataflow expression while building a
    composition (unknown port, duplicate vertex, double ``each``/``key``
    fan-in, cross-composition port, no active builder). Raised *eagerly*
    at the offending call, naming the culprit vertex/port;
  * ``ValidationError``  — whole-graph validation at ``App.compile()``
    (cycle, unfed input set, dangling output binding). Wraps the IR's
    ``Composition.validate`` errors, which name the culprit vertex;
  * ``DeploymentError``  — registration-time failures in
    ``Platform.deploy`` (conflicting redeclaration of a function name,
    composition referencing an unregistered function);
  * ``InvocationFailed`` — ``InvocationHandle.result()`` on a failed (or
    never-completing) invocation; carries the dispatcher's failure
    reason, which names the failing vertex;
  * ``PurityError``      — strict-mode purity verification failed at
    ``Platform(verify="strict")`` deploy time (or ``sdk.verify`` result
    escalated by the caller). Carries the full ``PurityReport`` as
    ``.report``; the message names every offending function, rule, and
    line.
"""
from __future__ import annotations


class SDKError(ValueError):
    """Base class for all declarative-SDK errors."""


class DeclarationError(SDKError):
    """Invalid function declaration (decorator / declare / ref)."""


class WiringError(SDKError):
    """Invalid dataflow expression while building a composition."""


class UnknownPortError(WiringError, AttributeError):
    """Unknown output set on a vertex handle. Also an ``AttributeError``
    so attribute-protocol probes (``hasattr``/``getattr`` with default)
    behave normally on ``VertexHandle``."""


class ValidationError(SDKError):
    """Whole-graph validation failed at compile time."""


class DeploymentError(SDKError):
    """Registration onto a Platform / FunctionRegistry failed."""


class InvocationFailed(SDKError):
    """``InvocationHandle.result()`` on a failed invocation."""


class PurityError(SDKError):
    """Strict purity verification rejected a deployment.

    ``.report`` is the full ``repro.analysis.PurityReport``; the message
    lists each blocking finding as ``function @ file:line [rule]``.
    """

    def __init__(self, report):
        self.report = report
        blocking = report.blocking
        lines = [
            f"  {f.function or '<?>'} @ {f.file}:{f.line} "
            f"[{f.rule}] {f.message}"
            for f in blocking
        ]
        super().__init__(
            f"strict purity verification failed: {len(blocking)} "
            f"violation(s)\n" + "\n".join(lines)
        )
