"""``sdk.verify``: the SDK front door to the static-analysis subsystem.

``verify(target)`` returns a ``repro.analysis.PurityReport`` for an
``App``, a ``FunctionSpec``, a raw IR ``Composition``, or a list of
those — payload purity findings (``repro.analysis.purity``) merged with
graph-shape findings on the compiled composition
(``repro.analysis.graphlint``). It never raises on findings: the report
carries them, ``report.ok`` says whether strict mode would pass, and
``Platform(verify="strict")`` is the enforcing caller (raising
``sdk.PurityError``).

Declarations marked ``pure_unsafe=True`` are still analyzed: their
findings are waived (reason ``pure_unsafe=True on declaration``) and the
function is listed in ``report.unsafe`` — an audited opt-out.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

from repro.analysis import lint_composition, PurityReport
from repro.analysis.purity import analyze_callable
from repro.core.dag import COMPUTE, SUBGRAPH, Composition
from repro.sdk.builder import App
from repro.sdk.functions import FunctionSpec


def _spec_entries(specs: Iterable[FunctionSpec], registry
                  ) -> List[Tuple[str, object, bool]]:
    entries: List[Tuple[str, object, bool]] = []
    for spec in specs:
        if spec.is_ref:
            if registry is not None:
                cf = registry.functions.get(spec.name)
                if cf is not None:
                    entries.append((cf.name, cf.fn,
                                    getattr(cf, "pure_unsafe", False)))
            continue
        entries.append((spec.name, spec.fn, spec.pure_unsafe))
    return entries


def _comp_entries(comp: Composition, registry,
                  _seen: Optional[set] = None
                  ) -> List[Tuple[str, object, bool]]:
    """Registered payloads of a composition's compute vertices."""
    seen = _seen if _seen is not None else set()
    entries: List[Tuple[str, object, bool]] = []
    if registry is None:
        return entries
    for v in comp.vertices.values():
        if v.kind == COMPUTE and v.function not in seen:
            seen.add(v.function)
            cf = registry.functions.get(v.function)
            if cf is not None:
                entries.append((cf.name, cf.fn,
                                getattr(cf, "pure_unsafe", False)))
        elif v.kind == SUBGRAPH and v.subgraph is not None:
            entries.extend(_comp_entries(v.subgraph, registry, seen))
    return entries


def verify(target, *, registry=None, cluster: bool = False,
           crossnode: bool = False) -> PurityReport:
    """Statically verify ``target`` against the pure-function contract.

    ``registry`` resolves ``sdk.ref`` declarations and raw-IR vertex
    functions to their registered payloads; ``cluster``/``crossnode``
    give the composition lint its deployment context (the
    ``graph-fanout-local`` rule only fires on multi-node shapes without
    cross-node scheduling).
    """
    findings = []
    entries: List[Tuple[str, object, bool]] = []
    comps: List[Composition] = []

    targets = target if isinstance(target, (list, tuple)) else [target]
    for t in targets:
        if isinstance(t, FunctionSpec):
            entries.extend(_spec_entries([t], registry))
        elif isinstance(t, App):
            entries.extend(_spec_entries(t.function_specs(), registry))
            comps.append(t.compile(registry) if registry is not None
                         else t.compile())
        elif isinstance(t, Composition):
            entries.extend(_comp_entries(t, registry))
            comps.append(t)
        elif hasattr(t, "fn") and hasattr(t, "name"):   # ComputeFunction
            entries.append((t.name, t.fn,
                            getattr(t, "pure_unsafe", False)))
        else:
            raise TypeError(
                f"verify() takes an App, Composition, FunctionSpec, or a "
                f"list of those, got {type(t).__name__}"
            )

    checked: List[str] = []
    unsafe: List[str] = []
    seen_names = set()
    for name, fn, pure_unsafe in entries:
        if name in seen_names:
            continue
        seen_names.add(name)
        checked.append(name)
        got = analyze_callable(fn, name=name)
        if pure_unsafe:
            unsafe.append(name)
            got = [f if f.waived else
                   f.waive("pure_unsafe=True on declaration")
                   for f in got]
        findings.extend(got)

    for comp in comps:
        findings.extend(lint_composition(
            comp, cluster=cluster, crossnode=crossnode).findings)

    return PurityReport(findings, checked=sorted(checked),
                        unsafe=sorted(unsafe))
