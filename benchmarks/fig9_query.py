"""Figure 9: elastic query processing (SSB-style) vs a QaaS cost model.

A mini columnar engine implemented as Dandelion compute functions:
partitioned scans fan out with 'each' (one sandbox per partition, the
paper's elastic scale-out), partial filter/aggregate per partition, merge.
Data is served from a simulated S3 (latency + bandwidth model); the scan
kernels are real numpy.

Cost model: Dandelion = wall-clock x EC2 m7a.8xlarge on-demand rate;
Athena-like QaaS = $5/TB scanned (10 MB minimum) with a fixed engine
startup latency + per-byte scan model. Both reported per query.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Composition,
    FunctionRegistry,
    HttpRequest,
    HttpResponse,
    Item,
    ServiceRegistry,
    WorkerNode,
)
from benchmarks.common import emit

PARTITIONS = 16
ROWS_PER_PART = 200_000
EC2_USD_PER_S = 1.85 / 3600.0          # m7a.8xlarge on-demand
ATHENA_USD_PER_TB = 5.0
ATHENA_MIN_BYTES = 10 * 1024**2
ATHENA_STARTUP_S = 0.65
ATHENA_SCAN_BPS = 2.0e9


def _make_partition(seed):
    rng = np.random.default_rng(seed)
    n = ROWS_PER_PART
    return {
        "quantity": rng.integers(1, 51, n, dtype=np.uint8),
        "discount": rng.integers(0, 11, n, dtype=np.uint8),
        "extendedprice": rng.integers(100, 10_000, n, dtype=np.uint32),
        "year": rng.integers(1992, 1999, n, dtype=np.uint16),
        "category": rng.integers(0, 25, n, dtype=np.uint8),
    }


def _setup(reg: FunctionRegistry, services: ServiceRegistry):
    parts = [_make_partition(s) for s in range(PARTITIONS)]
    blobs = {}
    for i, p in enumerate(parts):
        buf = b"".join(c.tobytes() for c in p.values())
        blobs[f"/part{i}"] = buf
    total_bytes = sum(len(b) for b in blobs.values())
    services.register(
        "s3.svc",
        lambda req: HttpResponse(200, blobs[req.url.split("s3.svc")[1]]),
        base_latency_s=2e-3, bandwidth_bps=10e9,
    )

    def decode(body):
        n = ROWS_PER_PART
        raw = body if isinstance(body, bytes) else bytes(body)
        off = 0
        cols = {}
        for name, dt in (("quantity", np.uint8), ("discount", np.uint8),
                         ("extendedprice", np.uint32), ("year", np.uint16),
                         ("category", np.uint8)):
            sz = n * np.dtype(dt).itemsize
            cols[name] = np.frombuffer(raw[off:off + sz], dt)
            off += sz
        return cols

    def plan_fn(ins):
        return {"reqs": [
            Item(HttpRequest("GET", f"http://s3.svc/part{i}"), key=str(i))
            for i in range(PARTITIONS)
        ]}

    def q1_scan(ins):  # filter + agg: revenue query (SSB Q1-like)
        c = decode(ins["part"][0].data.body)
        m = (c["discount"] >= 1) & (c["discount"] <= 3) & (c["quantity"] < 25) \
            & (c["year"] == 1993)
        rev = np.sum(c["extendedprice"][m].astype(np.int64) * c["discount"][m])
        return {"partial": [Item(np.int64(rev).tobytes())]}

    def q2_scan(ins):  # group-by category sum (join with tiny dim table)
        c = decode(ins["part"][0].data.body)
        sums = np.bincount(
            c["category"], weights=c["extendedprice"].astype(np.float64),
            minlength=25,
        )
        return {"partial": [Item(sums.tobytes())]}

    def q3_scan(ins):  # multi-filter group-by year
        c = decode(ins["part"][0].data.body)
        m = (c["category"] < 5) & (c["quantity"] > 10)
        sums = np.bincount(
            c["year"][m] - 1992,
            weights=c["extendedprice"][m].astype(np.float64), minlength=7,
        )
        return {"partial": [Item(sums.tobytes())]}

    def merge_sum(ins):
        arrs = [np.frombuffer(i.data, np.float64 if len(i.data) > 8 else np.int64)
                for i in ins["partials"]]
        return {"result": [Item(np.sum(arrs, axis=0).tobytes())]}

    reg.register_function("plan", plan_fn)
    reg.register_function("q1_scan", q1_scan, context_bytes=4 << 20)
    reg.register_function("q2_scan", q2_scan, context_bytes=4 << 20)
    reg.register_function("q3_scan", q3_scan, context_bytes=4 << 20)
    reg.register_function("merge", merge_sum)

    comps = {}
    for q in ("q1", "q2", "q3"):
        c = Composition(f"ssb_{q}")
        pl = c.compute("plan", "plan", inputs=("go",), outputs=("reqs",))
        h = c.http("fetch")
        sc = c.compute("scan", f"{q}_scan", inputs=("part",), outputs=("partial",),
                       context_bytes=4 << 20)
        mg = c.compute("merge", "merge", inputs=("partials",), outputs=("result",))
        c.edge(pl["reqs"], h["requests"], "each")
        c.edge(h["responses"], sc["part"], "each")
        c.edge(sc["partial"], mg["partials"], "all")
        c.bind_input("go", pl["go"])
        c.bind_output("result", mg["result"])
        reg.register_composition(c)
        comps[q] = c
    return comps, total_bytes


def run():
    reg, services = FunctionRegistry(), ServiceRegistry()
    comps, total_bytes = _setup(reg, services)
    rows = []
    for q, comp in comps.items():
        node = WorkerNode(reg, services, num_slots=32, comm_slots=4, seed=11)
        done = []
        node.invoke(comp, {"go": [Item(1)]}, on_done=done.append)
        node.run()
        assert done and not done[0].failed, done and done[0].failed
        lat = done[0].latency
        d_cost = lat * EC2_USD_PER_S
        scanned = max(total_bytes, ATHENA_MIN_BYTES)
        a_lat = ATHENA_STARTUP_S + total_bytes / ATHENA_SCAN_BPS
        a_cost = scanned / 1024**4 * ATHENA_USD_PER_TB
        rows.append({
            "query": q,
            "scanned_mb": total_bytes / 1024**2,
            "dandelion_latency_s": lat,
            "athena_like_latency_s": a_lat,
            "latency_ratio": lat / a_lat,
            "dandelion_cost_usd": d_cost,
            "athena_like_cost_usd": a_cost,
            "cost_ratio": d_cost / a_cost,
        })
    return rows


def main():
    emit("fig9_query", run())


if __name__ == "__main__":
    main()
