"""Table 1: cold-start latency breakdown per backend (microseconds).

Real code paths, measured on this host: marshal the input descriptors,
load the binary (RAM cache hit AND disk miss), bind+fill the memory
context, set up execution (nothing / AOT-deserialize / full compile), and
collect outputs. Payload: the paper's 1x1 int64 matmul.
"""
from __future__ import annotations

from repro.core import BACKENDS, FunctionRegistry, measure
from benchmarks.common import emit, matmul_inputs, register_matmul


def run(samples: int = 9):
    reg = FunctionRegistry()
    name = register_matmul(reg, 1)
    inputs = matmul_inputs(1)
    rows = []
    for backend in BACKENDS:
        for cached in (True, False):
            if not cached:
                reg.evict(name)
            bd, exec_s = measure(
                reg, name, inputs, backend=backend, cached=cached,
                samples=samples,
            )
            us = bd.us()
            rows.append({
                "backend": backend,
                "code_cache": "ram" if cached else "disk",
                "marshal_us": us["marshal_us"],
                "load_us": us["load_us"],
                "transfer_us": us["transfer_us"],
                "setup_us": us["execute_setup_us"],
                "output_us": us["output_us"],
                "total_coldstart_us": us["total_us"],
                "execute_us": exec_s * 1e6,
            })
    return rows


def main():
    emit("table1_coldstart", run())


if __name__ == "__main__":
    main()
