"""Shared benchmark substrate: paper payloads, calibration, CSV helpers.

Payloads follow SS7.1: the 1x1 / 128x128 int64 matrix multiplications
(Table 1, Fig. 2/5/6), the fetch-and-reduce phase microbenchmark (SS7.4/7.5)
and an image-transform stand-in (SS7.6). Cold-start profiles are calibrated
ONCE per process from the real code paths (repro.core.coldstart) and then
drive the virtual-time simulations, so RPS sweeps are faithful to measured
costs AND deterministic.
"""
from __future__ import annotations

import csv
import io
import json
import os
import sys
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    ColdStartProfile,
    Composition,
    FunctionRegistry,
    HttpRequest,
    HttpResponse,
    Item,
    ServiceRegistry,
    ThroughputStats,
    measure,
)


# ---------------------------------------------------------------- payloads
def matmul_fn(n: int):
    def fn(inputs):
        x = inputs["x"][0].data
        return {"out": [Item(np.matmul(x, x))]}

    return fn


def matmul_inputs(n: int):
    return {"x": [Item(np.ones((n, n), np.int64))]}


def register_matmul(reg: FunctionRegistry, n: int, name: Optional[str] = None):
    import jax.numpy as jnp

    name = name or f"matmul_{n}"
    reg.register_function(
        name,
        matmul_fn(n),
        jax_fn=lambda x: x @ x,
        abstract_args=(jnp.zeros((n, n), jnp.int32),),
        context_bytes=max(1 << 20, 3 * n * n * 8),
    )
    return name


def register_image_compress(reg: FunctionRegistry, kb: int = 18):
    """QOI->PNG stand-in: zlib-compress an image-sized buffer (real work)."""
    import zlib

    rng = np.random.default_rng(0)
    img = rng.integers(0, 255, kb * 1024, dtype=np.uint8).tobytes()

    def fn(inputs):
        return {"out": [Item(zlib.compress(inputs["img"][0].data, 6))]}

    reg.register_function("image_compress", fn, context_bytes=4 << 20)
    return "image_compress", {"img": [Item(img)]}


def register_reduce(reg: FunctionRegistry):
    """The SS7.4 phase compute: sum/min/max over a sampled array."""

    def fn(inputs):
        raw = inputs["data"][0].data
        body = raw.body if isinstance(raw, HttpResponse) else raw
        arr = np.frombuffer(body if isinstance(body, bytes) else bytes(body), np.uint8)
        sample = arr[:: max(1, len(arr) // 4096)]
        out = np.array([sample.sum(), sample.min(), sample.max()], np.int64)
        return {"out": [Item(out.tobytes())]}

    reg.register_function("reduce", fn, context_bytes=1 << 20)
    return "reduce"


def storage_service(services: ServiceRegistry, fetch_bytes: int = 64 * 1024,
                    base_latency_s: float = 0.5e-3,
                    bandwidth_bps: float = 1.25e9):
    blob = np.random.default_rng(1).integers(
        0, 255, fetch_bytes, dtype=np.uint8
    ).tobytes()
    services.register(
        "storage.svc", lambda req: HttpResponse(200, blob),
        base_latency_s=base_latency_s, bandwidth_bps=bandwidth_bps,
    )
    return "storage.svc"


# -------------------------------------------------------------- calibration
_PROFILE_CACHE: Dict[tuple, ColdStartProfile] = {}


def calibrate(reg: FunctionRegistry, name: str, inputs, backend="dandelion",
              cached=True, samples=5) -> ColdStartProfile:
    key = (id(reg), name, backend, cached)
    if key not in _PROFILE_CACHE:
        bd, exec_s = measure(
            reg, name, inputs, backend=backend, cached=cached, samples=samples
        )
        _PROFILE_CACHE[key] = ColdStartProfile(setup_s=bd.total, execute_s=exec_s)
    return _PROFILE_CACHE[key]


# ------------------------------------------------- simulator throughput
# Wall-clock events/sec per benchmark segment, keyed "<bench>/<segment>".
# Benchmarks record segments with ``track()``; ``emit`` appends the
# throughput metric to its CSV block and ``write_simperf`` serializes the
# whole registry to results/bench/BENCH_simperf.json so the perf
# trajectory is tracked across PRs (and gated in CI).
PERF: Dict[str, ThroughputStats] = {}
# per-segment extra fields merged into BENCH_simperf.json (baselines,
# speedups, window parameters) - benchmarks populate alongside track()
SIMPERF_EXTRA: Dict[str, dict] = {}


@contextmanager
def track(name: str, events: int):
    """Measure wall-clock for one simulator segment of ``events`` trace
    events; records a ThroughputStats row under ``name``."""
    t0 = time.perf_counter()
    yield
    PERF[name] = ThroughputStats(
        name=name, events=int(events), wall_s=time.perf_counter() - t0
    )


def bench_perf(prefix: str) -> Dict[str, ThroughputStats]:
    return {k: v for k, v in PERF.items() if k.split("/")[0] == prefix}


def write_simperf(outdir: str = "results/bench",
                  extra: Optional[Dict[str, dict]] = None) -> str:
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, "BENCH_simperf.json")
    # merge over an existing trajectory so a partial run (--only figN)
    # refreshes its own segments without dropping everyone else's
    payload: Dict[str, dict] = {}
    if os.path.isfile(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            payload = {}
    payload.update({k: v.row() for k, v in sorted(PERF.items())})
    for source in (SIMPERF_EXTRA, extra or {}):
        for k, v in source.items():
            payload.setdefault(k, {}).update(v)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


# --------------------------------------------------------------------- CSV
def emit(name: str, rows: List[dict], out_stream=None) -> None:
    out = out_stream or sys.stdout
    if not rows:
        print(f"# {name}: no rows", file=out)
        return
    print(f"# === {name} ===", file=out)
    cols = list(rows[0].keys())
    w = csv.DictWriter(out, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.6g}" if isinstance(v, float) else v)
                    for k, v in r.items()})
    for ts in bench_perf(name).values():
        print(f"# perf {ts.name}: {ts.events} events in {ts.wall_s:.3f}s "
              f"= {ts.events_per_sec:.0f} events/sec", file=out)
    out.flush()


def single_function_composition(reg: FunctionRegistry, fn_name: str,
                                in_set: str = "x") -> Composition:
    c = Composition(f"single_{fn_name}")
    v = c.compute(fn_name, fn_name, inputs=(in_set,), outputs=("out",),
                  context_bytes=reg.get(fn_name).context_bytes)
    c.bind_input(in_set, v[in_set])
    c.bind_output("out", v["out"])
    reg.register_composition(c)
    return c
