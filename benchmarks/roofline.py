"""Roofline report: aggregates the dry-run artifacts into the per-(arch x
shape x mesh) three-term table (EXPERIMENTS.md SSRoofline).

Reads results/<dir>/*.json produced by repro.launch.dryrun.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import List

from benchmarks.common import emit


def load_cells(dirname: str) -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def rows_from_cells(cells: List[dict]) -> List[dict]:
    rows = []
    for c in cells:
        base = {"arch": c.get("arch"), "shape": c.get("shape"),
                "mesh": c.get("mesh")}
        if "skipped" in c:
            rows.append({**base, "status": "SKIP", "bottleneck": "",
                         "compute_s": 0.0, "memory_s": 0.0,
                         "collective_s": 0.0, "step_s": 0.0, "mfu": 0.0,
                         "useful_flops_frac": 0.0, "hbm_gb_per_dev": 0.0})
            continue
        if "error" in c:
            rows.append({**base, "status": "FAIL", "bottleneck": "",
                         "compute_s": 0.0, "memory_s": 0.0,
                         "collective_s": 0.0, "step_s": 0.0, "mfu": 0.0,
                         "useful_flops_frac": 0.0, "hbm_gb_per_dev": 0.0})
            continue
        r = c["roofline"]
        mem = c.get("memory", {}) or {}
        peak = mem.get("peak_bytes_per_device") or 0
        rows.append({
            **base,
            "status": "OK",
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "step_s": r["step_time_s"],
            "mfu": r["mfu"],
            "useful_flops_frac": r["useful_flops_fraction"],
            "hbm_gb_per_dev": peak / 1024**3,
        })
    return rows


def run(dirname: str = "results/dryrun_baseline_v2"):
    if not os.path.isdir(dirname):
        return [{"arch": "(no dry-run artifacts found)", "shape": dirname,
                 "mesh": "", "status": "MISSING", "compute_s": 0.0,
                 "memory_s": 0.0, "collective_s": 0.0, "bottleneck": "",
                 "step_s": 0.0, "mfu": 0.0, "useful_flops_frac": 0.0,
                 "hbm_gb_per_dev": 0.0}]
    return rows_from_cells(load_cells(dirname))


def main():
    dirname = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline_v2"
    emit("roofline", run(dirname))


if __name__ == "__main__":
    main()
