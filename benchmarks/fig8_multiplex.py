"""Figure 8: multiplexing a compute-intensive and an I/O-intensive app
under bursty load.

Apps: image compression (zlib on an 18 KB buffer - compute) and the Fig. 3
log-processing composition (I/O). Load pattern: alternating bursts. Systems:
Dandelion (split + PI controller), keep-warm snapshot platform at 97% hot,
and a Wasmtime-like platform (fast create, ~3x slower compute from less
optimized codegen, unified engines).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    ColdStartProfile,
    EventLoop,
    FunctionRegistry,
    KeepWarmPlatform,
    ServiceRegistry,
    WorkerNode,
)
from repro.core.items import Item
from benchmarks.common import calibrate, emit, register_image_compress, single_function_composition
from repro.apps import build_log_processing

CORES = 16
PHASE = 4.0          # seconds per burst phase
BASE_RPS = 40.0
BURST_RPS = 250.0


def _arrivals(phases, seed):
    """phases: list of (img_rps, log_rps) per PHASE-second window."""
    rng = np.random.default_rng(seed)
    img, log = [], []
    for i, (ir, lr) in enumerate(phases):
        t0 = i * PHASE
        for rate, out in ((ir, img), (lr, log)):
            t = t0
            while t < t0 + PHASE:
                t += float(rng.exponential(1.0 / rate))
                if t < t0 + PHASE:
                    out.append(t)
    return img, log


def run():
    reg = FunctionRegistry()
    services = ServiceRegistry()
    log_comp = build_log_processing(reg, services)
    img_name, img_inputs = register_image_compress(reg)
    img_comp = single_function_composition(reg, img_name, in_set="img")

    img_prof = calibrate(reg, img_name, img_inputs)
    phases = [(BASE_RPS, BASE_RPS), (BASE_RPS, BURST_RPS),
              (BURST_RPS, BASE_RPS), (BURST_RPS, BURST_RPS)]
    img_t, log_t = _arrivals(phases, seed=7)

    rows = []

    def record(system, app, stats):
        s = stats.summary()
        rows.append({
            "system": system, "app": app, "n": s["n"],
            "mean_ms": s["mean_ms"], "p99_ms": s["p99_ms"],
            "rel_var_pct": s["rel_var_pct"],
        })

    # ---------------- Dandelion ----------------
    from repro.core.tracing import LatencyStats

    node = WorkerNode(reg, services, num_slots=CORES, comm_slots=2,
                      profiles={img_name: img_prof}, seed=8)
    img_lat, log_lat = LatencyStats(), LatencyStats()
    for t in img_t:
        node.invoke_at(t, img_comp, {"img": list(img_inputs["img"])},
                       on_done=lambda inv: img_lat.add(inv.latency))
    for i, t in enumerate(log_t):
        node.invoke_at(t, log_comp, {"token": [Item(f"t{i}")]},
                       on_done=lambda inv: log_lat.add(inv.latency))
    node.run()
    record("dandelion", "image_compress", img_lat)
    record("dandelion", "log_processing", log_lat)
    hist = node.controller.history
    if hist:
        rows.append({
            "system": "dandelion", "app": "(controller: io cores min->max)",
            "n": len(hist),
            "mean_ms": min(h[2] for h in hist),
            "p99_ms": max(h[2] for h in hist),
            "rel_var_pct": 0.0,
        })

    # ---------------- keep-warm @97% hot (Firecracker analogue) --------
    img_snap = calibrate(reg, img_name, img_inputs)  # no jax payload: use
    # the measured dandelion exec with a snapshot-scale boot constant
    boot_s = 15e-3
    loop = EventLoop()
    kw = KeepWarmPlatform(loop, cores=CORES, hot_ratio=0.97, seed=9)
    kw.register("img", ColdStartProfile(boot_s, img_prof.execute_s))
    # model the whole log composition as one warm function (its engines are
    # inside the sandbox on this platform): exec = end-to-end io+cpu
    log_serial_s = 1e-3 + 3 * 2e-3 / 3 + 2e-3  # auth + parallel logs + cpu
    kw.register("log", ColdStartProfile(boot_s, log_serial_s))
    img_kw, log_kw = LatencyStats(), LatencyStats()
    for t in img_t:
        kw.request_at(t, "img", on_done=img_kw.add)
    for t in log_t:
        kw.request_at(t, "log", on_done=log_kw.add)
    loop.run()
    record("keepwarm_97hot", "image_compress", img_kw)
    record("keepwarm_97hot", "log_processing", log_kw)

    # ---------------- Wasmtime-like: fast create, 3x slower compute ----
    loop = EventLoop()
    wt = KeepWarmPlatform(loop, cores=CORES, hot_ratio=0.0, seed=10,
                          guest_os_bytes=8 << 20)
    wt.register("img", ColdStartProfile(0.3e-3, img_prof.execute_s * 3.0))
    wt.register("log", ColdStartProfile(0.3e-3, log_serial_s * 1.2))
    img_wt, log_wt = LatencyStats(), LatencyStats()
    for t in img_t:
        wt.request_at(t, "img", on_done=img_wt.add)
    for t in log_t:
        wt.request_at(t, "log", on_done=log_wt.add)
    loop.run()
    record("wasmtime_like", "image_compress", img_wt)
    record("wasmtime_like", "log_processing", log_wt)
    return rows


def main():
    emit("fig8_multiplex", run())


if __name__ == "__main__":
    main()
