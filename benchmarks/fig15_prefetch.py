"""Figure 15 (extension): P2P artifact distribution + predictive scaling.

Two experiments proving the two halves of the FaaSNet/Boxer thread
(ROADMAP item 2), both pure virtual time:

**Segment A — cold join.** An elastic cluster with two warm seed nodes
(10 functions, two 48 MB weight models, warmed by 10 s of real traffic)
adopts six fresh nodes at once. With ``peer=True`` the ``P2PDistributor``
streams the hot artifact set over the tree of warm holders (every
completed receiver becomes a serving peer, ``fanout`` streams each);
with ``peer=False`` every artifact comes from the origin registry, whose
single uplink serializes the six downloads. Reported: average/max
join-to-warm seconds per mode and the P2P/origin ratio — the FaaSNet
claim is ratio << 1.

**Segment B — predicted burst.** One periodic ON/OFF function (period
12 s, duty 0.25, ~80 req/s during ON, 200 ms exec, 500 ms weight cold
start) against three platforms on identical traces:

  * ``keepwarm``   — min_nodes = max_nodes = 4: the peak-provisioned
                     reference (best p99, worst memory);
  * ``reactive``   — autoscaling from 1 node on queue pressure: every
                     burst eats node boot (0.75 s) plus weight cold
                     starts on the fresh nodes;
  * ``predictive`` — same autoscaler plus ``BurstPredictor`` (EWMA +
                     ON/OFF period detection over arrivals) booting
                     ``nodes_ahead`` nodes ``lead_s`` before each
                     predicted ON edge, and ``P2PDistributor`` prefetch
                     seeding the fresh nodes' code cache + weight store
                     so first touches are warm hits.

Latencies are measured for arrivals past a warm-up window that covers
the predictor's learning cycles; committed memory is averaged over the
whole run (learning included — the price of prediction is in the
number). Gates (CI, enforced here and via benchmarks/run.py):

  * join ratio: P2P avg join < FIG15_MAX_JOIN_RATIO (default 0.5) of
    origin-only;
  * predicted-burst tail: predictive p99 <= FIG15_MAX_P99_X (default
    1.1) x keepwarm p99;
  * elasticity: predictive average committed memory strictly below
    keepwarm's;
  * contrast: predictive p99 < reactive p99 (prediction visibly beats
    reaction; disable with FIG15_REQUIRE_CONTRAST=0).

Summary JSON lands in ``results/bench/BENCH_prefetch.json``. fig15 is
NOT in the byte-identity set; instead tests/test_prefetch.py pins the
transfer journal byte-identical across runs, loop modes, and CROSSNODE
values.

Knobs (environment variables):

  FIG15_QUICK             1 -> short window for CI smoke (also --quick)
  FIG15_JOINERS           joining nodes in segment A, default 6
  FIG15_MAX_JOIN_RATIO    cold-join gate, default 0.5
  FIG15_MAX_P99_X         predictive-vs-keepwarm p99 gate, default 1.1
  FIG15_REQUIRE_CONTRAST  0 -> skip the predictive<reactive p99 gate
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

import numpy as np

from repro import sdk
from repro.core import (
    ColdStartProfile,
    ControlPlaneConfig,
    Item,
    PredictorConfig,
    PrefetchConfig,
)
from repro.core.trace import TraceFunction, generate_events
from benchmarks.common import emit, track

QUICK = os.environ.get("FIG15_QUICK") == "1" or "--quick" in sys.argv
N_JOINERS = int(os.environ.get("FIG15_JOINERS", 6))

# ---------------------------------------------------------------- segment A
SEED_NODES = 2
JOIN_FUNCTIONS = 10
JOIN_WARM_S = 10.0          # warm-traffic window before the join wave
JOIN_RATE_HZ = 40.0
JOIN_MODEL_BYTES = 48 << 20
NODE_SLOTS = 8
NODE_CACHE_ENTRIES = 16
NODE_BASE_BYTES = 256 << 20
SETUP_S = 0.3e-3

# ---------------------------------------------------------------- segment B
BURST_PERIOD_S = 12.0
BURST_DUTY = 0.25
BURST_RATE_HZ = 20.0        # average; ON-phase rate = 20/0.25 = 80/s
BURST_EXEC_S = 0.2
BURST_EXEC_SIGMA = 0.3
BURST_MODEL_BYTES = 32 << 20
WEIGHT_COLD_S = 0.5         # weight cold start a prefetched node skips
MAX_NODES = 4
NODE_BOOT = ColdStartProfile(setup_s=0.75, execute_s=0.0, jitter_sigma=0.1)
# learning window: first prediction lands around cycle 5, so measure
# from cycle 5 onward
BURST_WARMUP_S = 5 * BURST_PERIOD_S
BURST_DURATION_S = 96.0 if QUICK else 132.0

PREDICTOR = PredictorConfig(
    bin_s=0.5, alpha=0.2, on_factor=1.5, min_cycles=2,
    lead_s=1.5, nodes_ahead=MAX_NODES - 1,
)


def _prefetch(peer: bool) -> PrefetchConfig:
    return PrefetchConfig(hot_k=JOIN_FUNCTIONS + 2, fanout=2, peer=peer)


# ===========================================================================
# Segment A: cold join — P2P tree vs origin-only fetch
# ===========================================================================
def _join_weight_store():
    ws = sdk.WeightStore(keepalive_s=60.0)
    half = JOIN_FUNCTIONS // 2
    ws.register("join_model_a", JOIN_MODEL_BYTES,
                tuple(f"joinfn{i}" for i in range(half)))
    ws.register("join_model_b", JOIN_MODEL_BYTES,
                tuple(f"joinfn{i}" for i in range(half, JOIN_FUNCTIONS)))
    return ws


def _join_node_spec(seed: int) -> sdk.NodeSpec:
    return sdk.NodeSpec(
        num_slots=NODE_SLOTS, code_cache_entries=NODE_CACHE_ENTRIES,
        base_bytes=NODE_BASE_BYTES, seed=seed,
        weight_store=_join_weight_store,
    )


def _join_segment(peer: bool) -> Dict[str, object]:
    cfg = ControlPlaneConfig(
        min_nodes=SEED_NODES, max_nodes=SEED_NODES,
        keepalive_s=120.0, node_base_bytes=NODE_BASE_BYTES,
    )
    platform = sdk.Platform(
        elastic=sdk.Elastic(config=cfg, seed=3, node=_join_node_spec(30)),
        config=sdk.PlatformConfig(prefetch=_prefetch(peer)),
    )
    comps = {}
    for i in range(JOIN_FUNCTIONS):
        spec = sdk.declare(
            f"joinfn{i}", lambda ins: {"out": [Item(1)]},
            inputs=("x",), outputs=("out",),
            profile=ColdStartProfile(SETUP_S, 0.020, jitter_sigma=0.2),
        )
        comps[i] = platform.deploy(sdk.single_function_app(spec))

    # warm the seed nodes with real traffic (code caches + weights)
    rng = np.random.default_rng(7)
    arrivals = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / JOIN_RATE_HZ)
        if t >= JOIN_WARM_S:
            break
        arrivals.append((t, comps[int(rng.integers(JOIN_FUNCTIONS))],
                         {"x": [Item(0)]}))
    platform.submit_stream(arrivals)

    cluster = platform.cluster

    def join_wave():
        for k in range(N_JOINERS):
            node = _join_node_spec(100 + k).build(platform, name=f"join{k}")
            cluster.add_node(node)

    platform.loop.at(JOIN_WARM_S, join_wave)
    with track(f"fig15/join_{'p2p' if peer else 'origin'}", len(arrivals)):
        platform.run()

    dist = platform.distributor
    warms = [w for _, _, w in dist.join_log]
    assert len(warms) == N_JOINERS, (
        f"fig15 join: {len(warms)} of {N_JOINERS} joins completed"
    )
    s = dist.summary()
    return {
        "segment": f"join_{'p2p' if peer else 'origin'}",
        "joiners": N_JOINERS,
        "artifacts": s["artifacts"],
        "peer_fetches": s["peer_fetches"],
        "origin_fetches": s["origin_fetches"],
        "transfer_mb": s["transfer_mb"],
        "join_avg_s": s["join_warm_avg_s"],
        "join_max_s": s["join_warm_max_s"],
    }


# ===========================================================================
# Segment B: predicted burst — keepwarm / reactive / predictive
# ===========================================================================
def _burst_weight_store(pinned: bool):
    def build():
        # keepwarm is the peak-provisioned reference: weights pinned for
        # the whole run. Elastic shapes pay keep-alive residency instead,
        # scaled to the node keepalive so retired nodes release promptly.
        ws = sdk.WeightStore(keepalive_s=0.0 if pinned else 4.0,
                             pinned=pinned)
        ws.register("burst_model", BURST_MODEL_BYTES, ("burstfn",))
        return ws
    return build


def _burst_node_spec(seed: int, *, pinned: bool) -> sdk.NodeSpec:
    return sdk.NodeSpec(
        num_slots=NODE_SLOTS, code_cache_entries=NODE_CACHE_ENTRIES,
        base_bytes=NODE_BASE_BYTES, seed=seed,
        weight_store=_burst_weight_store(pinned),
    )


def _burst_events():
    fn = TraceFunction(
        name="burstfn", rate_hz=BURST_RATE_HZ,
        exec_median_s=BURST_EXEC_S, exec_sigma=BURST_EXEC_SIGMA,
        context_bytes=1 << 20,
        burst_period_s=BURST_PERIOD_S, burst_duty=BURST_DUTY,
    )
    return generate_events([fn], BURST_DURATION_S, seed=11)


def _burst_segment(name: str, *, min_nodes: int,
                   predict: bool) -> Dict[str, object]:
    cfg = ControlPlaneConfig(
        min_nodes=min_nodes, max_nodes=MAX_NODES,
        target_outstanding_per_node=1.5 * NODE_SLOTS,
        max_queue_delay_s=100e-3,
        keepalive_s=3.0, tick_interval_s=0.25,
        node_boot=NODE_BOOT, node_base_bytes=NODE_BASE_BYTES,
    )
    pc = sdk.PlatformConfig(
        prefetch=_prefetch(True) if predict else None,
        predictor=PREDICTOR if predict else None,
    )
    pinned = min_nodes == MAX_NODES
    platform = sdk.Platform(
        elastic=sdk.Elastic(
            config=cfg, seed=5,
            node=_burst_node_spec(40, pinned=pinned),
        ),
        config=pc,
    )
    spec = sdk.declare(
        "burstfn", lambda ins: {"out": [Item(1)]},
        inputs=("x",), outputs=("out",), context_bytes=1 << 20,
        profile=ColdStartProfile(
            SETUP_S, BURST_EXEC_S, jitter_sigma=BURST_EXEC_SIGMA,
            cold_setup_s=WEIGHT_COLD_S,
        ),
    )
    comp = platform.deploy(sdk.single_function_app(spec))
    events = _burst_events()
    loop = platform.loop
    latencies: List[float] = []

    def stream():
        for e in events:
            if e.t >= BURST_WARMUP_S:
                def done(inv, t0=e.t):
                    if not inv.failed:
                        latencies.append(loop.now - t0)
                yield e.t, comp, {"x": [Item(0)]}, done
            else:
                yield e.t, comp, {"x": [Item(0)]}

    with track(f"fig15/{name}", len(events)):
        platform.submit_stream(stream())
        platform.run(until=BURST_DURATION_S)
        platform.run()      # drain stragglers past the window

    cp = platform.control_plane
    summ = cp.summary(BURST_DURATION_S)
    lat = np.array(latencies) if latencies else np.array([0.0])
    row = {
        "segment": name,
        "events": len(events),
        "measured": len(latencies),
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "avg_committed_mb": summ["committed_avg_mb"],
        "peak_committed_mb": summ["committed_peak_mb"],
        "nodes_avg": summ["nodes_avg"],
        "nodes_peak": summ["nodes_peak"],
        "scale_ups": summ["scale_ups"],
    }
    if predict:
        pred = cp.predictor.summary()
        row["predicted_edges"] = pred["edges"]
        row["predictions_fired"] = pred["fired"]
        row["period_est_s"] = pred["period_s"]
    return row


def _pad(rows: List[dict]) -> List[dict]:
    """Unify heterogeneous segment rows onto one column set (first-seen
    order, blanks for absent fields) so the CSV block has one header."""
    cols: Dict[str, None] = {}
    for r in rows:
        for k in r:
            cols.setdefault(k)
    return [{k: r.get(k, "") for k in cols} for r in rows]


def run() -> List[dict]:
    rows = [
        _join_segment(peer=True),
        _join_segment(peer=False),
        _burst_segment("burst_keepwarm", min_nodes=MAX_NODES, predict=False),
        _burst_segment("burst_reactive", min_nodes=1, predict=False),
        _burst_segment("burst_predictive", min_nodes=1, predict=True),
    ]
    by = {r["segment"]: r for r in rows}
    rows.append({
        "segment": "summary",
        "join_p2p_over_origin": (
            by["join_p2p"]["join_avg_s"]
            / max(by["join_origin"]["join_avg_s"], 1e-9)
        ),
        "predictive_p99_over_keepwarm": (
            by["burst_predictive"]["p99_ms"]
            / max(by["burst_keepwarm"]["p99_ms"], 1e-9)
        ),
        "reactive_p99_over_keepwarm": (
            by["burst_reactive"]["p99_ms"]
            / max(by["burst_keepwarm"]["p99_ms"], 1e-9)
        ),
        "predictive_mem_over_keepwarm": (
            by["burst_predictive"]["avg_committed_mb"]
            / max(by["burst_keepwarm"]["avg_committed_mb"], 1e-9)
        ),
    })
    rows = _pad(rows)
    _LAST["rows"] = rows
    return rows


# last run() result, serialized to BENCH_prefetch.json by write_json
# (called from benchmarks.run and from this module's main)
_LAST: Dict[str, object] = {}


def write_json(outdir: str = "results/bench") -> str:
    rows = _LAST.get("rows")
    if not rows:
        raise RuntimeError("fig15: run() before write_json()")
    by = {r["segment"]: r for r in rows}
    payload = {
        "workload": {
            "join": {
                "seed_nodes": SEED_NODES,
                "joiners": N_JOINERS,
                "functions": JOIN_FUNCTIONS,
                "model_bytes": JOIN_MODEL_BYTES,
                "warm_s": JOIN_WARM_S,
            },
            "burst": {
                "period_s": BURST_PERIOD_S,
                "duty": BURST_DUTY,
                "rate_hz": BURST_RATE_HZ,
                "exec_s": BURST_EXEC_S,
                "weight_cold_s": WEIGHT_COLD_S,
                "max_nodes": MAX_NODES,
                "node_boot_s": NODE_BOOT.setup_s,
                "duration_s": BURST_DURATION_S,
                "warmup_s": BURST_WARMUP_S,
                "predictor": {
                    "bin_s": PREDICTOR.bin_s,
                    "lead_s": PREDICTOR.lead_s,
                    "nodes_ahead": PREDICTOR.nodes_ahead,
                },
            },
        },
        "segments": by,
    }
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, "BENCH_prefetch.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def gate() -> None:
    """CI gates — all virtual-time deterministic, robust on any runner."""
    rows = _LAST.get("rows") or []
    by = {r["segment"]: r for r in rows}
    summ = by["summary"]
    max_join_ratio = float(os.environ.get("FIG15_MAX_JOIN_RATIO", 0.5))
    max_p99_x = float(os.environ.get("FIG15_MAX_P99_X", 1.1))
    contrast = os.environ.get("FIG15_REQUIRE_CONTRAST", "1") == "1"
    jr = summ["join_p2p_over_origin"]
    if jr >= max_join_ratio:
        raise SystemExit(
            f"fig15 join gate: P2P cold-join is {jr:.3f}x origin-only "
            f"(required < {max_join_ratio}x)"
        )
    px = summ["predictive_p99_over_keepwarm"]
    if px > max_p99_x:
        raise SystemExit(
            f"fig15 tail gate: predictive p99 is {px:.3f}x keepwarm "
            f"(limit {max_p99_x}x)"
        )
    mx = summ["predictive_mem_over_keepwarm"]
    if mx >= 1.0:
        raise SystemExit(
            f"fig15 memory gate: predictive committed avg is {mx:.3f}x "
            f"keepwarm — must be strictly lower"
        )
    if contrast and by["burst_predictive"]["p99_ms"] \
            >= by["burst_reactive"]["p99_ms"]:
        raise SystemExit(
            f"fig15 contrast gate: predictive p99 "
            f"{by['burst_predictive']['p99_ms']:.1f}ms must beat reactive "
            f"{by['burst_reactive']['p99_ms']:.1f}ms"
        )


def main():
    emit("fig15", run())
    path = write_json()
    print(f"# prefetch summary written to {path}")
    gate()


if __name__ == "__main__":
    main()
