"""Figure 12 (extension): cross-node composition scheduling trade-off.

The paper's elasticity claim (SS4/SS5): expressing applications as DAGs
of pure functions lets the platform place and scale each *vertex*
independently. This benchmark quantifies what vertex-granular placement
buys over whole-request pinning on fan-out DAGs:

  src --(payload)--> b0..b{W-1} (heavy contexts) --> join

run over a static 4-node cluster under load, in two modes on identical
hardware and identical arrival streams:

  * **local**  — today's default (``CROSSNODE=0``): the control plane
    routes a whole composition to one node; all W branch contexts commit
    on that node;
  * **crossnode** — vertex-granular placement (``CrossNodePlacer``):
    branches spread over the cluster, each cross edge charged one
    modeled transfer task on the producing node's comm engine
    (``TransferProfile``: latency + bytes/bandwidth, deterministic).

Reported per (mode, fan-out): p50/p99 latency, cluster-wide average and
peak committed memory, max single-node peak (the provisioning floor),
transfer count/bytes, and a cross/local ratio row. The measured
trade-off flips with DAG width vs node slots: when the fan-out fits one
node's engine slots, cross-node placement only costs (transfer latency
plus staged in-flight copies inflate p99 and committed memory a few
percent to ~1.5x); once the fan-out oversubscribes a node, vertex
spreading taps idle remote slots — p99 drops several-fold and *average*
committed memory falls too, because contexts live exactly as long as
their (now much shorter) queue+execute window. Memory/latency elasticity
bought with transfer bytes, priced per link.

Knobs (environment variables):

  FIG12_DURATION_S   arrival window, default 20 (virtual seconds)
  FIG12_RATE_HZ      composition arrivals/sec, default 6
  CROSSNODE          platform default for ClusterManager (this benchmark
                     passes explicit flags, so both modes always run)
"""
from __future__ import annotations

import os

from repro import sdk
from repro.core import ColdStartProfile, Item, TransferProfile
from repro.core.sim import merged_peak
from benchmarks.common import emit, track

N_NODES = 4
NODE_SLOTS = 4
FANOUTS = (2, 4, 8)
PAYLOAD_BYTES = 512 << 10            # src -> branch edge payload
BRANCH_CONTEXT_BYTES = 16 << 20      # the committed memory that spreads
BRANCH_EXEC_S = 25e-3
LINK = TransferProfile(latency_s=100e-6, bandwidth_bps=1.25e9)

DURATION_S = float(os.environ.get("FIG12_DURATION_S", 20.0))
RATE_HZ = float(os.environ.get("FIG12_RATE_HZ", 6.0))


def _fanout_app(width: int) -> sdk.App:
    """src --(payload)--> b0..b{W-1} (heavy contexts) --> join, declared
    through the SDK with per-function calibrated profiles."""
    src = sdk.declare(
        "src", lambda ins: {"out": [Item(b"x" * PAYLOAD_BYTES)]},
        inputs=("x",), outputs=("out",),
        profile=ColdStartProfile(0.3e-3, 1e-3, 0.0),
    )
    join = sdk.declare(
        "join",
        lambda ins: {"out": [Item("|".join(sorted(i.data for i in ins["xs"])))]},
        inputs=("xs",), outputs=("out",),
        profile=ColdStartProfile(0.3e-3, 2e-3, 0.0),
    )
    branches = [
        sdk.declare(
            f"b{k}",
            lambda ins, k=k: {"out": [Item(f"b{k}:{len(ins['xs'][0].data)}")]},
            inputs=("xs",), outputs=("out",),
            context_bytes=BRANCH_CONTEXT_BYTES,
            profile=ColdStartProfile(0.3e-3, BRANCH_EXEC_S, 0.0),
        )
        for k in range(width)
    ]
    with sdk.composition(f"fanout{width}") as app:
        s = app.input("x")
        sv = src(x=s)
        j = join()
        for spec in branches:
            b = spec(xs=sv.out)
            j.feed(xs=b.out)
        app.output("result", j.out)
    return app


def _run_mode(mode: str, width: int):
    crossnode = mode == "crossnode"
    platform = sdk.Platform(
        pool=[sdk.NodeSpec(num_slots=NODE_SLOTS, seed=30 + i, name=f"n{i}")
              for i in range(N_NODES)],
        crossnode=crossnode, transfer_profile=LINK,
    )
    comp = platform.deploy(_fanout_app(width))
    n_events = int(DURATION_S * RATE_HZ)
    arrivals = ((i / RATE_HZ, comp, {"x": [Item(b"go")]})
                for i in range(n_events))
    with track(f"fig12/{mode}_w{width}", n_events):
        platform.submit_stream(arrivals)
        platform.run(until=DURATION_S)
        # window aggregates read before draining (streaming fast path)
        nodes = platform.nodes
        node_avgs = [n.tracker.timeline.average(DURATION_S) for n in nodes]
        platform.run()   # drain stragglers
    s = platform.latency.summary()
    node_peaks = [n.tracker.timeline.peak() for n in nodes]
    stats = platform.placer.stats if platform.placer is not None else None
    return {
        "mode": mode,
        "fanout": width,
        "events": n_events,
        "p50_ms": s["p50_ms"],
        "p99_ms": s["p99_ms"],
        "cluster_avg_mb": sum(node_avgs) / 1024**2,
        "cluster_peak_mb": merged_peak([n.tracker.timeline for n in nodes]) / 1024**2,
        "max_node_peak_mb": max(node_peaks) / 1024**2,
        "remote_placement_rate": (
            stats.remote_placements
            / max(1, stats.local_placements + stats.remote_placements)
            if stats else 0.0
        ),
        "transfers": stats.transfers if stats else 0,
        "transfer_mb": (stats.bytes_total / 1024**2) if stats else 0.0,
    }


def run():
    rows = []
    for width in FANOUTS:
        local = _run_mode("local", width)
        cross = _run_mode("crossnode", width)
        rows.append(local)
        rows.append(cross)
        rows.append({
            "mode": "ratio",
            "fanout": width,
            "events": local["events"],
            "p50_ms": cross["p50_ms"] / max(local["p50_ms"], 1e-9),
            "p99_ms": cross["p99_ms"] / max(local["p99_ms"], 1e-9),
            "cluster_avg_mb": cross["cluster_avg_mb"]
            / max(local["cluster_avg_mb"], 1e-9),
            "cluster_peak_mb": cross["cluster_peak_mb"]
            / max(local["cluster_peak_mb"], 1e-9),
            "max_node_peak_mb": cross["max_node_peak_mb"]
            / max(local["max_node_peak_mb"], 1e-9),
            "remote_placement_rate": cross["remote_placement_rate"],
            "transfers": cross["transfers"],
            "transfer_mb": cross["transfer_mb"],
        })
    return rows


def main():
    emit("fig12", run())


if __name__ == "__main__":
    main()
