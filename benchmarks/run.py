"""Benchmark harness entry: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig5,...]

Emits CSV blocks per benchmark to stdout (tee'd into bench_output.txt by
the final deliverable run) and mirrors them under results/bench/. Every
sub-benchmark's pass/fail lands in the end-of-run summary, and the exit
code is non-zero if any failed.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks import (
    fig2_hot_ratio,
    fig5_throughput,
    fig7_split,
    fig8_multiplex,
    fig9_query,
    fig10_azure_trace,
    fig11_elastic_scaleout,
    fig12_crossnode,
    fig13_serving,
    fig14_chaos,
    fig15_prefetch,
    roofline,
    table1_coldstart,
)
from benchmarks.common import emit, write_simperf

BENCHES = {
    "table1": ("Table 1: cold-start phase breakdown", table1_coldstart.run),
    "fig2": ("Fig 2/6: latency vs hot-request ratio", fig2_hot_ratio.run),
    "fig5": ("Fig 5: tail latency vs RPS (0% hot)", fig5_throughput.run),
    "fig7": ("Fig 7: compute/comm split vs D-hybrid", fig7_split.run),
    "fig8": ("Fig 8: multiplexing mixed bursty apps", fig8_multiplex.run),
    "fig9": ("Fig 9: SSB query latency + cost", fig9_query.run),
    "fig10": ("Fig 1/10: Azure-trace committed memory", fig10_azure_trace.run),
    "fig11": ("Fig 11: elastic scale-out vs static cluster",
              fig11_elastic_scaleout.run),
    "fig12": ("Fig 12: cross-node composition scheduling trade-off",
              fig12_crossnode.run),
    "fig13": ("Fig 13: LM serving as an elastic composition workload",
              fig13_serving.run),
    "fig14": ("Fig 14: reliability under chaos (churn + cancellation)",
              fig14_chaos.run),
    "fig15": ("Fig 15: P2P artifact prefetch + predictive scaling",
              fig15_prefetch.run),
    "roofline": ("Roofline: dry-run three-term table", roofline.run),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--outdir", default="results/bench")
    args = ap.parse_args()
    names = list(BENCHES) if args.only == "all" else args.only.split(",")
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; known: {list(BENCHES)}")
    os.makedirs(args.outdir, exist_ok=True)

    status = {}  # name -> (ok, seconds)
    for name in names:
        title, fn = BENCHES[name]
        print(f"\n## {name}: {title}")
        t0 = time.time()
        try:
            rows = fn()
            emit(name, rows)
            with open(os.path.join(args.outdir, f"{name}.csv"), "w") as f:
                emit(name, rows, out_stream=f)
            status[name] = (True, time.time() - t0)
            print(f"# {name} done in {status[name][1]:.1f}s")
        except (Exception, SystemExit) as e:
            status[name] = (False, time.time() - t0)
            print(f"# {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    # serving summary (deterministic JSON next to the CSVs)
    if status.get("fig13", (False,))[0]:
        print(f"# serving summary written to "
              f"{fig13_serving.write_json(args.outdir)}")
    # chaos summary + gates (completion rate, contrast, tail bound)
    if status.get("fig14", (False,))[0]:
        print(f"# chaos summary written to "
              f"{fig14_chaos.write_json(args.outdir)}")
        try:
            fig14_chaos.gate()
        except SystemExit as e:
            print(f"# fig14 gate FAILED: {e}")
            status["fig14"] = (False, status["fig14"][1])
    # prefetch summary + gates (cold-join ratio, predicted-burst tail)
    if status.get("fig15", (False,))[0]:
        print(f"# prefetch summary written to "
              f"{fig15_prefetch.write_json(args.outdir)}")
        try:
            fig15_prefetch.gate()
        except SystemExit as e:
            print(f"# fig15 gate FAILED: {e}")
            status["fig15"] = (False, status["fig15"][1])
    # simulator throughput trajectory (events/sec per tracked segment)
    perf_path = write_simperf(args.outdir)
    print(f"# simulator throughput written to {perf_path}")

    # determinism lint over the platform source: an unwaived finding
    # (wall-clock, unseeded RNG, set iteration, ...) threatens the very
    # byte-identity the benchmarks above are gated on, so it fails the
    # run like any benchmark
    t0 = time.time()
    from repro.analysis.detlint import lint_paths
    src_root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    lint = lint_paths([src_root])
    status["det_lint"] = (not lint.unwaived, time.time() - t0)
    if lint.unwaived:
        print(f"# det_lint FAILED: {len(lint.unwaived)} unwaived finding(s)")
        for f in lint.unwaived:
            print(f"#   {f.render()}")
    else:
        print(f"# det_lint clean ({len(lint.waived)} waived finding(s))")

    failed = [n for n, (ok, _) in status.items() if not ok]
    print("\n# ---- summary ----")
    for name, (ok, secs) in status.items():
        print(f"# {name:10s} {'PASS' if ok else 'FAIL'}  {secs:7.1f}s")
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        raise SystemExit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
