"""Figure 14 (extension): reliability under chaos — node churn, timeout
pressure, and request cancellation on the fig10-style trace workload.

Three segments on an identical 4-node static pool, identical seeded
arrival process (Poisson) and lognormal execution jitter sized so ~2.3%
of attempts exceed the per-vertex timeout:

  * ``baseline``      — retry policy on, no churn, no cancellations:
                        the churn-free latency reference;
  * ``chaos_on``      — periodic node kills (a replacement node joins
                        after each), random cancellations, retries ON
                        (``RetryPolicy(max_retries=3, base_backoff_s=...,
                        retry_timeouts=True)``) and the cluster's
                        node-death restart budget raised;
  * ``chaos_off``     — the same chaos with retries and restarts OFF:
                        every timeout or lost node is a whole-request
                        failure.

Each node carries a ``WeightStore`` model bound to the workload function
so the segments also pin the reliability refcount invariants after the
loop drains, on every node that ever existed (including the dead ones):

  * freed-exactly-once: ``tracker.committed`` returns to the weight
    store's resident bytes (0 leaked context/staging bytes);
  * weights-inflight-zero: every ``touch`` was balanced by ``task_done``
    across retries, hedges, node death, and cancellation.

Gates (CI): ``chaos_on`` completes >= FIG14_MIN_COMPLETION of the
non-cancelled requests; ``chaos_off`` records > 0 whole-run failures;
``chaos_on`` p99 stays within FIG14_MAX_P99_X of ``baseline`` p99; the
invariants above hold. Summary JSON lands in
``results/bench/BENCH_chaos.json``. fig14 IS in the byte-identity set
(tools/check_bench_identity.py): churn and cancellation are fully
modeled in virtual time, so its CSV data rows and JSON sidecar must
match the committed seeds byte-for-byte.

Knobs (environment variables):

  FIG14_DURATION_S        trace window, default 120
  FIG14_RATE_HZ           aggregate arrival rate, default 25
  FIG14_NODES             pool size, default 4
  FIG14_CHURN_PERIOD_S    seconds between node kills, default 12
  FIG14_CANCEL_RATE       fraction of requests cancelled, default 0.05
  FIG14_MIN_COMPLETION    completion-rate gate, default 0.99
  FIG14_MAX_P99_X         p99 inflation gate vs baseline, default 5.0
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List

import numpy as np

from repro import sdk
from repro.core import ColdStartProfile, Item
from repro.sdk import NodeSpec, RetryPolicy, WeightStore
from benchmarks.common import emit, track

DURATION_S = float(os.environ.get("FIG14_DURATION_S", 120.0))
RATE_HZ = float(os.environ.get("FIG14_RATE_HZ", 50.0))
N_NODES = int(os.environ.get("FIG14_NODES", 4))
CHURN_PERIOD_S = float(os.environ.get("FIG14_CHURN_PERIOD_S", 12.0))
CANCEL_RATE = float(os.environ.get("FIG14_CANCEL_RATE", 0.05))

SLOTS = 8
SETUP_S = 0.3e-3
# 20ms median keeps ~1-2 requests in flight per node-kill instant, so
# the churn segments actually exercise the node-death restart path
MEDIAN_S = 20e-3
SIGMA = 0.8
# exec ~ lognormal(median, sigma): P(exec > median * e^{2 sigma}) ~ 2.3%,
# so this timeout preempts ~2.3% of attempts — retries rescue them,
# the retries-off segment turns each into a whole-request failure
TIMEOUT_S = MEDIAN_S * math.exp(2.0 * SIGMA)
MODEL_BYTES = 64 << 20
KEEPALIVE_S = 0.05

CHAOS_RETRY = RetryPolicy(
    max_retries=3, base_backoff_s=0.02, max_backoff_s=0.5,
    retry_timeouts=True,
)
NO_RETRY = RetryPolicy(max_retries=0)


def _weight_store_factory():
    ws = WeightStore(keepalive_s=KEEPALIVE_S)
    ws.register("chaos_model", MODEL_BYTES, ("churnwork",))
    return ws


def _node_spec(seed: int) -> NodeSpec:
    return NodeSpec(
        num_slots=SLOTS, comm_slots=1, seed=seed,
        weight_store=_weight_store_factory,
    )


def _segment(name: str, *, retry: RetryPolicy, restart_attempts: int,
             churn: bool, cancels: bool, seed: int) -> Dict[str, object]:
    platform = sdk.Platform(
        pool=[_node_spec(seed=seed + i) for i in range(N_NODES)],
        restart_attempts=restart_attempts,
    )
    spec = sdk.declare(
        "churnwork", lambda ins: {"out": [Item(1)]},
        inputs=("x",), outputs=("out",),
        timeout_s=TIMEOUT_S, retry=retry,
        profile=ColdStartProfile(SETUP_S, MEDIAN_S, jitter_sigma=SIGMA),
    )
    comp = platform.deploy(sdk.single_function_app(spec))
    loop = platform.loop
    cluster = platform.cluster

    # ---------------- seeded arrival + cancellation plan ----------------
    rng = np.random.default_rng(seed)
    arrivals: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / RATE_HZ)
        if t >= DURATION_S:
            break
        arrivals.append(t)

    latencies: List[float] = []
    handles = []
    for t in arrivals:
        def make_done(t0=t):
            return lambda inv: (
                latencies.append(loop.now - t0) if not inv.failed else None
            )

        h = platform.invoke(comp, {"x": [Item(0)]}, at=t,
                            on_done=make_done())
        handles.append(h)
        if cancels and rng.random() < CANCEL_RATE:
            loop.at(t + rng.uniform(0.0, 2.0 * MEDIAN_S), h.cancel)

    # ------------------------------- churn ------------------------------
    kills = 0
    if churn:
        def kill(k: int):
            nonlocal kills
            alive = [n for n in cluster.nodes if n.alive]
            if len(alive) <= 1:
                return      # never kill the last survivor
            victim = alive[0]   # oldest alive node
            victim.fail()
            if cluster.placer is not None:
                cluster.placer.on_node_failure(victim)
            spare = _node_spec(seed=seed + 1000 + k).build(
                platform, name=f"spare{k}")
            cluster.add_node(spare)
            kills += 1

        n_kills = int(DURATION_S / CHURN_PERIOD_S)
        for k in range(1, n_kills):
            loop.at(k * CHURN_PERIOD_S, lambda k=k: kill(k))

    with track(f"fig14/{name}", len(arrivals)):
        platform.run(until=DURATION_S)
        platform.run()      # drain stragglers (retries, restarts)

    # --------------------------- classification -------------------------
    completed = failed = cancelled = 0
    for h in handles:
        if h.invocation is None:
            # cancelled before the scheduled fire: never dispatched
            assert h.cancelled, "handle neither completed nor cancelled"
            cancelled += 1
        elif h.invocation.failure_kind == "cancelled":
            cancelled += 1
        elif h.invocation.failed:
            failed += 1
        else:
            completed += 1
    eligible = len(handles) - cancelled
    completion_rate = completed / eligible if eligible else 1.0

    # ------------------------ refcount invariants -----------------------
    # every node that ever existed, dead ones included: committed bytes
    # must return to exactly the resident weights (nothing leaked), and
    # the weight-store touch/task_done refcount must balance to zero
    leak_bytes = 0
    weights_inflight = 0
    for node in cluster.nodes:
        resident = node.weight_store.resident_bytes
        leak_bytes += node.tracker.committed - resident
        weights_inflight += node.weight_store.inflight
    if leak_bytes != 0:
        raise SystemExit(
            f"fig14/{name}: freed-exactly-once violated — "
            f"{leak_bytes} bytes still committed after drain"
        )
    if weights_inflight != 0:
        raise SystemExit(
            f"fig14/{name}: weight refcount violated — "
            f"{weights_inflight} touches never balanced"
        )

    lat = np.array(latencies) if latencies else np.array([0.0])
    return {
        "segment": name,
        "invocations": len(handles),
        "completed": completed,
        "failed": failed,
        "cancelled": cancelled,
        "completion_rate": completion_rate,
        "node_kills": kills,
        "restarts": cluster.restarts,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "leak_bytes": leak_bytes,
        "weights_inflight": weights_inflight,
    }


def run() -> List[dict]:
    rows = [
        _segment("baseline", retry=CHAOS_RETRY, restart_attempts=3,
                 churn=False, cancels=False, seed=10),
        _segment("chaos_on", retry=CHAOS_RETRY, restart_attempts=8,
                 churn=True, cancels=True, seed=10),
        _segment("chaos_off", retry=NO_RETRY, restart_attempts=0,
                 churn=True, cancels=True, seed=10),
    ]
    _LAST["rows"] = rows
    return rows


# last run() result, serialized to BENCH_chaos.json by write_json
# (called from benchmarks.run and from this module's main)
_LAST: Dict[str, object] = {}


def write_json(outdir: str = "results/bench") -> str:
    rows = _LAST.get("rows")
    if not rows:
        raise RuntimeError("fig14: run() before write_json()")
    by = {r["segment"]: r for r in rows}
    payload = {
        "workload": {
            "duration_s": DURATION_S,
            "rate_hz": RATE_HZ,
            "nodes": N_NODES,
            "slots": SLOTS,
            "churn_period_s": CHURN_PERIOD_S,
            "cancel_rate": CANCEL_RATE,
            "timeout_s": TIMEOUT_S,
            "exec_median_s": MEDIAN_S,
            "exec_sigma": SIGMA,
            "retry": {
                "max_retries": CHAOS_RETRY.max_retries,
                "base_backoff_s": CHAOS_RETRY.base_backoff_s,
                "max_backoff_s": CHAOS_RETRY.max_backoff_s,
                "retry_timeouts": CHAOS_RETRY.retry_timeouts,
            },
        },
        "segments": by,
        "chaos_on_vs_off": {
            "completion_on": by["chaos_on"]["completion_rate"],
            "completion_off": by["chaos_off"]["completion_rate"],
            "failures_rescued": (
                by["chaos_off"]["failed"] - by["chaos_on"]["failed"]
            ),
            "p99_inflation_vs_baseline": (
                by["chaos_on"]["p99_ms"] / max(by["baseline"]["p99_ms"], 1e-9)
            ),
        },
    }
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, "BENCH_chaos.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def gate() -> None:
    """CI gates: retries-on survives chaos; retries-off visibly does not;
    the tail stays bounded (all deterministic in virtual time, so the
    floors are robust on any runner)."""
    rows = _LAST.get("rows") or []
    by = {r["segment"]: r for r in rows}
    min_completion = float(os.environ.get("FIG14_MIN_COMPLETION", 0.99))
    max_p99_x = float(os.environ.get("FIG14_MAX_P99_X", 5.0))
    on, off, base = by["chaos_on"], by["chaos_off"], by["baseline"]
    if on["completion_rate"] < min_completion:
        raise SystemExit(
            f"fig14 completion gate: chaos_on completes "
            f"{on['completion_rate']:.4f} < required {min_completion:.4f}"
        )
    if off["failed"] <= on["failed"]:
        raise SystemExit(
            f"fig14 contrast gate: retries off must fail more requests "
            f"than retries on (off={off['failed']}, on={on['failed']})"
        )
    inflation = on["p99_ms"] / max(base["p99_ms"], 1e-9)
    if inflation > max_p99_x:
        raise SystemExit(
            f"fig14 tail gate: chaos_on p99 {on['p99_ms']:.1f}ms is "
            f"{inflation:.1f}x baseline {base['p99_ms']:.1f}ms "
            f"(limit {max_p99_x:.1f}x)"
        )


def main():
    emit("fig14", run())
    path = write_json()
    print(f"# chaos summary written to {path}")
    gate()


if __name__ == "__main__":
    main()
