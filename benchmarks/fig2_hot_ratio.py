"""Figures 2 & 6: latency sensitivity to the % of hot (warm) requests.

128x128 int64 matmul on the keep-warm (Firecracker-analogue) platform at
a fixed moderate load, sweeping the forced hot-request ratio, vs Dandelion
cold-starting every request. Reports median / p5 / p95 / p99 - the paper's
point is the 2-3 orders of magnitude between the platforms' variability.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    ColdStartProfile,
    EventLoop,
    FunctionRegistry,
    KeepWarmPlatform,
    WorkerNode,
)
from repro.core.items import Item
from benchmarks.common import (
    calibrate,
    emit,
    matmul_inputs,
    register_matmul,
    single_function_composition,
)

N = 128
RPS = 400.0
DURATION = 15.0
CORES = 16


def _requests(seed=0):
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    while t < DURATION:
        t += float(rng.exponential(1.0 / RPS))
        out.append(t)
    return out


def run():
    reg = FunctionRegistry()
    name = register_matmul(reg, N)
    inputs = matmul_inputs(N)
    dand = calibrate(reg, name, inputs, backend="dandelion")
    # boot-cost analogues from the real AOT code paths (see Table 1):
    # snapshot restore (deserialize) and full boot (trace+lower+compile)
    snap = calibrate(reg, name, inputs, backend="snapshot")
    boot = calibrate(reg, name, inputs, backend="microvm")

    rows = []
    # --- keep-warm platform at several hot ratios, both boot modes ---
    for label, boot_s in (("keepwarm_snapshot", snap.setup_s),
                          ("keepwarm_fullboot", boot.setup_s)):
        for hot in (1.0, 0.99, 0.97, 0.9, 0.5):
            loop = EventLoop()
            kw = KeepWarmPlatform(loop, cores=CORES, hot_ratio=hot, seed=1)
            kw.register(name, ColdStartProfile(boot_s, dand.execute_s),
                        context_bytes=reg.get(name).context_bytes)
            for t in _requests():
                kw.request_at(t, name)
            loop.run()
            s = kw.latency.summary()
            rows.append({
                "platform": label, "hot_pct": hot * 100,
                "p50_ms": s["p50_ms"], "p5_ms": kw.latency.percentile(5) * 1e3,
                "p95_ms": s["p95_ms"], "p99_ms": s["p99_ms"],
                "rel_var_pct": s["rel_var_pct"],
            })

    # --- Dandelion: every request cold, 3% code-cache misses (SS7.3) ---
    node = WorkerNode(
        reg, num_slots=CORES, comm_slots=1,
        profiles={name: dand}, cache_miss_rate=0.03, seed=1,
    )
    comp = single_function_composition(reg, name)
    for t in _requests():
        node.invoke_at(t, comp, {"x": list(inputs["x"])})
    node.run()
    s = node.latency.summary()
    rows.append({
        "platform": "dandelion", "hot_pct": 0.0,
        "p50_ms": s["p50_ms"], "p5_ms": node.latency.percentile(5) * 1e3,
        "p95_ms": s["p95_ms"], "p99_ms": s["p99_ms"],
        "rel_var_pct": s["rel_var_pct"],
    })
    return rows


def main():
    emit("fig2_fig6_hot_ratio", run())


if __name__ == "__main__":
    main()
