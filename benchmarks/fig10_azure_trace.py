"""Figures 1 & 10: Azure-Functions-trace memory over-provisioning.

Synthetic Azure-like trace (100 functions, heavy-tailed rates, lognormal
execution times, ON/OFF bursts; generator parameters in
repro/core/trace.py, seeded). Two platforms on identical hardware budget:

  * Knative-style keep-warm autoscaling over snapshot-boot sandboxes
    (concurrency-target autoscaler, keep-alive reaping, guest OS resident
    per sandbox);
  * Dandelion: a context per request, committed only while running.

Reports average/peak committed memory and end-to-end latency percentiles,
plus the active-memory floor (the Fig. 1 blue line) and wall-clock
simulator throughput (events/sec, recorded in BENCH_simperf.json).

Knobs (environment variables):

  FIG10_DURATION_S  trace window, default 1200 (the paper's 20-minute
                    window at full rate — affordable since the simulator
                    fast path: payload memoization, idle-slot scheduling,
                    streaming timelines, cursor-based trace injection)
  FIG10_RATE_HZ     aggregate invocation rate, default 50
  FIG10_MIN_EPS     optional CI gate: exit non-zero unless the Dandelion
                    segment sustains at least this many events/sec
"""
from __future__ import annotations

import os

import numpy as np

from repro import sdk
from repro.core import ColdStartProfile, EventLoop, KeepWarmPlatform
from repro.core.items import Item
from repro.core.trace import generate_events, generate_functions
from benchmarks.common import (
    PERF,
    SIMPERF_EXTRA,
    emit,
    track,
    write_simperf,
)

CORES = 16
# Full paper scale: 20-minute window, 100 functions, 50 Hz aggregate.
# (The pre-fast-path event loop only afforded a 5-minute window; the
# committed-memory ratio is stationary after the first keep-alive period
# (~60 s), so the longer window adds statistical weight, not new regime.)
DURATION_S = float(os.environ.get("FIG10_DURATION_S", 1200.0))
TOTAL_RATE_HZ = float(os.environ.get("FIG10_RATE_HZ", 50.0))
N_FUNCTIONS = 100
GUEST_OS_BYTES = 128 << 20
SNAPSHOT_BOOT_S = 15e-3
DANDELION_SETUP_S = 0.3e-3

# Dandelion-segment throughput measured before the simulator fast path
# (PR 2), on this container at the 300 s window: 15582 events / ~28.9 s.
# The acceptance target is >= 10x this.
BASELINE_DANDELION_EPS = 540.0


def run():
    fns = generate_functions(N_FUNCTIONS, seed=0, total_rate_hz=TOTAL_RATE_HZ)
    events = generate_events(fns, DURATION_S, seed=1)

    # ---- active-memory floor: Little's-law integral of running requests
    active_avg = sum(e.exec_s for e in events) / DURATION_S
    mem_by_fn = {f.name: f.context_bytes for f in fns}
    active_mem_avg = (
        sum(e.exec_s * mem_by_fn[e.fn] for e in events) / DURATION_S
    )

    rows = []

    # ---------------- Knative keep-warm over snapshots ----------------
    loop = EventLoop()
    kw = KeepWarmPlatform(
        loop, cores=CORES, guest_os_bytes=GUEST_OS_BYTES,
        keepalive_s=60.0, seed=2,
    )
    for f in fns:
        kw.register(f.name, ColdStartProfile(SNAPSHOT_BOOT_S, f.exec_median_s),
                    context_bytes=f.context_bytes)
    with track("fig10/keepwarm", len(events)):
        kw.request_stream((e.t, e.fn) for e in events)
        loop.run(until=DURATION_S)
    kw_avg_mb = kw.committed_avg_bytes / 1024**2
    s = kw.latency.summary()
    cold_frac = kw.cold_count / max(1, kw.cold_count + kw.warm_count)
    rows.append({
        "platform": "knative_keepwarm",
        "events": len(events),
        "avg_committed_mb": kw_avg_mb,
        "peak_committed_mb": kw.tracker.timeline.peak() / 1024**2,
        "active_floor_mb": active_mem_avg / 1024**2,
        "cold_start_pct": cold_frac * 100,
        "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
    })

    # ------------------------- Dandelion ------------------------------
    # SDK front door: one typed declaration per trace function (payload +
    # context bytes + calibrated profile in one place), deployed onto a
    # single-node Platform and driven through submit_stream
    platform = sdk.Platform(node=sdk.NodeSpec(
        num_slots=CORES, comm_slots=1, cache_miss_rate=0.03, seed=3,
    ))
    comps = {}
    for f in fns:
        spec = sdk.declare(
            f.name, lambda ins: {"out": [Item(1)]},
            inputs=("x",), outputs=("out",),
            context_bytes=f.context_bytes,
            profile=ColdStartProfile(
                DANDELION_SETUP_S, f.exec_median_s, jitter_sigma=f.exec_sigma,
            ),
        )
        comps[f.name] = platform.deploy(sdk.single_function_app(spec))
    node = platform.node
    with track("fig10/dandelion", len(events)):
        platform.submit_stream(
            (e.t, comps[e.fn], {"x": [Item(0)]}) for e in events)
        platform.run(until=DURATION_S)
        # window average read before draining keeps the O(1) streaming path
        dd_avg_mb = node.tracker.timeline.average(DURATION_S) / 1024**2
        platform.run()  # drain stragglers past the window
    s = platform.latency.summary()
    rows.append({
        "platform": "dandelion",
        "events": len(events),
        "avg_committed_mb": dd_avg_mb,
        "peak_committed_mb": node.tracker.timeline.peak() / 1024**2,
        "active_floor_mb": active_mem_avg / 1024**2,
        "cold_start_pct": 100.0,
        "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
    })

    rows.append({
        "platform": "summary",
        "events": len(events),
        "avg_committed_mb": dd_avg_mb / kw_avg_mb,  # ratio (paper: ~0.04)
        "peak_committed_mb": 0.0,
        "active_floor_mb": active_mem_avg / 1024**2,
        "cold_start_pct": 0.0,
        "p50_ms": 0.0,
        "p99_ms": rows[1]["p99_ms"] / max(rows[0]["p99_ms"], 1e-9),
    })
    dd = PERF["fig10/dandelion"]
    SIMPERF_EXTRA["fig10/dandelion"] = {
        "baseline_events_per_sec": BASELINE_DANDELION_EPS,
        "speedup_vs_baseline": dd.events_per_sec / BASELINE_DANDELION_EPS,
        "duration_s": DURATION_S,
        "total_rate_hz": TOTAL_RATE_HZ,
    }
    return rows


def main():
    emit("fig10", run())
    write_simperf()
    dd = PERF.get("fig10/dandelion")
    min_eps = float(os.environ.get("FIG10_MIN_EPS", 0.0))
    if min_eps > 0 and dd is not None and dd.events_per_sec < min_eps:
        raise SystemExit(
            f"fig10 throughput gate: {dd.events_per_sec:.0f} events/sec "
            f"< required {min_eps:.0f}"
        )


if __name__ == "__main__":
    main()
