"""Figures 1 & 10: Azure-Functions-trace memory over-provisioning.

Synthetic Azure-like trace (100 functions, heavy-tailed rates, lognormal
execution times, ON/OFF bursts; generator parameters in
repro/core/trace.py, seeded). Two platforms on identical hardware budget:

  * Knative-style keep-warm autoscaling over snapshot-boot sandboxes
    (concurrency-target autoscaler, keep-alive reaping, guest OS resident
    per sandbox);
  * Dandelion: a context per request, committed only while running.

Reports average/peak committed memory and end-to-end latency percentiles,
plus the active-memory floor (the Fig. 1 blue line).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    ColdStartProfile,
    EventLoop,
    FunctionRegistry,
    KeepWarmPlatform,
    WorkerNode,
)
from repro.core.items import Item
from repro.core.trace import generate_events, generate_functions
from benchmarks.common import emit, single_function_composition

CORES = 16
# a 5-minute window keeps the discrete-event run CPU-cheap; the committed-
# memory ratio is stationary after the first keep-alive period (~60 s), so
# the 20-minute paper window adds events, not information
DURATION_S = 300.0
N_FUNCTIONS = 100
GUEST_OS_BYTES = 128 << 20
SNAPSHOT_BOOT_S = 15e-3
DANDELION_SETUP_S = 0.3e-3


def run():
    fns = generate_functions(N_FUNCTIONS, seed=0)
    events = generate_events(fns, DURATION_S, seed=1)

    # ---- active-memory floor: Little's-law integral of running requests
    active_avg = sum(e.exec_s for e in events) / DURATION_S
    mem_by_fn = {f.name: f.context_bytes for f in fns}
    active_mem_avg = (
        sum(e.exec_s * mem_by_fn[e.fn] for e in events) / DURATION_S
    )

    rows = []

    # ---------------- Knative keep-warm over snapshots ----------------
    loop = EventLoop()
    kw = KeepWarmPlatform(
        loop, cores=CORES, guest_os_bytes=GUEST_OS_BYTES,
        keepalive_s=60.0, seed=2,
    )
    for f in fns:
        kw.register(f.name, ColdStartProfile(SNAPSHOT_BOOT_S, f.exec_median_s),
                    context_bytes=f.context_bytes)
    for e in events:
        kw.request_at(e.t, e.fn)
    loop.run(until=DURATION_S)
    s = kw.latency.summary()
    cold_frac = kw.cold_count / max(1, kw.cold_count + kw.warm_count)
    rows.append({
        "platform": "knative_keepwarm",
        "events": len(events),
        "avg_committed_mb": kw.committed_avg_bytes / 1024**2,
        "peak_committed_mb": kw.tracker.timeline.peak() / 1024**2,
        "active_floor_mb": active_mem_avg / 1024**2,
        "cold_start_pct": cold_frac * 100,
        "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
    })

    # ------------------------- Dandelion ------------------------------
    reg = FunctionRegistry()
    profiles = {}
    comps = {}
    for f in fns:
        reg.register_function(
            f.name, lambda ins: {"out": [Item(1)]},
            context_bytes=f.context_bytes,
        )
        profiles[f.name] = ColdStartProfile(
            DANDELION_SETUP_S, f.exec_median_s, jitter_sigma=f.exec_sigma,
        )
        comps[f.name] = single_function_composition(reg, f.name)
    node = WorkerNode(
        reg, num_slots=CORES, comm_slots=1, profiles=profiles,
        cache_miss_rate=0.03, seed=3,
    )
    for e in events:
        node.invoke_at(e.t, comps[e.fn], {"x": [Item(0)]})
    node.run(until=DURATION_S)
    node.loop.run()  # drain stragglers past the window
    s = node.latency.summary()
    rows.append({
        "platform": "dandelion",
        "events": len(events),
        "avg_committed_mb": node.tracker.timeline.average(DURATION_S) / 1024**2,
        "peak_committed_mb": node.tracker.timeline.peak() / 1024**2,
        "active_floor_mb": active_mem_avg / 1024**2,
        "cold_start_pct": 100.0,
        "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
    })

    kw_mb = rows[0]["avg_committed_mb"]
    dd_mb = rows[1]["avg_committed_mb"]
    rows.append({
        "platform": "summary",
        "events": len(events),
        "avg_committed_mb": dd_mb / kw_mb,  # ratio (paper: ~0.04)
        "peak_committed_mb": 0.0,
        "active_floor_mb": active_mem_avg / 1024**2,
        "cold_start_pct": 0.0,
        "p50_ms": 0.0,
        "p99_ms": rows[1]["p99_ms"] / max(rows[0]["p99_ms"], 1e-9),
    })
    return rows


def main():
    emit("fig10_azure_trace", run())


if __name__ == "__main__":
    main()
