"""Figure 7: compute/communication split + PI control vs unified "D-hybrid".

D-hybrid runs a composition as a single hybrid function: network I/O blocks
the execution thread, and the OS multiplexes ``tpc`` threads per core.
Modeled as engine slots = cores x tpc with the CPU portion inflated by the
processor-sharing factor (tpc) under saturation; the I/O portion is not
inflated (threads sleep). Dandelion runs the same work as a real
composition: compute functions run-to-completion on dedicated cores,
communication functions multiplex cooperatively, and the PI controller
moves cores between the pools.

Two workloads (SS7.5): compute-intensive (128x128 int64 matmul) and
I/O-intensive (fetch 64 KiB + reduce).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    ColdStartProfile,
    Composition,
    FunctionRegistry,
    HttpRequest,
    Item,
    ServiceRegistry,
    WorkerNode,
)
from benchmarks.common import (
    calibrate,
    emit,
    matmul_inputs,
    register_matmul,
    register_reduce,
    single_function_composition,
    storage_service,
)

CORES = 16
DURATION = 8.0


def _fetch_compute_comp(reg: FunctionRegistry) -> Composition:
    reg.register_function(
        "mk_req",
        lambda ins: {"req": [Item(HttpRequest("GET", "http://storage.svc/blob"))]},
    )
    c = Composition("fetch_compute")
    m = c.compute("mk_req", "mk_req", inputs=("x",), outputs=("req",))
    h = c.http("fetch")
    r = c.compute("reduce", "reduce", inputs=("data",), outputs=("out",))
    c.edge(m["req"], h["requests"])
    c.edge(h["responses"], r["data"])
    c.bind_input("x", m["x"])
    c.bind_output("out", r["out"])
    reg.register_composition(c)
    return c


def _drive(node, comp, inputs, rps, seed=5):
    rng = np.random.default_rng(seed)
    duration = min(DURATION, 25_000 / rps)  # bound the event count
    t = 0.0
    while t < duration:
        t += float(rng.exponential(1.0 / rps))
        node.invoke_at(t, comp, {k: list(v) for k, v in inputs.items()})
    node.run()
    s = node.latency.summary()
    return {
        "goodput_rps": s["n"] / duration,
        "p50_ms": s["p50_ms"],
        "p99_ms": s["p99_ms"],
    }


def run():
    reg = FunctionRegistry()
    services = ServiceRegistry()
    storage_service(services)
    mm = register_matmul(reg, 128)
    register_reduce(reg)
    mm_inputs = matmul_inputs(128)
    mm_comp = single_function_composition(reg, mm)
    fc_comp = _fetch_compute_comp(reg)

    mm_prof = calibrate(reg, mm, mm_inputs, backend="dandelion")
    from repro.core import measure
    red_bd, red_exec = measure(reg, "reduce", {
        "data": [Item(b"\x00" * 65536)]}, samples=5)
    mk_bd, mk_exec = measure(reg, "mk_req", {"x": [Item(0)]}, samples=5)
    io_s = 0.5e-3 + 2 * 65536 / 1.25e9

    rows = []
    workloads = {
        "compute_intensive": dict(
            comp=mm_comp, inputs=mm_inputs, cpu=mm_prof.execute_s, io=0.0,
            setup=mm_prof.setup_s, rps=0.75 * CORES / (mm_prof.setup_s + mm_prof.execute_s),
        ),
        "io_intensive": dict(
            comp=fc_comp, inputs={"x": [Item(0)]},
            cpu=mk_exec + red_exec, io=io_s, setup=mm_prof.setup_s,
            rps=0.75 * CORES * 3 / (mk_exec + red_exec + io_s),
        ),
    }

    for wname, w in workloads.items():
        # --- D-hybrid: single hybrid function, tpc sweep ---
        for tpc in (1, 3, 5):
            hname = f"hybrid_{wname}_{tpc}"
            reg.register_function(hname, lambda ins: {"out": [Item(1)]})
            hcomp = single_function_composition(reg, hname)
            prof = ColdStartProfile(
                setup_s=w["setup"] + w["io"],          # io blocks the thread
                execute_s=w["cpu"] * tpc,              # processor sharing
            )
            node = WorkerNode(
                reg, num_slots=CORES * tpc, comm_slots=1,
                profiles={hname: prof}, controller_enabled=False, seed=6,
            )
            r = _drive(node, hcomp, {"x": [Item(0)]}, w["rps"])
            rows.append({"workload": wname, "system": f"d_hybrid_tpc{tpc}",
                         **r})
        # --- Dandelion: real composition, split engines + PI ---
        node = WorkerNode(
            reg, services, num_slots=CORES, comm_slots=2,
            profiles={mm: mm_prof,
                      "reduce": ColdStartProfile(mm_prof.setup_s, red_exec),
                      "mk_req": ColdStartProfile(mm_prof.setup_s, mk_exec)},
            seed=6,
        )
        r = _drive(node, w["comp"], w["inputs"], w["rps"])
        rows.append({"workload": wname, "system": "dandelion_split_pi", **r})
    return rows


def main():
    emit("fig7_split_vs_hybrid", run())


if __name__ == "__main__":
    main()
