"""Figure 11 (extension): elastic scale-out under ON/OFF bursts.

Azure-like ON/OFF bursty workload (low-duty burst modulation over the
seeded trace generator) against identical worker-node hardware, two
control planes:

  * **static**: a peak-provisioned fixed-size cluster (all ``MAX_NODES``
    nodes up for the whole run, least-outstanding routing) - the
    capacity a fleet must hold to survive its worst burst;
  * **elastic**: the Dirigent-style control plane - locality-aware
    routing (code-cache affinity + p2c spillover) and node autoscaling
    (boot-delay scale-up on queue pressure, keep-alive scale-down with
    drain-before-remove).

Nodes pay a runtime/OS base footprint while up (NODE_BASE_BYTES), so
committed memory follows the node count: the elastic plane should commit
well below the static peak-provisioned average while keeping p99 within
2x (requests that land during a node boot queue briefly).

Reports per-platform p50/p99 latency, average/peak committed memory and
node counts, a summary ratio row, and the elastic node-count timeline.
All in virtual time; ``--quick`` (or FIG11_QUICK=1) shrinks the window
for CI smoke runs.
"""
from __future__ import annotations

import os
import sys

from repro import sdk
from repro.core import ColdStartProfile, ControlPlaneConfig, Item
from repro.core.sim import merged_peak
from repro.core.trace import generate_events, generate_functions
from benchmarks.common import emit, track

MAX_NODES = 6
NODE_SLOTS = 8
NODE_CACHE_ENTRIES = 12              # < N_FUNCTIONS: locality matters
NODE_BASE_BYTES = 256 << 20          # runtime/OS/code-cache arena per node
NODE_BOOT = ColdStartProfile(setup_s=0.75, execute_s=0.0, jitter_sigma=0.1)
N_FUNCTIONS = 30
TOTAL_RATE_HZ = 70.0
DANDELION_SETUP_S = 0.3e-3


def _duration() -> float:
    quick = os.environ.get("FIG11_QUICK") == "1" or "--quick" in sys.argv
    return 40.0 if quick else 240.0


def _workload(duration_s: float):
    fns = generate_functions(
        N_FUNCTIONS, seed=0, total_rate_hz=TOTAL_RATE_HZ,
        burst_period_range=(30.0, 90.0), burst_duty_range=(0.15, 0.4),
        exec_median_s=0.060, stagger_bursts=True,
    )
    events = generate_events(fns, duration_s, seed=1)
    return fns, events


def _deploy(platform: sdk.Platform, fns):
    """Declare + deploy one single-function app per trace function."""
    comps = {}
    for f in fns:
        spec = sdk.declare(
            f.name, lambda ins: {"out": [Item(1)]},
            inputs=("x",), outputs=("out",),
            context_bytes=f.context_bytes,
            profile=ColdStartProfile(
                DANDELION_SETUP_S, f.exec_median_s, jitter_sigma=f.exec_sigma,
            ),
        )
        comps[f.name] = platform.deploy(sdk.single_function_app(spec))
    return comps


def _row(platform, events, latency, avg_mb, peak_mb, nodes_avg, nodes_peak):
    s = latency.summary()
    return {
        "platform": platform,
        "events": events,
        "p50_ms": s["p50_ms"],
        "p99_ms": s["p99_ms"],
        "avg_committed_mb": avg_mb,
        "peak_committed_mb": peak_mb,
        "nodes_avg": nodes_avg,
        "nodes_peak": nodes_peak,
    }


def run():
    duration_s = _duration()
    fns, events = _workload(duration_s)
    rows = []

    # ------------------- static peak-provisioned cluster ------------------
    static = sdk.Platform(pool=[
        sdk.NodeSpec(num_slots=NODE_SLOTS,
                     code_cache_entries=NODE_CACHE_ENTRIES,
                     base_bytes=NODE_BASE_BYTES, seed=10 + i, name=f"sn{i}")
        for i in range(MAX_NODES)
    ])
    comps = _deploy(static, fns)
    with track("fig11/static", len(events)):
        static.submit_stream(
            (e.t, comps[e.fn], {"x": [Item(0)]}) for e in events)
        static.run(until=duration_s)
        static.run()  # drain stragglers past the window
    nodes = static.nodes
    static_avg_mb = (
        MAX_NODES * NODE_BASE_BYTES
        + sum(n.tracker.timeline.average(duration_s) for n in nodes)
    ) / 1024**2
    static_peak_mb = (
        merged_peak([n.tracker.timeline for n in nodes])
        + MAX_NODES * NODE_BASE_BYTES
    ) / 1024**2
    rows.append(_row("static_peak", len(events), static.latency,
                     static_avg_mb, static_peak_mb, MAX_NODES, MAX_NODES))

    # --------------------- elastic control plane --------------------------
    cfg = ControlPlaneConfig(
        min_nodes=1, max_nodes=MAX_NODES,
        target_outstanding_per_node=1.5 * NODE_SLOTS,
        # sustained queueing only: transient waits below one ~60ms service
        # time must not boot nodes the watermark will immediately reap
        max_queue_delay_s=100e-3,
        keepalive_s=20.0, tick_interval_s=0.25,
        node_boot=NODE_BOOT, node_base_bytes=NODE_BASE_BYTES,
    )
    elastic = sdk.Platform(elastic=sdk.Elastic(
        config=cfg, seed=2,
        node=sdk.NodeSpec(num_slots=NODE_SLOTS,
                          code_cache_entries=NODE_CACHE_ENTRIES,
                          base_bytes=NODE_BASE_BYTES, seed=20),
    ))
    comps = _deploy(elastic, fns)
    with track("fig11/elastic", len(events)):
        elastic.submit_stream(
            (e.t, comps[e.fn], {"x": [Item(0)]}) for e in events)
        elastic.run(until=duration_s)
        elastic.run()
    cp = elastic.control_plane
    summ = cp.summary(duration_s)
    rows.append(_row("elastic", len(events), elastic.latency,
                     summ["committed_avg_mb"], summ["committed_peak_mb"],
                     summ["nodes_avg"], summ["nodes_peak"]))

    # ------------------------------ summary -------------------------------
    rows.append({
        "platform": "summary",
        "events": len(events),
        "p50_ms": rows[1]["p50_ms"] / max(rows[0]["p50_ms"], 1e-9),
        "p99_ms": rows[1]["p99_ms"] / max(rows[0]["p99_ms"], 1e-9),
        "avg_committed_mb": rows[1]["avg_committed_mb"] / rows[0]["avg_committed_mb"],
        "peak_committed_mb": rows[1]["peak_committed_mb"] / rows[0]["peak_committed_mb"],
        "nodes_avg": rows[1]["nodes_avg"] / MAX_NODES,
        "nodes_peak": rows[1]["nodes_peak"] / MAX_NODES,
    })

    # routing/scaling detail + node-count timeline (elastic)
    print(f"# routing: {cp.stats.summary()}")
    tl = [f"{t:.1f}:{int(n)}" for t, n in cp.node_count_timeline.points]
    print(f"# node_count_timeline: {' '.join(tl)}")
    return rows


def main():
    emit("fig11", run())


if __name__ == "__main__":
    main()
