"""Figure 5: sandbox-creation tail latency vs offered throughput, 0% hot.

1x1 int64 matmul; open-loop Poisson arrivals swept over RPS. Every request
cold-starts (hot ratio 0). Compares the Dandelion backend against the two
AOT-restore backends standing in for Firecracker-with-snapshots and full
MicroVM boot (profiles measured from the real code paths, see Table 1).
"""
from __future__ import annotations

import numpy as np

from repro.core import FunctionRegistry, WorkerNode
from benchmarks.common import (
    calibrate,
    emit,
    matmul_inputs,
    register_matmul,
    single_function_composition,
)

CORES = 16
DURATION = 10.0


def run():
    reg = FunctionRegistry()
    name = register_matmul(reg, 1)
    inputs = matmul_inputs(1)
    comp = single_function_composition(reg, name)

    profiles = {
        "dandelion": calibrate(reg, name, inputs, backend="dandelion"),
        "snapshot": calibrate(reg, name, inputs, backend="snapshot"),
        "microvm": calibrate(reg, name, inputs, backend="microvm"),
    }
    rows = []
    for backend, prof in profiles.items():
        # service rate per core ~ 1/(setup+exec); sweep into saturation
        mu = 1.0 / (prof.setup_s + prof.execute_s)
        capacity = mu * CORES
        for frac in (0.1, 0.3, 0.5, 0.7, 0.85, 0.95):
            rps = capacity * frac
            # bound the event count: steady-state percentiles converge long
            # before 30k samples even at millions of offered RPS
            duration = min(DURATION, 30_000 / rps)
            node = WorkerNode(
                reg, num_slots=CORES, comm_slots=1,
                profiles={name: prof}, seed=2,
            )
            rng = np.random.default_rng(3)
            t = 0.0
            n = 0
            while t < duration:
                t += float(rng.exponential(1.0 / rps))
                node.invoke_at(t, comp, {"x": list(inputs["x"])})
                n += 1
            node.run()
            s = node.latency.summary()
            rows.append({
                "backend": backend,
                "offered_rps": round(rps),
                "capacity_frac": frac,
                "p50_ms": s["p50_ms"],
                "p95_ms": s["p95_ms"],
                "p99_ms": s["p99_ms"],
                "goodput_rps": s["n"] / duration,
            })
    return rows


def main():
    emit("fig5_throughput", run())


if __name__ == "__main__":
    main()
