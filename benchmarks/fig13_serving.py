"""Figure 13 (extension): LM serving as an elastic composition workload.

Each serving request is a composition DAG (tokenize -> prefill -> N
decode steps -> detokenize, ``repro.apps.inference_service``) scheduled
by the ordinary dispatcher over identical 2-node hardware; the KV cache
rides between vertices as real-sized items; model-weight cold starts are
priced from the HLO cost models (param bytes / disk bandwidth + compile
time, ``launch.hlo_analysis.weight_coldstart_estimate``). Azure-trace-
shaped ON/OFF bursty arrivals, three weight-residency policies:

  * **keepwarm** — weights pinned on every node for the whole run (the
    dedicated inference server): no cold starts, peak-provisioned
    memory; continuous batching on.
  * **percold**  — per-request cold start with NO keep-alive: weights
    leave the node the instant no request holds them, so every arrival
    into an idle gap repays load+compile; batching off (``max_batch=1``
    serializes decode steps on the replica). The naive serverless-LM
    baseline.
  * **elastic**  — the Dandelion story: per-request sandboxes, weights
    kept by a short keep-alive while traffic flows and dropped in the
    OFF valleys, decode steps coalesced by the platform's batching
    engine (``core.workloads.BatchStepModel`` roofline).

Reported per policy: p50/p99 time-to-first-token (arrival -> prefill
complete), p50/p99 end-to-end latency, generated tokens per virtual
second, average/peak committed memory, and the weight cold-touch rate;
plus an elastic/keepwarm ratio row (the acceptance gate: p99 TTFT within
2x of keepwarm at >= 40% less average committed memory). A JSON summary
lands in ``results/bench/BENCH_serving.json``.

All in virtual time, seeded end to end: data rows and the JSON are
byte-identical across runs (`# perf` lines excepted).

Knobs (environment variables):

  FIG13_QUICK       1 shrinks the window to 60 s for CI smoke
  FIG13_DURATION_S  arrival window, default 240 (virtual seconds)
  FIG13_MIN_TPS     CI gate: exit non-zero unless the elastic policy
                    sustains this many generated tokens per virtual sec
  FIG13_MIN_EPS     CI gate: exit non-zero unless the elastic segment
                    sustains this many vertex-task events per wall-clock
                    second (simulator throughput, same unit as fig10)
  FIG13_REAL_EXEC   1 drops the calibrated profiles so every vertex runs
                    its real registered payload under measured wall-clock
                    durations instead of priced models. Dataflow (token
                    streams, output text) is byte-identical to the
                    modeled default (tests/test_inference_service.py);
                    timings become machine-dependent, so the CSV identity
                    contract and the gates apply only to the default.
  FIG13_TELEMETRY   live-metrics stream destination: a path, or ``-``
                    for stderr (default off). The measurement window
                    runs in FIG13_TELEMETRY_INTERVAL_S chunks (default
                    5 virtual seconds) and each checkpoint publishes an
                    SSE frame (completed, p50/p99 TTFT, tokens,
                    committed MB). Checkpoints are driven from outside
                    the event loop, so the data rows stay byte-identical
                    with telemetry on or off.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple

import numpy as np

from repro import sdk
from repro.apps.inference_service import (
    LMSpec,
    build_request_composition,
    register_inference_service,
)
from repro.core import FunctionRegistry, Item, LatencyStats
from repro.core.sim import merged_peak
from repro.core.tracing import LiveTelemetry
from benchmarks.common import PERF, SIMPERF_EXTRA, emit, track

N_NODES = 2
NODE_SLOTS = 8                   # CPU slots (frontend + prefill lanes)
MAX_BATCH = 16                   # batching engine coalescing width
KEEPALIVE_S = 6.0                # elastic weight keep-alive
BURST_PERIOD_S = 60.0
BURST_DUTY = 0.35                # ON fraction of each period
RATE_HZ = 20.0                   # request rate during ON windows
PROMPT_LEN_RANGE = (32, 128)
DECODE_RANGE = (8, 32)
SPEC = LMSpec()

POLICIES = ("keepwarm", "percold", "elastic")

# request-shape composition cache, shared across the three policies (and
# repeated runs): a Composition is pure structure — the dispatcher never
# mutates it, and every policy prices the same request DAGs — so the
# ~1.2k distinct (prompt_len, n_decode) shapes build once per process
# instead of once per policy.
_COMPS: Dict[Tuple[int, int], object] = {}

# Elastic-segment simulator throughput at the seed of this PR, in
# vertex-task events (the fig10 unit: one event = one completed
# function invocation; a request is tokenize + prefill + n_decode
# decodes + detokenize = n_decode + 3 tasks). Measured on this
# container at the default 240 s window: 37485 tasks / ~5.9 s.
BASELINE_ELASTIC_EPS = 6300.0


def _n_tasks(requests) -> int:
    """Vertex-task count of a request list — the ``track()`` event unit.

    fig10's events/sec counts single-function invocations; counting
    whole ~23-vertex serving requests here would understate this
    benchmark by that factor and make BENCH_simperf.json rows
    incomparable across segments, so fig13 reports the same unit."""
    return sum(d + 3 for _, _, _, d in requests)


def _duration() -> float:
    if os.environ.get("FIG13_QUICK") == "1" or "--quick" in sys.argv:
        return 60.0
    return float(os.environ.get("FIG13_DURATION_S", 240.0))


def _requests(duration_s: float, seed: int = 0):
    """ON/OFF-modulated Poisson arrivals of LM requests, by thinning (the
    repro.core.trace recipe): (t, prompt_bytes, prompt_len, n_decode)."""
    rng = np.random.default_rng(seed)
    n = int(RATE_HZ * duration_s * 1.5 + 50)
    ts = np.cumsum(rng.exponential(1.0 / RATE_HZ, size=n))
    keep = ((ts % BURST_PERIOD_S) / BURST_PERIOD_S < BURST_DUTY) & (ts < duration_s)
    lo, hi = PROMPT_LEN_RANGE
    plens = rng.integers(lo, hi + 1, size=n)
    dlo, dhi = DECODE_RANGE
    decs = rng.integers(dlo, dhi + 1, size=n)
    out = []
    for rid, (t, p, d) in enumerate(zip(ts[keep], plens[keep], decs[keep])):
        prompt = (f"req{rid:05d}:".encode() * (int(p) // 2))[: 4 * int(p)]
        out.append((float(t), prompt, int(p), int(d)))
    return out


def _run_policy(policy: str, requests, duration_s: float,
                tele: "LiveTelemetry" = None) -> Dict[str, float]:
    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC)
    # real-execution mode: no calibrated profiles -> the engines take the
    # measured path (repro.core.coldstart, perf_counter durations) and the
    # registered payloads actually run. Token streams are seeded from the
    # prompt digest alone, so outputs must match the modeled default
    # byte for byte.
    real_exec = os.environ.get("FIG13_REAL_EXEC") == "1"
    platform = sdk.Platform(
        registry=reg, profiles=None if real_exec else svc.profiles,
        pool=[sdk.NodeSpec(
            num_slots=NODE_SLOTS,
            batch_slots=1, batch_model=svc.batch_model,
            max_batch=1 if policy == "percold" else MAX_BATCH,
            # per-node weight residency: a fresh store per node built
            weight_store=lambda: svc.make_weight_store(
                keepalive_s=KEEPALIVE_S if policy == "elastic" else 0.0,
                pinned=policy == "keepwarm",
            ),
            seed=40 + i, name=f"sv{i}",
        ) for i in range(N_NODES)],
    )

    ttft = LatencyStats()
    tokens = 0

    def make_done(n_decode: int):
        def done(inv):
            nonlocal tokens
            if inv.failed:
                return
            tokens += n_decode + 1
            ttft.add(inv.vertex_runs["prefill"].done_t - inv.t_start)
        return done

    def arrivals():
        comps = _COMPS
        for t, prompt, p, d in requests:
            comp = comps.get((p, d))
            if comp is None:
                comp = comps[(p, d)] = build_request_composition(
                    SPEC, prompt_len=p, n_decode=d)
            yield t, comp, {"prompt": [Item(prompt)]}, make_done(d)

    if tele is not None:
        tele.stream = f"fig13/{policy}"

    def snapshot(t_k: float):
        tf = ttft.summary()
        tele.emit({
            "policy": policy, "t_virtual_s": t_k,
            "completed": int(tf["n"]),
            "p50_ttft_ms": tf["p50_ms"], "p99_ttft_ms": tf["p99_ms"],
            "tokens": tokens,
            "committed_mb": sum(
                n.tracker.committed for n in platform.nodes) / 1024**2,
        })

    with track(f"fig13/{policy}", _n_tasks(requests)):
        platform.submit_stream(arrivals())
        if tele is None:
            platform.run(until=duration_s)
        else:
            # chunked window: checkpoints live OUTSIDE the loop (daemon
            # events would consume sequence numbers and shift the event
            # order), so the run is byte-identical with telemetry on
            step = float(os.environ.get("FIG13_TELEMETRY_INTERVAL_S", 5.0))
            t_k = 0.0
            while t_k < duration_s:
                t_k = min(t_k + step, duration_s)
                platform.run(until=t_k)
                snapshot(t_k)
        nodes = platform.nodes
        avg_committed = sum(
            n.tracker.timeline.average(duration_s) for n in nodes
        )
        platform.run()   # drain stragglers past the window
        if tele is not None:
            snapshot(duration_s)     # post-drain totals

    e2e = platform.latency.summary()
    tf = ttft.summary()
    ws_summ = [n.weight_store.summary() for n in nodes]
    touches = sum(s["touches"] for s in ws_summ)
    colds = sum(s["cold_touches"] for s in ws_summ)
    return {
        "policy": policy,
        "requests": len(requests),
        "completed": int(tf["n"]),
        "p50_ttft_ms": tf["p50_ms"],
        "p99_ttft_ms": tf["p99_ms"],
        "p50_e2e_ms": e2e["p50_ms"],
        "p99_e2e_ms": e2e["p99_ms"],
        "tokens_per_s": tokens / duration_s,
        "avg_committed_mb": avg_committed / 1024**2,
        "peak_committed_mb": merged_peak(
            [n.tracker.timeline for n in nodes]) / 1024**2,
        "weight_cold_rate": colds / touches if touches else 0.0,
    }


def run() -> List[dict]:
    duration_s = _duration()
    requests = _requests(duration_s)
    tele = LiveTelemetry.from_env("FIG13_TELEMETRY")
    try:
        rows = [_run_policy(p, requests, duration_s, tele=tele)
                for p in POLICIES]
    finally:
        if tele is not None:
            tele.close()
    el = PERF["fig13/elastic"]
    SIMPERF_EXTRA["fig13/elastic"] = {
        "event_unit": "vertex_tasks",
        "baseline_events_per_sec": BASELINE_ELASTIC_EPS,
        "speedup_vs_baseline": el.events_per_sec / BASELINE_ELASTIC_EPS,
        "duration_s": duration_s,
        "requests": len(requests),
    }
    by = {r["policy"]: r for r in rows}
    kw, el = by["keepwarm"], by["elastic"]
    rows.append({
        "policy": "elastic_vs_keepwarm",
        "requests": len(requests),
        "completed": el["completed"],
        "p50_ttft_ms": el["p50_ttft_ms"] / max(kw["p50_ttft_ms"], 1e-9),
        "p99_ttft_ms": el["p99_ttft_ms"] / max(kw["p99_ttft_ms"], 1e-9),
        "p50_e2e_ms": el["p50_e2e_ms"] / max(kw["p50_e2e_ms"], 1e-9),
        "p99_e2e_ms": el["p99_e2e_ms"] / max(kw["p99_e2e_ms"], 1e-9),
        "tokens_per_s": el["tokens_per_s"] / max(kw["tokens_per_s"], 1e-9),
        "avg_committed_mb": el["avg_committed_mb"] / max(kw["avg_committed_mb"], 1e-9),
        "peak_committed_mb": el["peak_committed_mb"] / max(kw["peak_committed_mb"], 1e-9),
        "weight_cold_rate": el["weight_cold_rate"],
    })
    _LAST["rows"] = rows
    _LAST["duration_s"] = duration_s
    return rows


# last run() result, serialized to BENCH_serving.json by write_json
# (called from benchmarks.run and from this module's main)
_LAST: Dict[str, object] = {}


def write_json(outdir: str = "results/bench") -> str:
    rows = _LAST.get("rows")
    if not rows:
        raise RuntimeError("fig13: run() before write_json()")
    by = {r["policy"]: r for r in rows}
    ratio = by["elastic_vs_keepwarm"]
    payload = {
        "workload": {
            "model": SPEC.name,
            "param_bytes": SPEC.param_bytes,
            "kv_bytes_per_token": SPEC.kv_bytes_per_token,
            "duration_s": _LAST["duration_s"],
            "nodes": N_NODES,
            "max_batch": MAX_BATCH,
            "keepalive_s": KEEPALIVE_S,
            "burst_period_s": BURST_PERIOD_S,
            "burst_duty": BURST_DUTY,
            "rate_hz": RATE_HZ,
        },
        "policies": {r["policy"]: r for r in rows if r["policy"] in POLICIES},
        "elastic_vs_keepwarm": {
            "p99_ttft_ratio": ratio["p99_ttft_ms"],
            "avg_committed_ratio": ratio["avg_committed_mb"],
            "tokens_per_s_ratio": ratio["tokens_per_s"],
        },
    }
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def gate() -> None:
    """CI floors: FIG13_MIN_TPS generated tokens per *virtual* second
    (deterministic, so a conservative floor is robust on any runner) and
    FIG13_MIN_EPS vertex-task events per *wall-clock* second on the
    elastic segment (simulator throughput — machine-dependent, so CI
    floors sit well below the container's steady-state rate)."""
    min_tps = float(os.environ.get("FIG13_MIN_TPS", 0.0))
    if min_tps > 0:
        rows = _LAST.get("rows") or []
        el = next((r for r in rows if r["policy"] == "elastic"), None)
        if el is None or el["tokens_per_s"] < min_tps:
            got = el["tokens_per_s"] if el else 0.0
            raise SystemExit(
                f"fig13 tokens/sec gate: elastic sustains {got:.1f} tok/s "
                f"< required {min_tps:.1f}"
            )
    min_eps = float(os.environ.get("FIG13_MIN_EPS", 0.0))
    if min_eps > 0:
        seg = PERF.get("fig13/elastic")
        if seg is None or seg.events_per_sec < min_eps:
            got = seg.events_per_sec if seg else 0.0
            raise SystemExit(
                f"fig13 throughput gate: elastic sustains {got:.0f} "
                f"events/sec < required {min_eps:.0f}"
            )


def main():
    from benchmarks.common import write_simperf

    emit("fig13", run())
    path = write_json()
    print(f"# serving summary written to {path}")
    print(f"# simulator throughput written to {write_simperf()}")
    gate()


if __name__ == "__main__":
    main()
