"""Figure 13 (extension): LM serving as an elastic composition workload.

Each serving request is a composition DAG (tokenize -> prefill -> N
decode steps -> detokenize, ``repro.apps.inference_service``) scheduled
by the ordinary dispatcher over an identical FIG13_NODES-node fleet; the
KV cache rides between vertices as real-sized items; model-weight cold
starts are priced from the HLO cost models (param bytes / disk bandwidth
+ compile time, ``launch.hlo_analysis.weight_coldstart_estimate``).
Azure-trace-shaped ON/OFF bursty arrivals, three residency policies:

  * **keepwarm** — weights pinned on every node for the whole run (the
    dedicated inference server): no cold starts, and a peak-provisioned
    fleet — ``REPLICAS_PER_NODE`` batch replicas per node, each holding
    its KV/activation arena (``replica_bytes``) for the whole run;
    continuous batching on.
  * **percold**  — per-request cold start with NO keep-alive: weights
    leave the node the instant no request holds them, so every arrival
    into an idle gap repays load+compile; batching off (``max_batch=1``
    serializes decode steps on the replica). The naive serverless-LM
    baseline.
  * **elastic**  — the Dandelion story: per-request sandboxes, weights
    kept by a short keep-alive while traffic flows and dropped in the
    OFF valleys; batch replicas scaled 0..``REPLICAS_PER_NODE`` per node
    by a ``ReplicaAutoscaler`` (queue pressure up, drain-before-retire
    down), requests routed by the ``batch_aware`` marginal-latency
    estimator (``core.control_plane.BatchRouter``) instead of shortest
    queue; decode steps coalesced by the platform's batching engine
    (``core.workloads.BatchStepModel`` roofline).

A fourth **multiplex** segment (JSON-only, no CSV row) serves TWO models
(the default LMSpec plus ``hymba-1.5b`` priced straight from its
``repro.configs`` geometry via ``lm_spec_from_config``) on one smaller
pool whose per-node ``WeightStore`` capacity cannot hold both models at
once — weight residency is evicted LRU-idle under contention while both
models' decode steps coalesce (same-function steps only) on the shared
replicas.

Reported per policy: p50/p99 time-to-first-token (arrival -> prefill
complete), p50/p99 end-to-end latency, generated tokens per virtual
second, average/peak committed memory, and the weight cold-touch rate;
plus an elastic/keepwarm ratio row (the acceptance gate: p99 TTFT within
1.1x of keepwarm at <= 0.6x keepwarm average committed memory). A JSON
summary — including replica-autoscaler scale events/latencies and the
multiplex eviction stats — lands in ``results/bench/BENCH_serving.json``.

All in virtual time, seeded end to end: data rows and the JSON are
byte-identical across runs (`# perf` lines excepted).

Knobs (environment variables):

  FIG13_QUICK       1 shrinks the window to 60 s for CI smoke
  FIG13_DURATION_S  arrival window, default 240 (virtual seconds)
  FIG13_NODES       fleet width, default 16 (integer >= 2)
  FIG13_RATE_HZ     request rate during ON windows, default 200 (> 0)
  FIG13_PREFILL_CHUNK
                    tokens per prefill chunk (integer >= 1): declares
                    prefill batchable so it rides the BATCH engine in
                    ceil(prompt_len/chunk)-unit slices of the coalesced
                    step. Default off (whole-prompt CPU prefill).
  FIG13_MIN_TPS     CI gate: exit non-zero unless the elastic policy
                    sustains this many generated tokens per virtual sec
  FIG13_MIN_EPS     CI gate: exit non-zero unless the elastic segment
                    sustains this many vertex-task events per wall-clock
                    second (simulator throughput, same unit as fig10)
  FIG13_MAX_TTFT_RATIO
                    CI gate: elastic p99 TTFT must stay within this
                    factor of keepwarm (acceptance: 1.1)
  FIG13_MAX_MEM_RATIO
                    CI gate: elastic average committed memory must stay
                    under this fraction of keepwarm (acceptance: 0.6)
  FIG13_MAX_SCALEUP_S
                    CI gate: worst replica scale-up latency (decision ->
                    slot serving) must stay under this many virtual secs
  FIG13_REAL_EXEC   1 drops the calibrated profiles so every vertex runs
                    its real registered payload under measured wall-clock
                    durations instead of priced models. Dataflow (token
                    streams, output text) is byte-identical to the
                    modeled default (tests/test_inference_service.py);
                    timings become machine-dependent, so the CSV identity
                    contract and the gates apply only to the default.
  FIG13_TELEMETRY   live-metrics stream destination: a path, or ``-``
                    for stderr (default off). The measurement window
                    runs in FIG13_TELEMETRY_INTERVAL_S chunks (default
                    5 virtual seconds) and each checkpoint publishes an
                    SSE frame (completed, p50/p99 TTFT, tokens,
                    committed MB). Checkpoints are driven from outside
                    the event loop, so the data rows stay byte-identical
                    with telemetry on or off.
"""
from __future__ import annotations

import hashlib
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import sdk
from repro.apps.inference_service import (
    LMSpec,
    build_request_composition,
    lm_spec_from_config,
    register_inference_service,
)
from repro.configs import get_config
from repro.core import (
    BatchRouter,
    FunctionRegistry,
    Item,
    LatencyStats,
    ReplicaAutoscaler,
    ReplicaConfig,
    WeightStore,
)
from repro.core.sim import merged_peak
from repro.core.tracing import LiveTelemetry
from benchmarks.common import PERF, SIMPERF_EXTRA, emit, track


def _env_int(name: str, default: int, minimum: int) -> int:
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        v = int(raw)
    except ValueError:
        raise SystemExit(f"{name} must be an integer, got {raw!r}")
    if v < minimum:
        raise SystemExit(f"{name} must be >= {minimum}, got {v}")
    return v


def _env_float(name: str, default: float, minimum: float) -> float:
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        v = float(raw)
    except ValueError:
        raise SystemExit(f"{name} must be a number, got {raw!r}")
    if v <= minimum:
        raise SystemExit(f"{name} must be > {minimum}, got {v}")
    return v


N_NODES = _env_int("FIG13_NODES", 16, 2)
RATE_HZ = _env_float("FIG13_RATE_HZ", 200.0, 0.0)
_chunk_raw = os.environ.get("FIG13_PREFILL_CHUNK")
PREFILL_CHUNK: Optional[int] = (
    _env_int("FIG13_PREFILL_CHUNK", 0, 1) if _chunk_raw is not None else None
)

NODE_SLOTS = 8                   # CPU slots (frontend + prefill lanes)
MAX_BATCH = 16                   # batching engine coalescing width
KEEPALIVE_S = 6.0                # elastic weight keep-alive
REPLICAS_PER_NODE = 2            # batch replicas per node (cap/pin count)
REPLICA_KEEPALIVE_S = 3.0        # replica idle retirement clock
REPLICA_BOOT_S = 0.05            # replica activation latency
BURST_PERIOD_S = 60.0
BURST_DUTY = 0.35                # ON fraction of each period
PROMPT_LEN_RANGE = (32, 128)
DECODE_RANGE = (8, 32)
SPEC = LMSpec()
MULTIPLEX_ARCH = "hymba-1.5b"    # second model on the shared pool

POLICIES = ("keepwarm", "percold", "elastic")


def _replica_bytes(spec: LMSpec) -> int:
    """KV/activation arena one batch replica commits while it exists:
    a full coalescing width of representative-length sequences."""
    return MAX_BATCH * spec.seq_len_hint * spec.kv_bytes_per_token


def _replica_config() -> ReplicaConfig:
    return ReplicaConfig(
        min_replicas=0,
        max_per_node=REPLICAS_PER_NODE,
        keepalive_s=REPLICA_KEEPALIVE_S,
        boot_s=REPLICA_BOOT_S,
    )


# request-shape composition cache, shared across the policies (and
# repeated runs): a Composition is pure structure — the dispatcher never
# mutates it, and every policy prices the same request DAGs — so the
# distinct (model, prompt_len, n_decode) shapes build once per process
# instead of once per policy.
_COMPS: Dict[Tuple[str, int, int, Optional[int]], object] = {}


def _comp_for(spec: LMSpec, p: int, d: int):
    key = (spec.name, p, d, PREFILL_CHUNK)
    comp = _COMPS.get(key)
    if comp is None:
        comp = _COMPS[key] = build_request_composition(
            spec, prompt_len=p, n_decode=d, prefill_chunk=PREFILL_CHUNK)
    return comp


# Elastic-segment simulator throughput at the seed of this PR, in
# vertex-task events (the fig10 unit: one event = one completed
# function invocation; a request is tokenize + prefill + n_decode
# decodes + detokenize = n_decode + 3 tasks). Measured on this
# container at the default 16-node 200 Hz 240 s window.
BASELINE_ELASTIC_EPS = 9932.0


def _n_tasks(requests) -> int:
    """Vertex-task count of a request list — the ``track()`` event unit.

    fig10's events/sec counts single-function invocations; counting
    whole ~23-vertex serving requests here would understate this
    benchmark by that factor and make BENCH_simperf.json rows
    incomparable across segments, so fig13 reports the same unit."""
    return sum(r[3] + 3 for r in requests)


def _duration() -> float:
    if os.environ.get("FIG13_QUICK") == "1" or "--quick" in sys.argv:
        return 60.0
    return float(os.environ.get("FIG13_DURATION_S", 240.0))


def _requests(duration_s: float, seed: int = 0,
              rate_hz: Optional[float] = None):
    """ON/OFF-modulated Poisson arrivals of LM requests, by thinning (the
    repro.core.trace recipe): (t, prompt_bytes, prompt_len, n_decode)."""
    rate = RATE_HZ if rate_hz is None else rate_hz
    rng = np.random.default_rng(seed)
    n = int(rate * duration_s * 1.5 + 50)
    ts = np.cumsum(rng.exponential(1.0 / rate, size=n))
    keep = ((ts % BURST_PERIOD_S) / BURST_PERIOD_S < BURST_DUTY) & (ts < duration_s)
    lo, hi = PROMPT_LEN_RANGE
    plens = rng.integers(lo, hi + 1, size=n)
    dlo, dhi = DECODE_RANGE
    decs = rng.integers(dlo, dhi + 1, size=n)
    out = []
    for rid, (t, p, d) in enumerate(zip(ts[keep], plens[keep], decs[keep])):
        prompt = (f"req{rid:05d}:".encode() * (int(p) // 2))[: 4 * int(p)]
        out.append((float(t), prompt, int(p), int(d)))
    return out


def _run_policy(policy: str, requests, duration_s: float,
                tele: "LiveTelemetry" = None) -> Dict[str, float]:
    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC, prefill_chunk=PREFILL_CHUNK)
    # real-execution mode: no calibrated profiles -> the engines take the
    # measured path (repro.core.coldstart, perf_counter durations) and the
    # registered payloads actually run. Token streams are seeded from the
    # prompt digest alone, so outputs must match the modeled default
    # byte for byte.
    real_exec = os.environ.get("FIG13_REAL_EXEC") == "1"
    elastic = policy == "elastic"
    arena = _replica_bytes(SPEC)
    platform = sdk.Platform(
        registry=reg, profiles=None if real_exec else svc.profiles,
        pool=[sdk.NodeSpec(
            num_slots=NODE_SLOTS,
            # keepwarm: the full replica fleet pinned up for the run.
            # percold: one non-coalescing replica (max_batch=1).
            # elastic: zero replicas; batch_models marks the capability
            # so decode queues on the BATCH engine where the autoscaler
            # sees backlog and boots replicas.
            batch_slots=(0 if elastic
                         else REPLICAS_PER_NODE if policy == "keepwarm"
                         else 1),
            batch_model=svc.batch_model,
            batch_models=svc.batch_models if policy != "percold" else None,
            max_batch=1 if policy == "percold" else MAX_BATCH,
            replica_bytes=0 if policy == "percold" else arena,
            # per-node weight residency: a fresh store per node built
            weight_store=lambda: svc.make_weight_store(
                keepalive_s=KEEPALIVE_S if elastic else 0.0,
                pinned=policy == "keepwarm",
            ),
            seed=40 + i, name=f"sv{i}",
        ) for i in range(N_NODES)],
        route_policy="batch_aware" if elastic else "outstanding",
        batch_router=BatchRouter(
            spinup_s=REPLICA_BOOT_S, cold_s=svc.weight_cold.total_s,
        ) if elastic else None,
    )
    autoscaler = None
    if elastic:
        autoscaler = ReplicaAutoscaler(
            platform.loop, platform.nodes, config=_replica_config())
        autoscaler.start()

    ttft = LatencyStats()
    tokens = 0

    def make_done(n_decode: int):
        def done(inv):
            nonlocal tokens
            if inv.failed:
                return
            tokens += n_decode + 1
            ttft.add(inv.vertex_runs["prefill"].done_t - inv.t_start)
        return done

    def arrivals():
        for t, prompt, p, d in requests:
            yield t, _comp_for(SPEC, p, d), {"prompt": [Item(prompt)]}, \
                make_done(d)

    if tele is not None:
        tele.stream = f"fig13/{policy}"

    def snapshot(t_k: float):
        tf = ttft.summary()
        tele.emit({
            "policy": policy, "t_virtual_s": t_k,
            "completed": int(tf["n"]),
            "p50_ttft_ms": tf["p50_ms"], "p99_ttft_ms": tf["p99_ms"],
            "tokens": tokens,
            "committed_mb": sum(
                n.tracker.committed for n in platform.nodes) / 1024**2,
        })

    with track(f"fig13/{policy}", _n_tasks(requests)):
        platform.submit_stream(arrivals())
        if tele is None:
            platform.run(until=duration_s)
        else:
            # chunked window: checkpoints live OUTSIDE the loop (daemon
            # events would consume sequence numbers and shift the event
            # order), so the run is byte-identical with telemetry on
            step = float(os.environ.get("FIG13_TELEMETRY_INTERVAL_S", 5.0))
            t_k = 0.0
            while t_k < duration_s:
                t_k = min(t_k + step, duration_s)
                platform.run(until=t_k)
                snapshot(t_k)
        nodes = platform.nodes
        avg_committed = sum(
            n.tracker.timeline.average(duration_s) for n in nodes
        )
        platform.run()   # drain stragglers past the window
        if tele is not None:
            snapshot(duration_s)     # post-drain totals

    e2e = platform.latency.summary()
    tf = ttft.summary()
    ws_summ = [n.weight_store.summary() for n in nodes]
    touches = sum(s["touches"] for s in ws_summ)
    colds = sum(s["cold_touches"] for s in ws_summ)
    if autoscaler is not None:
        _LAST["autoscaler"] = autoscaler.summary()
    return {
        "policy": policy,
        "requests": len(requests),
        "completed": int(tf["n"]),
        "p50_ttft_ms": tf["p50_ms"],
        "p99_ttft_ms": tf["p99_ms"],
        "p50_e2e_ms": e2e["p50_ms"],
        "p99_e2e_ms": e2e["p99_ms"],
        "tokens_per_s": tokens / duration_s,
        "avg_committed_mb": avg_committed / 1024**2,
        "peak_committed_mb": merged_peak(
            [n.tracker.timeline for n in nodes]) / 1024**2,
        "weight_cold_rate": colds / touches if touches else 0.0,
    }


def _run_multiplex(duration_s: float) -> Dict[str, object]:
    """Two models, one elastic pool: per-node weight capacity holds only
    one model's weights at a time (1.25x the larger), so residency churns
    through deterministic LRU-idle eviction while both models' decode
    steps share the replica fleet (coalesced per function, routed by the
    batch-aware estimator)."""
    reg = FunctionRegistry()
    svc_a = register_inference_service(reg, SPEC, prefill_chunk=PREFILL_CHUNK)
    spec_b = lm_spec_from_config(get_config(MULTIPLEX_ARCH))
    svc_b = register_inference_service(reg, spec_b,
                                       prefill_chunk=PREFILL_CHUNK)
    capacity = int(1.25 * max(SPEC.param_bytes, spec_b.param_bytes))
    n_nodes = max(4, N_NODES // 4)
    rate_hz = RATE_HZ / 4.0
    batch_models = {**svc_a.batch_models, **svc_b.batch_models}
    arena = max(_replica_bytes(SPEC), _replica_bytes(spec_b))
    cold_s = max(svc_a.weight_cold.total_s, svc_b.weight_cold.total_s)
    real_exec = os.environ.get("FIG13_REAL_EXEC") == "1"

    def make_ws():
        ws = WeightStore(keepalive_s=KEEPALIVE_S, capacity_bytes=capacity)
        svc_a.register_weights(ws)
        svc_b.register_weights(ws)
        return ws

    platform = sdk.Platform(
        registry=reg,
        profiles=None if real_exec
        else {**svc_a.profiles, **svc_b.profiles},
        pool=[sdk.NodeSpec(
            num_slots=NODE_SLOTS,
            batch_slots=0,
            batch_models=batch_models,
            max_batch=MAX_BATCH,
            replica_bytes=arena,
            weight_store=make_ws,
            seed=70 + i, name=f"mx{i}",
        ) for i in range(n_nodes)],
        route_policy="batch_aware",
        batch_router=BatchRouter(spinup_s=REPLICA_BOOT_S, cold_s=cold_s),
    )
    autoscaler = ReplicaAutoscaler(
        platform.loop, platform.nodes, config=_replica_config())
    autoscaler.start()

    reqs = _requests(duration_s, seed=7, rate_hz=rate_hz)
    which = np.random.default_rng(11).integers(0, 2, size=len(reqs))
    specs = (SPEC, spec_b)
    ttft = {s.name: LatencyStats() for s in specs}
    tokens = 0
    completed = 0
    digest = hashlib.blake2b(digest_size=16)

    def make_done(rid: int, spec: LMSpec, n_decode: int):
        def done(inv):
            nonlocal tokens, completed
            if inv.failed:
                return
            completed += 1
            tokens += n_decode + 1
            tf = inv.vertex_runs["prefill"].done_t - inv.t_start
            ttft[spec.name].add(tf)
            digest.update(f"{rid}:{spec.name}:{tf:.9f}".encode())
        return done

    def arrivals():
        for rid, ((t, prompt, p, d), w) in enumerate(zip(reqs, which)):
            spec = specs[int(w)]
            yield t, _comp_for(spec, p, d), {"prompt": [Item(prompt)]}, \
                make_done(rid, spec, d)

    with track("fig13/multiplex", _n_tasks(reqs)):
        platform.submit_stream(arrivals())
        platform.run(until=duration_s)
        nodes = platform.nodes
        avg_committed = sum(
            n.tracker.timeline.average(duration_s) for n in nodes)
        platform.run()

    ws_summ = [n.weight_store.summary() for n in nodes]
    out = {
        "models": [s.name for s in specs],
        "nodes": n_nodes,
        "rate_hz": rate_hz,
        "weight_capacity_bytes": capacity,
        "requests": len(reqs),
        "completed": completed,
        "tokens_per_s": tokens / duration_s,
        "avg_committed_mb": avg_committed / 1024**2,
        "weight_evictions": sum(s["evictions"] for s in ws_summ),
        "weight_over_capacity": sum(s["over_capacity"] for s in ws_summ),
        "weight_cold_touches": sum(s["cold_touches"] for s in ws_summ),
        "result_digest": digest.hexdigest(),
    }
    for s in specs:
        tf = ttft[s.name].summary()
        out[f"p99_ttft_ms_{s.name}"] = tf["p99_ms"]
    out.update(autoscaler.summary())
    return out


def run() -> List[dict]:
    duration_s = _duration()
    requests = _requests(duration_s)
    tele = LiveTelemetry.from_env("FIG13_TELEMETRY")
    try:
        rows = [_run_policy(p, requests, duration_s, tele=tele)
                for p in POLICIES]
    finally:
        if tele is not None:
            tele.close()
    _LAST["multiplex"] = _run_multiplex(duration_s)
    el = PERF["fig13/elastic"]
    SIMPERF_EXTRA["fig13/elastic"] = {
        "event_unit": "vertex_tasks",
        "baseline_events_per_sec": BASELINE_ELASTIC_EPS,
        "speedup_vs_baseline": el.events_per_sec / BASELINE_ELASTIC_EPS,
        "duration_s": duration_s,
        "requests": len(requests),
    }
    by = {r["policy"]: r for r in rows}
    kw, el = by["keepwarm"], by["elastic"]
    rows.append({
        "policy": "elastic_vs_keepwarm",
        "requests": len(requests),
        "completed": el["completed"],
        "p50_ttft_ms": el["p50_ttft_ms"] / max(kw["p50_ttft_ms"], 1e-9),
        "p99_ttft_ms": el["p99_ttft_ms"] / max(kw["p99_ttft_ms"], 1e-9),
        "p50_e2e_ms": el["p50_e2e_ms"] / max(kw["p50_e2e_ms"], 1e-9),
        "p99_e2e_ms": el["p99_e2e_ms"] / max(kw["p99_e2e_ms"], 1e-9),
        "tokens_per_s": el["tokens_per_s"] / max(kw["tokens_per_s"], 1e-9),
        "avg_committed_mb": el["avg_committed_mb"] / max(kw["avg_committed_mb"], 1e-9),
        "peak_committed_mb": el["peak_committed_mb"] / max(kw["peak_committed_mb"], 1e-9),
        "weight_cold_rate": el["weight_cold_rate"],
    })
    _LAST["rows"] = rows
    _LAST["duration_s"] = duration_s
    return rows


# last run() result, serialized to BENCH_serving.json by write_json
# (called from benchmarks.run and from this module's main)
_LAST: Dict[str, object] = {}


def write_json(outdir: str = "results/bench") -> str:
    rows = _LAST.get("rows")
    if not rows:
        raise RuntimeError("fig13: run() before write_json()")
    by = {r["policy"]: r for r in rows}
    ratio = by["elastic_vs_keepwarm"]
    payload = {
        "workload": {
            "model": SPEC.name,
            "param_bytes": SPEC.param_bytes,
            "kv_bytes_per_token": SPEC.kv_bytes_per_token,
            "duration_s": _LAST["duration_s"],
            "nodes": N_NODES,
            "max_batch": MAX_BATCH,
            "keepalive_s": KEEPALIVE_S,
            "replicas_per_node": REPLICAS_PER_NODE,
            "replica_keepalive_s": REPLICA_KEEPALIVE_S,
            "replica_boot_s": REPLICA_BOOT_S,
            "replica_bytes": _replica_bytes(SPEC),
            "prefill_chunk": PREFILL_CHUNK,
            "burst_period_s": BURST_PERIOD_S,
            "burst_duty": BURST_DUTY,
            "rate_hz": RATE_HZ,
        },
        "policies": {r["policy"]: r for r in rows if r["policy"] in POLICIES},
        "elastic_vs_keepwarm": {
            "p99_ttft_ratio": ratio["p99_ttft_ms"],
            "avg_committed_ratio": ratio["avg_committed_mb"],
            "tokens_per_s_ratio": ratio["tokens_per_s"],
        },
        "elastic_autoscaler": _LAST.get("autoscaler", {}),
        "multiplex": _LAST.get("multiplex", {}),
    }
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def gate() -> None:
    """CI floors/ceilings. FIG13_MIN_TPS (generated tokens per *virtual*
    second), FIG13_MAX_TTFT_RATIO / FIG13_MAX_MEM_RATIO (elastic vs
    keepwarm), and FIG13_MAX_SCALEUP_S (worst replica scale-up latency)
    are deterministic, so conservative bounds are robust on any runner.
    FIG13_MIN_EPS (vertex-task events per *wall-clock* second on the
    elastic segment) is machine-dependent, so CI floors sit well below
    the container's steady-state rate."""
    rows = _LAST.get("rows") or []
    by = {r["policy"]: r for r in rows}
    min_tps = float(os.environ.get("FIG13_MIN_TPS", 0.0))
    if min_tps > 0:
        el = by.get("elastic")
        if el is None or el["tokens_per_s"] < min_tps:
            got = el["tokens_per_s"] if el else 0.0
            raise SystemExit(
                f"fig13 tokens/sec gate: elastic sustains {got:.1f} tok/s "
                f"< required {min_tps:.1f}"
            )
    max_ttft = float(os.environ.get("FIG13_MAX_TTFT_RATIO", 0.0))
    if max_ttft > 0:
        r = by.get("elastic_vs_keepwarm")
        if r is None or r["p99_ttft_ms"] > max_ttft:
            got = r["p99_ttft_ms"] if r else float("inf")
            raise SystemExit(
                f"fig13 TTFT gate: elastic p99 TTFT is {got:.3f}x keepwarm "
                f"> allowed {max_ttft:.3f}x"
            )
    max_mem = float(os.environ.get("FIG13_MAX_MEM_RATIO", 0.0))
    if max_mem > 0:
        r = by.get("elastic_vs_keepwarm")
        if r is None or r["avg_committed_mb"] > max_mem:
            got = r["avg_committed_mb"] if r else float("inf")
            raise SystemExit(
                f"fig13 memory gate: elastic commits {got:.3f}x keepwarm "
                f"average > allowed {max_mem:.3f}x"
            )
    max_scaleup = float(os.environ.get("FIG13_MAX_SCALEUP_S", 0.0))
    if max_scaleup > 0:
        a = _LAST.get("autoscaler") or {}
        worst = a.get("scaleup_latency_max_s", float("inf"))
        if worst > max_scaleup:
            raise SystemExit(
                f"fig13 scale-up gate: worst replica scale-up took "
                f"{worst:.3f}s > allowed {max_scaleup:.3f}s"
            )
    min_eps = float(os.environ.get("FIG13_MIN_EPS", 0.0))
    if min_eps > 0:
        seg = PERF.get("fig13/elastic")
        if seg is None or seg.events_per_sec < min_eps:
            got = seg.events_per_sec if seg else 0.0
            raise SystemExit(
                f"fig13 throughput gate: elastic sustains {got:.0f} "
                f"events/sec < required {min_eps:.0f}"
            )


def main():
    from benchmarks.common import write_simperf

    emit("fig13", run())
    path = write_json()
    print(f"# serving summary written to {path}")
    print(f"# simulator throughput written to {write_simperf()}")
    gate()


if __name__ == "__main__":
    main()
