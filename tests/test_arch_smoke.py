"""Per-architecture smoke tests: reduced same-family config, one forward /
train step / prefill+decode on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.config.shapes import SHAPES, applicability
from repro.models.model import build

RNG = jax.random.PRNGKey(0)


def _extras(cfg, b, s=8):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(RNG, (b, 16, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"patches": jax.random.normal(RNG, (b, 8, cfg.d_model), jnp.bfloat16)}
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)  # validates internally
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    api = build(cfg)
    params = api.init_params(RNG)
    B, S = 2, 32
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens, **_extras(cfg, B)}
    loss, grads = jax.jit(jax.value_and_grad(lambda p: api.train_loss(p, batch)))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke(arch)
    api = build(cfg)
    params = api.init_params(RNG)
    B, S = 2, 32
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    plens = jnp.array([S, S // 2], jnp.int32)
    kw = _extras(cfg, B)
    logits, cache = jax.jit(lambda p, t, pl: api.prefill(p, t, pl, **kw))(params, tokens, plens)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(api.decode_step)(params, cache, nxt)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache["pos"][0]) == S + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long_context_applicability(arch):
    """long_500k must be skipped exactly for pure full-attention archs."""
    cfg = get_config(arch)
    skip = applicability(cfg, SHAPES["long_500k"])
    if cfg.family in ("ssm", "hybrid"):
        assert skip is None, f"{arch} is sub-quadratic; long_500k must run"
    else:
        assert skip is not None, f"{arch} is full-attention; long_500k must skip"


@pytest.mark.parametrize("arch", ["deepseek-67b", "qwen3-moe-235b-a22b", "mamba2-130m"])
def test_param_count_matches_template(arch):
    """Analytic param formula must agree with the template tree."""
    cfg = get_config(arch)
    api = build(cfg)
    analytic = cfg.num_params()
    template = api.param_count()
    rel = abs(analytic - template) / template
    assert rel < 0.01, f"{arch}: analytic {analytic:.3e} vs template {template:.3e}"
