"""Sharding rules: divisibility fallback, axis dedup, batch specs.

Uses a duck-typed mesh stub so the single-CPU test process can exercise
the 16x16 production-mesh logic without 256 devices.
"""
from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.config.parallel import ParallelPlan
from repro.sharding.rules import batch_spec, default_rules, spec_for_axes


class _MeshStub(SimpleNamespace):
    pass


def mesh_stub(**axes):
    return _MeshStub(axis_names=tuple(axes), shape=dict(axes))


SINGLE = mesh_stub(data=16, model=16)
MULTI = mesh_stub(pod=2, data=16, model=16)


def _plan(mesh, kind="train", zero3=True):
    return default_rules(
        ParallelPlan(zero3=zero3).restrict_to(mesh.axis_names), kind
    )


def test_fsdp_2d_sharding_train():
    rules = _plan(MULTI)
    # deepseek wq: (8192, 8192) embed x heads
    spec = spec_for_axes((8192, 8192), ("embed", "heads"), rules, MULTI)
    assert spec == P(("pod", "data"), "model")


def test_divisibility_fallback_replicates():
    rules = _plan(SINGLE)
    # 25 heads (hymba) cannot shard over 16: falls back to replication
    spec = spec_for_axes((1600, 25), ("embed", "heads"), rules, SINGLE)
    assert spec[1] is None if len(spec) > 1 else True


def test_axis_never_used_twice():
    rules = _plan(SINGLE, kind="serve")
    # MoE expert weights (E, D, F): experts take model; ffn must fall
    # through to data, never reusing model
    spec = spec_for_axes((128, 4096, 1536), ("experts", "embed", "ffn"), rules, SINGLE)
    flat = []
    for e in spec:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat))
    assert spec[0] == "model"
    assert spec[2] == "data"  # serve-mode fallback keeps 235B under HBM


def test_kv_cache_seq_takes_model_when_heads_dont_divide():
    rules = _plan(SINGLE, kind="serve")
    # [L, B, S, K, dh] with K=8 (not divisible by 16): S gets model
    spec = spec_for_axes(
        (95, 128, 32768, 8, 128),
        ("layers", "batch", "cache_seq", "kv_heads", None),
        rules, SINGLE,
    )
    assert spec[1] == "data"
    assert spec[2] == "model"
    assert len(spec) < 4 or spec[3] is None


def test_pod_axis_dropped_on_single_pod():
    plan = ParallelPlan().restrict_to(("data", "model"))
    assert plan.data_axes == ("data",)
    rules = default_rules(plan, "train")
    spec = spec_for_axes((1024, 1024), ("embed", "ffn"), rules, SINGLE)
    assert spec == P("data", "model")


@pytest.mark.parametrize("batch,expected", [
    (256, P(("pod", "data"))),
    (32, P(("pod", "data"))),
    (2, P("pod")),       # sheds the 16-way axis, keeps pod
    (1, P()),            # long_500k: replicate
])
def test_batch_spec_sheds_axes(batch, expected):
    plan = ParallelPlan().restrict_to(("pod", "data", "model"))
    assert batch_spec(plan, MULTI, batch) == expected
