"""Observational-identity proofs behind the simulator fast paths.

Several hot-path rewrites claim byte-identity with the code they
replaced, each citing this module:

  * vectorized jitter draws (``engines._serve_batch``): one
    ``Generator.lognormal(size=2n)`` call is draw-for-draw identical to
    ``2n`` sequential scalar draws AND leaves the generator in the same
    state;
  * vectorized batch pricing: ``BatchStepModel.step_s_batch`` matches
    ``step_s`` element-for-element;
  * collapsed cold-start commits (``MemoryContext.bulk_load`` /
    ``write_sets_bulk``): one tracker record for same-instant all-
    positive commits is observation-identical to the per-write path —
    same page totals, same ``average()``/``peak()``/``merged_peak``;
  * the payload memo's adaptive fingerprint bypass is a deterministic
    function of invocation history and never changes dataflow;
  * ``StreamingPercentile`` (P^2) tracks ``np.percentile`` on the
    latency distributions the benchmarks draw.
"""
import numpy as np

from repro.core import BatchStepModel, EventLoop, FunctionRegistry, Item
from repro.core.context import PAGE, MemoryContext, MemoryTracker
from repro.core.registry import PayloadMemo
from repro.core.sim import merged_peak
from repro.core.tracing import StreamingPercentile


# ------------------------------------------------------ vectorized draws
def test_vectorized_lognormal_bit_identical_to_scalar_draws():
    for sigma in (0.05, 0.3, 1.2):
        a = np.random.default_rng(1234)
        b = np.random.default_rng(1234)
        vec = a.lognormal(0.0, sigma, size=24)
        seq = [b.lognormal(0.0, sigma) for _ in range(24)]
        assert vec.tolist() == seq                    # bit-identical draws
        # ...and identical generator state afterwards: any draw that
        # follows the vectorized block matches the scalar timeline too
        assert a.bit_generator.state == b.bit_generator.state
        assert a.lognormal(0.0, sigma) == b.lognormal(0.0, sigma)


# ---------------------------------------------------- vectorized pricing
def test_step_s_batch_matches_elementwise():
    m = BatchStepModel(
        flops_per_seq=2.6e9, fixed_bytes=2.6e9, bytes_per_seq=30e6,
        peak_flops=197e12, hbm_bw=819e9, overhead_s=100e-6,
    )
    ns = list(range(0, 65))
    vec = m.step_s_batch(ns)
    assert vec.tolist() == [m.step_s(n) for n in ns]


# ------------------------------------------------- collapsed commit records
def _commit_timeline(bulk: bool) -> MemoryTracker:
    """Two modeled cold starts and their frees on one virtual timeline,
    committed either through the collapsed bulk calls or the per-write
    reference path."""
    loop = EventLoop()
    tracker = MemoryTracker(loop)
    ins1 = {"a": [Item(b"x" * 5000)], "b": [Item(b"y" * 123), Item(b"q" * 7)]}
    out1 = {"out": [Item(b"r" * 9001)]}
    ins2 = {"c": [Item(b"z" * (3 * PAGE))]}
    ctxs = []

    def start(code_n, ins, outs):
        ctx = MemoryContext(capacity=1 << 20, tracker=tracker)
        if bulk:
            ctx.bulk_load(code_n, ins)
            ctx.write_sets_bulk(outs, into="outputs")
        else:
            ctx.load_code_size(code_n)
            for name, items in ins.items():
                ctx.write_set(name, items)
            for name, items in outs.items():
                ctx.write_set(name, items, into="outputs")
        ctxs.append(ctx)

    loop.at(0.5, lambda: start(3000, ins1, out1))
    loop.at(1.25, lambda: start(777, ins2, {}))
    loop.at(2.0, lambda: ctxs[0].free())
    loop.at(3.5, lambda: ctxs[1].free())
    loop.run()
    return tracker


def test_bulk_commits_observationally_identical():
    bulk, ref = _commit_timeline(True), _commit_timeline(False)
    assert bulk.committed == ref.committed == 0       # freed exactly once
    assert bulk.timeline.peak() == ref.timeline.peak()
    assert merged_peak([bulk.timeline]) == merged_peak([ref.timeline])
    for t_end in (0.6, 1.3, 2.5, 3.5, 5.0):
        assert bulk.timeline.average(t_end) == ref.timeline.average(t_end)
    # page accounting still rounds per write, then sums: the bulk path
    # must not merge byte counts before rounding
    ctx_b = MemoryContext(capacity=1 << 20)
    ctx_b.bulk_load(1, {"a": [Item(b"x")], "b": [Item(b"y")]})
    ctx_r = MemoryContext(capacity=1 << 20)
    ctx_r.load_code_size(1)
    ctx_r.write_set("a", [Item(b"x")])
    ctx_r.write_set("b", [Item(b"y")])
    assert ctx_b.committed_pages == ctx_r.committed_pages == 3


# ------------------------------------------------- adaptive memo bypass
def test_payload_memo_adaptive_bypass_deterministic():
    def _counters():
        reg = FunctionRegistry()
        calls = []
        reg.register_function(
            "uniq", lambda ins: {"out": [Item(ins["x"][0].data * 2)]},
            context_bytes=1 << 20,
        )
        cf = reg.get("uniq")
        memo = PayloadMemo(bypass_after=4)
        outs = []
        for i in range(10):                  # inputs never repeat
            out = memo.run(cf, {"x": [Item(bytes([i]))]})
            outs.append(out["out"][0].data)
        return memo.hits, memo.misses, memo.skips, outs

    a, b = _counters(), _counters()
    assert a == b                            # pure function of history
    hits, misses, skips, outs = a
    assert hits == 0
    assert misses == 4                       # fingerprinted until the bound
    assert skips == 6                        # then bypassed permanently
    assert outs == [bytes([i]) * 2 for i in range(10)]   # dataflow unchanged

    # one hit before the bound disarms the bypass for good
    reg = FunctionRegistry()
    reg.register_function(
        "rep", lambda ins: {"out": [Item(b"v")]}, context_bytes=1 << 20)
    cf = reg.get("rep")
    memo = PayloadMemo(bypass_after=4)
    memo.run(cf, {"x": [Item(b"same")]})
    memo.run(cf, {"x": [Item(b"same")]})     # hit
    for i in range(20):
        memo.run(cf, {"x": [Item(b"n%d" % i)]})
    assert memo.skips == 0
    assert memo.hits == 1 and memo.misses == 21


# ------------------------------------------------- streaming percentiles
def test_streaming_percentile_tracks_numpy():
    rng = np.random.default_rng(7)
    samples = rng.lognormal(-3.0, 0.6, size=4000)
    p50 = StreamingPercentile(50)
    p99 = StreamingPercentile(99)
    for x in samples:
        p50.add(float(x))
        p99.add(float(x))
    ref50 = float(np.percentile(samples, 50))
    ref99 = float(np.percentile(samples, 99))
    assert abs(p50.value - ref50) / ref50 < 0.05
    assert abs(p99.value - ref99) / ref99 < 0.15
    # exact while the marker window is still filling
    small = StreamingPercentile(50)
    for x in (5.0, 1.0, 3.0):
        small.add(x)
    assert small.value == 3.0
    assert StreamingPercentile(99).value == 0.0


# ------------------------------------------- batch-aware routing identity
def _serving_pool_run(route_policy):
    """One-node serving pool driven through the SDK platform under the
    given routing policy; returns completion timeline + memory points."""
    from repro import sdk
    from repro.apps.inference_service import (
        LMSpec, build_request_composition, register_inference_service)
    from repro.core import BatchRouter, FunctionRegistry, Item

    spec = LMSpec()
    reg = FunctionRegistry()
    svc = register_inference_service(reg, spec)
    platform = sdk.Platform(
        registry=reg, profiles=svc.profiles,
        pool=[sdk.NodeSpec(
            num_slots=4, batch_slots=1, batch_model=svc.batch_model,
            max_batch=8, weight_store=svc.make_weight_store(keepalive_s=0.5),
            seed=21, name="solo",
        )],
        route_policy=route_policy,
        batch_router=BatchRouter(spinup_s=0.02, cold_s=svc.weight_cold.total_s)
        if route_policy == "batch_aware" else None,
    )
    done = {}
    rng = np.random.default_rng(3)
    reqs = []
    for rid in range(10):
        p, d = int(rng.integers(6, 20)), int(rng.integers(2, 7))
        reqs.append((0.05 * rid, f"ident{rid}:".encode() * 4, p, d))

    def arrivals():
        for rid, (t, prompt, p, d) in enumerate(reqs):
            comp = build_request_composition(spec, prompt_len=p, n_decode=d)

            def cb(inv, rid=rid):
                done[rid] = inv
            yield t, comp, {"prompt": [Item(prompt)]}, cb

    platform.submit_stream(arrivals())
    platform.run()
    node = platform.nodes[0]
    timeline = [(rid, done[rid].t_end, done[rid].latency)
                for rid in sorted(done)]
    return timeline, list(node.tracker.timeline.points)


def test_batch_aware_degenerates_to_outstanding_at_one_replica():
    """With one replica and one model every marginal estimate is equal,
    so the batch-aware policy's decision sequence — and therefore the
    whole run: completion timeline and memory commits — is byte-
    identical to the default least-outstanding policy (the degeneration
    contract in control_plane.BatchRouter)."""
    base_tl, base_pts = _serving_pool_run("outstanding")
    aware_tl, aware_pts = _serving_pool_run("batch_aware")
    assert base_tl == aware_tl
    assert base_pts == aware_pts


def test_batch_router_ties_break_to_least_outstanding():
    """Equal estimates (fresh identical nodes) defer to invocation load,
    then stable node order — no RNG is consumed."""
    from repro.apps.inference_service import (
        LMSpec, build_request_composition, register_inference_service)
    from repro.core import BatchRouter, FunctionRegistry, WorkerNode

    spec = LMSpec()
    reg = FunctionRegistry()
    svc = register_inference_service(reg, spec)
    loop = EventLoop()
    nodes = [WorkerNode(reg, loop=loop, num_slots=2, profiles=svc.profiles,
                        batch_slots=1, batch_model=svc.batch_model,
                        weight_store=svc.make_weight_store(), seed=5 + i,
                        name=f"tie{i}")
             for i in range(3)]
    comp = build_request_composition(spec, prompt_len=8, n_decode=3)
    router = BatchRouter(spinup_s=0.02, cold_s=0.0)
    loads = {id(n): w for n, w in zip(nodes, (2.0, 0.0, 1.0))}
    picked = router.pick(nodes, comp, reg, load=lambda n: loads[id(n)])
    assert picked is nodes[1]            # least outstanding wins the tie
    loads[id(nodes[1])] = 1.0            # exact tie on load now: 2, 1, 1
    assert router.pick(nodes, comp, reg,
                       load=lambda n: loads[id(n)]) is nodes[1]  # stable order
