"""Composition IR: DSL construction, validation, fan-out semantics."""
import pytest

from repro.core.dag import Composition
from repro.core.items import Item, group_by_key


def _simple_comp():
    c = Composition("c")
    a = c.compute("a", "fa", inputs=("x",), outputs=("y",))
    b = c.compute("b", "fb", inputs=("y",), outputs=("z",))
    c.edge(a["y"], b["y"], "all")
    c.bind_input("x", a["x"])
    c.bind_output("z", b["z"])
    return c


def test_validate_ok():
    _simple_comp().validate()


def test_cycle_detected():
    c = Composition("cyc")
    a = c.compute("a", "fa", inputs=("x",), outputs=("y",))
    b = c.compute("b", "fb", inputs=("y",), outputs=("z",))
    c.edge(a["y"], b["y"])
    c.edges.append(type(c.edges[0])(b["z"], a["x"], "all"))
    c.bind_input("x", a["x"])
    with pytest.raises(ValueError, match="cycle"):
        c.validate()


def test_unfed_input_rejected():
    c = Composition("u")
    c.compute("a", "fa", inputs=("x",), outputs=("y",))
    with pytest.raises(ValueError, match="unfed"):
        c.validate()


def test_double_fan_in_rejected():
    c = Composition("d")
    a = c.compute("a", "fa", inputs=("x",), outputs=("y", "w"))
    b = c.compute("b", "fb", inputs=("y", "w"), outputs=("z",))
    c.edge(a["y"], b["y"], "each")
    c.edge(a["w"], b["w"], "key")
    c.bind_input("x", a["x"])
    with pytest.raises(ValueError, match="each"):
        c.validate()


def test_bad_edge_set_rejected():
    c = Composition("e")
    a = c.compute("a", "fa", inputs=("x",), outputs=("y",))
    b = c.compute("b", "fb", inputs=("y",), outputs=("z",))
    with pytest.raises(ValueError, match="no output set"):
        c.edge(a["x"], b["y"])  # x is an input, not an output


def test_topo_order():
    c = _simple_comp()
    order = c.topo_order()
    assert order.index("a") < order.index("b")


def test_io_intensity():
    c = Composition("i")
    a = c.compute("a", "fa", inputs=("x",), outputs=("y",))
    h = c.http("h")
    c.edge(a["y"], h["requests"])
    c.bind_input("x", a["x"])
    assert c.io_intensity() == 0.5


def test_group_by_key():
    items = [Item(1, "a"), Item(2, "b"), Item(3, "a")]
    g = group_by_key(items)
    assert sorted(g) == ["a", "b"]
    assert [i.data for i in g["a"]] == [1, 3]
