"""Fleet-serving invariants: replica autoscaling, batch-aware routing,
multi-model multiplexing.

Property tests (via tests/_hypothesis_compat) over small random fleets
pin the contracts the fig13 serving benchmark builds on:

  * **weights inflight-zero / freed-exactly-once** — after a fleet fully
    drains, every node's ``WeightStore`` refcount is zero and committed
    bytes equal exactly the still-resident weights plus the arenas of
    the still-active batch replicas, across autoscaler scale-up/down
    churn AND capacity eviction (no double-free, no leak);
  * **no step on a draining replica** — ``EngineSlot._serve_batch``
    never fires on a slot marked draining: retire-while-busy finishes
    the in-flight step first (drain-before-retire);
  * **every decode step runs on resident weights** — a task's model is
    resident at serve time (the inflight refcount shields it from
    eviction and keep-alive reaps), and each residency period pays
    exactly one cold touch (cold touches == releases + still-resident);
  * **multiplex eviction determinism** — two models on one
    capacity-limited pool churn residency through LRU-idle eviction
    deterministically (identical eviction journals and completion
    timelines across runs, under both CROSSNODE settings and the
    sharded loop) with token streams byte-identical to single-model
    runs.

The ``batch_aware``-degenerates-to-``outstanding`` identity proof lives
with the other observational-identity tests in test_perf_identity.py.
"""
import pytest

import numpy as np

from _hypothesis_compat import given, settings, strategies as st

from repro import sdk
from repro.apps.inference_service import (
    LMSpec,
    build_request_composition,
    expected_tokens,
    register_inference_service,
)
from repro.core import (
    BatchRouter,
    EventLoop,
    FunctionRegistry,
    Item,
    ReplicaAutoscaler,
    ReplicaConfig,
    ShardedEventLoop,
    WeightStore,
    WorkerNode,
)
from repro.core.engines import EngineSlot

SPEC_A = LMSpec()
SPEC_B = LMSpec(name="lm-b", n_params=1.45e9, n_layers=20, d_model=1536)


# ------------------------------------------------------------- fixtures
def _replica_cfg(**kw):
    base = dict(min_replicas=0, max_per_node=2, keepalive_s=0.4,
                tick_interval_s=0.05, boot_s=0.02,
                target_queue_per_replica=4.0)
    base.update(kw)
    return ReplicaConfig(**base)


def _fleet(n_nodes, specs, *, capacity=None, ws_keepalive=100.0, loop=None,
           crossnode=None, arena=1 << 20, seed0=40, cfg=None):
    """An elastic pool: zero replicas up front (``batch_slots=0`` with
    per-fn ``batch_models`` marking the capability), batch-aware
    routing, a ``ReplicaAutoscaler``, and per-node weight stores shared
    by every registered model (capacity-limited when ``capacity``)."""
    reg = FunctionRegistry()
    svcs = [register_inference_service(reg, s) for s in specs]
    batch_models, profiles = {}, {}
    for svc in svcs:
        batch_models.update(svc.batch_models)
        profiles.update(svc.profiles)

    def make_ws():
        ws = WeightStore(keepalive_s=ws_keepalive, capacity_bytes=capacity)
        for svc in svcs:
            svc.register_weights(ws)
        return ws

    platform = sdk.Platform(
        registry=reg, profiles=profiles, loop=loop, crossnode=crossnode,
        pool=[sdk.NodeSpec(
            num_slots=4, batch_slots=0, batch_models=batch_models,
            max_batch=8, replica_bytes=arena, weight_store=make_ws,
            seed=seed0 + i, name=f"fl{i}",
        ) for i in range(n_nodes)],
        route_policy="batch_aware",
        batch_router=BatchRouter(
            spinup_s=0.02,
            cold_s=max(svc.weight_cold.total_s for svc in svcs),
        ),
    )
    scaler = ReplicaAutoscaler(platform.loop, platform.nodes,
                               config=cfg or _replica_cfg())
    scaler.start()
    return platform, scaler


def _mixed_requests(n, n_models, seed, spread_s=1.5):
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        t = float(rng.uniform(0.0, spread_s))
        w = int(rng.integers(0, n_models))
        p = int(rng.integers(6, 20))
        d = int(rng.integers(2, 7))
        prompt = (f"fleet{rid}:".encode() * p)[: 4 * p]
        out.append((t, w, prompt, p, d))
    out.sort(key=lambda r: r[0])
    return out


def _drive(platform, specs, reqs):
    """Submit ``(t, model_idx, prompt, p, d)`` requests, run to drain,
    return {rid: finished invocation}."""
    done = {}

    def arrivals():
        for rid, (t, w, prompt, p, d) in enumerate(reqs):
            comp = build_request_composition(
                specs[w], prompt_len=p, n_decode=d)

            def cb(inv, rid=rid):
                done[rid] = inv
            yield t, comp, {"prompt": [Item(prompt)]}, cb

    platform.submit_stream(arrivals())
    platform.run()
    return done


def _tokens_of(inv):
    text = inv.outputs["text"][0].data.decode()
    return [int(t) for t in text[len("tok:"):].split(",")]


def _check_drained(platform, base_committed):
    """The freed-exactly-once contract: after full drain, committed
    bytes on every node are the build-time base plus still-resident
    weights plus the KV arenas of still-active replicas — nothing else
    (contexts freed, retired arenas released, no double-free)."""
    for node, b0 in zip(platform.nodes, base_committed):
        ws, eng = node.weight_store, node.engines
        assert ws.inflight == 0
        expect = b0 + ws.resident_bytes + eng.batch_slots * eng.replica_bytes
        assert node.tracker.committed == expect, (
            f"{node.name}: committed {node.tracker.committed} != {expect}")


# ------------------------------------------- weights freed exactly once
@settings(max_examples=6, deadline=None)
@given(n_nodes=st.integers(2, 4), n_reqs=st.integers(4, 14),
       seed=st.integers(0, 2 ** 20))
def test_fleet_drain_frees_weights_and_arenas_exactly_once(
        n_nodes, n_reqs, seed):
    """Across autoscaler churn AND capacity eviction (two models, room
    for ~one), inflight refcounts drain to zero and committed memory
    closes the books exactly; every token stream matches the pure
    reference."""
    specs = (SPEC_A, SPEC_B)
    capacity = int(1.25 * max(s.param_bytes for s in specs))
    platform, scaler = _fleet(n_nodes, specs, capacity=capacity,
                              ws_keepalive=0.3)
    base = [n.tracker.committed for n in platform.nodes]
    reqs = _mixed_requests(n_reqs, 2, seed)
    done = _drive(platform, specs, reqs)
    assert len(done) == n_reqs
    for rid, (t, w, prompt, p, d) in enumerate(reqs):
        assert not done[rid].failed, done[rid].failed
        assert _tokens_of(done[rid]) == expected_tokens(prompt, specs[w], d)
    assert scaler.scale_ups >= 1          # traffic actually booted replicas
    _check_drained(platform, base)


# ------------------------------- serve-time residency / draining guards
def test_steps_never_serve_draining_or_cold_replicas(monkeypatch):
    """Wrap the batch step server: it must never fire on a draining
    slot, and every coalesced task's weights must be resident at serve
    time. Each residency period pays exactly one cold touch."""
    orig_serve = EngineSlot._serve_batch
    served = [0]

    def guarded(self, tasks):
        assert not self.draining, "batch step served on a draining replica"
        for t in tasks:
            ws = t.meta.get("wstore")
            if ws is not None:
                assert ws.fn_resident(t.fn_name), (
                    f"step for {t.fn_name} on non-resident weights")
        served[0] += 1
        return orig_serve(self, tasks)

    monkeypatch.setattr(EngineSlot, "_serve_batch", guarded)

    releases = []
    orig_release = WeightStore._release

    def counting(self, state):
        releases.append(state)
        return orig_release(self, state)

    monkeypatch.setattr(WeightStore, "_release", counting)

    specs = (SPEC_A, SPEC_B)
    capacity = int(1.25 * max(s.param_bytes for s in specs))
    platform, _ = _fleet(3, specs, capacity=capacity, ws_keepalive=0.25)
    base = [n.tracker.committed for n in platform.nodes]
    reqs = _mixed_requests(24, 2, seed=5, spread_s=3.0)
    done = _drive(platform, specs, reqs)
    assert served[0] > 0                 # the batch engine actually ran
    assert len(done) == len(reqs)
    # exactly-one-cold per residency period: every cold touch opened a
    # period, every release (reap or eviction) closed one
    for node in platform.nodes:
        for state in node.weight_store._models.values():
            ends = sum(1 for s in releases if s is state)
            assert state.cold_touches == ends + (1 if state.resident else 0)
    _check_drained(platform, base)


def test_retire_busy_replica_drains_before_retiring(monkeypatch):
    """Retiring the only replica mid-step marks it draining; the
    in-flight coalesced step completes, THEN the slot retires and its
    arena is released. The request's tokens are unaffected."""
    orig_serve = EngineSlot._serve_batch
    state = {}

    def trigger(self, tasks):
        r = orig_serve(self, tasks)
        if "retired" not in state:
            # slot is busy with the step we just started: retire it now,
            # and boot a replacement shortly after (the autoscaler's
            # move) so the rest of the decode chain has a replica
            state["retired"] = self.node.retire_batch_slot()
            state["draining_seen"] = self.draining
            self.node.loop.after(0.01, self.node.add_batch_slot)
        else:
            assert not self.draining     # later steps: the fresh slot only
        return r

    monkeypatch.setattr(EngineSlot, "_serve_batch", trigger)

    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC_A)
    loop = EventLoop()
    arena = 1 << 20
    node = WorkerNode(
        reg, loop=loop, num_slots=4, profiles=svc.profiles,
        batch_slots=0, batch_models=svc.batch_models, max_batch=8,
        replica_bytes=arena,
        weight_store=svc.make_weight_store(keepalive_s=0.0), seed=3,
    )
    node.engines.add_batch_slot()
    assert node.tracker.committed >= arena       # arena committed up front
    out = {}
    prompt = b"drain-me" * 4
    comp = build_request_composition(SPEC_A, prompt_len=8, n_decode=5)
    node.invoke(comp, {"prompt": [Item(prompt)]},
                lambda inv: out.setdefault("inv", inv))
    loop.run()
    assert state["retired"] is True
    assert state["draining_seen"] is True        # busy -> drained, not yanked
    inv = out["inv"]
    assert not inv.failed
    assert _tokens_of(inv) == expected_tokens(prompt, SPEC_A, 5)
    eng = node.engines
    assert eng.replicas_retired == 1             # the drained replica left
    assert eng.replicas_added == 2               # original + replacement
    assert eng.batch_slots == 1
    assert node.weight_store.inflight == 0
    # books balance: one live arena + resident weights, retired arena freed
    assert node.tracker.committed == \
        node.weight_store.resident_bytes + eng.replica_bytes


# -------------------------------------------- multiplexing determinism
def _phased_requests():
    """Three sequential per-model phases: A warms up, B's arrival must
    evict A's idle weights (capacity holds ~one model), A's return
    evicts B — deterministic LRU-idle churn."""
    reqs = []
    # phase gaps must exceed the ~2.8 s weight cold-start: the previous
    # model's first request holds an inflight ref until it finishes
    # loading + decoding, and inflight weights are never victims
    for rid in range(3):
        reqs.append((0.03 * rid, 0, f"mxa{rid}:".encode() * 8, 8, 4))
    for rid in range(3):
        reqs.append((6.0 + 0.03 * rid, 1, f"mxb{rid}:".encode() * 8, 8, 4))
    for rid in range(2):
        reqs.append((12.0 + 0.03 * rid, 0, f"mxc{rid}:".encode() * 8, 8, 4))
    return reqs


def _multiplex_run(crossnode, sharded):
    specs = (SPEC_A, SPEC_B)
    capacity = int(1.25 * max(s.param_bytes for s in specs))
    loop = ShardedEventLoop() if sharded else EventLoop()
    platform, scaler = _fleet(2, specs, capacity=capacity, loop=loop,
                              crossnode=crossnode)
    base = [n.tracker.committed for n in platform.nodes]
    reqs = _phased_requests()
    done = _drive(platform, specs, reqs)
    texts = {rid: _tokens_of(done[rid]) for rid in done}
    evictions = sum(n.weight_store.evictions for n in platform.nodes)
    journal = [tuple(n.weight_store.eviction_log) for n in platform.nodes]
    timeline = sorted((rid, done[rid].t_end, done[rid].latency)
                      for rid in done)
    _check_drained(platform, base)
    return {"reqs": reqs, "texts": texts, "evictions": evictions,
            "journal": journal, "timeline": timeline,
            "scale": scaler.summary()}


@pytest.mark.parametrize("crossnode", [False, True])
@pytest.mark.parametrize("sharded", [False, True])
def test_multiplex_eviction_deterministic(crossnode, sharded):
    """Two-model contention on a capacity-limited pool: residency churns
    through at least one LRU-idle eviction, byte-identically across
    runs (eviction journal, completion timeline, scale events) under
    both CROSSNODE settings and the sharded loop."""
    a = _multiplex_run(crossnode, sharded)
    b = _multiplex_run(crossnode, sharded)
    assert a["evictions"] >= 1
    assert a["journal"] == b["journal"]
    assert a["timeline"] == b["timeline"]
    assert a["scale"] == b["scale"]
    for rid, (t, w, prompt, p, d) in enumerate(a["reqs"]):
        assert a["texts"][rid] == expected_tokens(
            prompt, (SPEC_A, SPEC_B)[w], d)


@pytest.mark.parametrize("crossnode", [False, True])
def test_multiplex_token_streams_match_single_model_runs(crossnode):
    """Contention may reshape durations and residency, never dataflow:
    each model's token streams under two-model multiplexing equal the
    same requests replayed on a single-model fleet."""
    mx = _multiplex_run(crossnode, sharded=False)
    for model_idx, spec in ((0, SPEC_A), (1, SPEC_B)):
        solo_reqs = [(t, 0, prompt, p, d)
                     for (t, w, prompt, p, d) in mx["reqs"]
                     if w == model_idx]
        platform, _ = _fleet(2, (spec,))
        done = _drive(platform, (spec,), solo_reqs)
        solo = [_tokens_of(done[i]) for i in range(len(solo_reqs))]
        multi = [mx["texts"][rid]
                 for rid, (t, w, prompt, p, d) in enumerate(mx["reqs"])
                 if w == model_idx]
        assert solo == multi


# ----------------------------------------------- fig13 knob validation
def test_fig13_env_knob_validation(monkeypatch):
    """FIG13_NODES / FIG13_RATE_HZ are validated at import: bad values
    exit with a message instead of producing a silently-wrong fleet."""
    import importlib

    import benchmarks.fig13_serving as f13
    for name, bad in (("FIG13_NODES", "sixteen"), ("FIG13_NODES", "1"),
                      ("FIG13_RATE_HZ", "fast"), ("FIG13_RATE_HZ", "0")):
        monkeypatch.setenv(name, bad)
        with pytest.raises(SystemExit):
            importlib.reload(f13)
        monkeypatch.delenv(name)
    f13 = importlib.reload(f13)
    assert f13.N_NODES == 16 and f13.RATE_HZ == 200.0
