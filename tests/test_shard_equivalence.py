"""``ShardedEventLoop`` exact-mode byte identity vs. ``EventLoop``.

The sharded loop's contract (see ``core/sim.py``): with ``lookahead_s
== 0`` it pops the globally minimal ``(time, seq)`` head across shard
heaps and shares one sequence counter, so its pop order — and therefore
every downstream observable: callback order, clock reads, RNG
consumption, latency samples, memory timelines — is *identical* to a
single merged heap. These tests pin that claim three ways:

  1. a property test over adversarial schedules (ties, daemons,
     recursive reschedules that hop shards);
  2. a full SDK pool platform with cold starts, jittered service times
     and streamed arrivals, run to float equality on every observable
     (honors the ``CROSSNODE`` env knob like the CI matrix does);
  3. the fig10/fig11 benchmark row contract itself, in-process, with
     ``DANDELION_SHARDS`` off vs. on.

The lookahead>0 window mode trades the identity guarantee for shard
batching and is exercised only for soundness (same completions), not
byte identity.
"""
import os

from tests._hypothesis_compat import given, settings, strategies as st

from repro import sdk
from repro.core import EventLoop, Item, ShardedEventLoop, merged_peak


# ===========================================================================
# 1. Event-order identity on adversarial schedules
# ===========================================================================
def _run_schedule(loop, shards, events):
    """Replay a drawn schedule and trace every callback invocation.

    ``shards`` maps a drawn shard id to a scheduling surface (the loop
    itself, or one of its shard views); callbacks reschedule themselves
    ``after`` a drawn delay on the *next* shard id, two levels deep, so
    cross-shard time reads and tie-breaking both get exercised.
    """
    trace = []

    def fire(i, sid, delay, depth):
        def cb():
            trace.append((round(loop.now, 9), i, depth))
            if depth < 2:
                nxt = shards[(sid + depth + 1) % len(shards)]
                nxt.after(delay, fire(i, sid + 1, delay, depth + 1))
        return cb

    for i, (sid, t, delay, daemon) in enumerate(events):
        shards[sid % len(shards)].at(t, fire(i, sid, delay, 0),
                                     daemon=daemon)
    loop.run()
    return trace, loop.now


@settings(max_examples=15)
@given(st.lists(
    st.tuples(
        st.integers(0, 2),                       # shard id
        st.sampled_from([0.0, 0.5, 0.5, 1.0, 1.25, 2.0]),  # time (ties!)
        st.sampled_from([0.0, 0.25, 0.5]),       # reschedule delay
        st.booleans(),                           # daemon
    ),
    min_size=1, max_size=12,
))
def test_exact_mode_event_order_identical(events):
    # daemon-only schedules stop immediately on both loops; keep one
    # non-daemon event so the run is non-trivial
    events = list(events)
    sid, t, d, _ = events[0]
    events[0] = (sid, t, d, False)

    ref_loop = EventLoop()
    ref = _run_schedule(ref_loop, [ref_loop] * 3, events)

    sh_loop = ShardedEventLoop()
    shards = [sh_loop.shard(f"n{i}") for i in range(3)]
    got = _run_schedule(sh_loop, shards, events)
    assert got == ref


# ===========================================================================
# 2. Full platform identity (pool shape, cold starts, jitter, stream)
# ===========================================================================
def _apps():
    return [
        sdk.single_function_app(sdk.declare(
            f"f{k}",
            lambda ins: {"out": [Item(ins["x"][0].data)]},
            inputs=("x",), outputs=("out",),
            context_bytes=(1 + k) << 18,
            profile=sdk.ColdStartProfile(3e-4, 0.02, jitter_sigma=0.2),
        ))
        for k in range(4)
    ]


def _run_mini(loop, n_events, seed):
    platform = sdk.Platform(
        pool=[sdk.NodeSpec(num_slots=2, seed=30 + i, name=f"pn{i}")
              for i in range(3)],
        loop=loop,
    )
    apps = _apps()
    for app in apps:
        platform.deploy(app)
    rng = __import__("random").Random(seed)
    arrivals = sorted(
        (rng.uniform(0.0, 2.0), apps[rng.randrange(4)],
         {"x": [Item(bytes([j % 251]))]})
        for j in range(n_events)
    )
    platform.submit_stream(iter(arrivals))
    platform.run(until=2.5)
    platform.run()           # drain stragglers past the window
    return (
        sorted(platform.latency.samples),
        [n.tracker.timeline.points for n in platform.nodes],
        merged_peak([n.tracker.timeline for n in platform.nodes]),
        next(loop._seq),     # total events consumed — pop-count identity
    )


@settings(max_examples=5)
@given(st.integers(5, 40), st.integers(0, 10_000))
def test_pool_platform_identical_under_sharding(n_events, seed):
    ref = _run_mini(EventLoop(), n_events, seed)
    got = _run_mini(ShardedEventLoop(), n_events, seed)
    assert got == ref


def test_pool_platform_identical_with_crossnode_forced():
    for crossnode in (False, True):
        os.environ["CROSSNODE"] = "1" if crossnode else "0"
        try:
            ref = _run_mini(EventLoop(), 30, 77)
            got = _run_mini(ShardedEventLoop(), 30, 77)
        finally:
            os.environ.pop("CROSSNODE", None)
        assert got == ref, f"crossnode={crossnode}"


# ===========================================================================
# 3. The benchmark row contract itself (fig10 / fig11, in-process)
# ===========================================================================
def _bench_rows(module_name, knob, value, monkeypatch, shards):
    import importlib

    monkeypatch.setenv(knob, value)
    if shards:
        monkeypatch.setenv("DANDELION_SHARDS", "1")
    else:
        monkeypatch.delenv("DANDELION_SHARDS", raising=False)
    mod = importlib.import_module(f"benchmarks.{module_name}")
    return mod.run()


def test_fig10_rows_identical_under_sharding(monkeypatch):
    ref = _bench_rows("fig10_azure_trace", "FIG10_DURATION_S", "30",
                      monkeypatch, shards=False)
    got = _bench_rows("fig10_azure_trace", "FIG10_DURATION_S", "30",
                      monkeypatch, shards=True)
    assert got == ref


def test_fig11_rows_identical_under_sharding(monkeypatch):
    ref = _bench_rows("fig11_elastic_scaleout", "FIG11_QUICK", "1",
                      monkeypatch, shards=False)
    got = _bench_rows("fig11_elastic_scaleout", "FIG11_QUICK", "1",
                      monkeypatch, shards=True)
    assert got == ref


# ===========================================================================
# 4. Lookahead window mode: sound, not byte-identical
# ===========================================================================
def test_lookahead_mode_completes_all_work():
    """With a conservative window the shard batching must never lose or
    reorder *dataflow* (every invocation completes with the right
    outputs), even though wall-ordering details may differ."""
    loop = ShardedEventLoop(lookahead_s=1e-3)
    platform = sdk.Platform(
        pool=[sdk.NodeSpec(num_slots=2, seed=40 + i, name=f"ln{i}")
              for i in range(2)],
        loop=loop,
    )
    apps = _apps()
    for app in apps:
        platform.deploy(app)
    done = []
    platform.submit_stream([
        (0.01 * j, apps[j % 4], {"x": [Item(bytes([j]))]},
         lambda inv, j=j: done.append((j, inv.outputs["out"][0].data)))
        for j in range(24)
    ])
    platform.run()
    assert sorted(done) == [(j, bytes([j])) for j in range(24)]
