"""Attention math: chunked == naive; cache semantics; decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models.attention import (
    cache_write_decode,
    cache_write_prefill,
    chunked_attention,
    decode_attention,
    naive_attention,
)

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("sq,sk,hq,hkv,dh,window", [
    (64, 64, 4, 2, 16, 0),
    (64, 64, 4, 4, 32, 0),
    (128, 128, 8, 2, 16, 24),
    (32, 96, 4, 1, 16, 0),
])
def test_chunked_matches_naive(sq, sk, hq, hkv, dh, window):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, sq, hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (2, sk, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (2, sk, hkv, dh), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, window=window, q_block=16, kv_block=32)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@given(
    sq=st.sampled_from([16, 32, 48]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16]),
    qb=st.sampled_from([8, 16]),
)
@settings(max_examples=12, deadline=None)
def test_chunked_matches_naive_property(sq, hkv, g, dh, qb):
    hq = hkv * g
    ks = jax.random.split(jax.random.PRNGKey(sq * 1000 + hq * 10 + dh), 3)
    q = jax.random.normal(ks[0], (1, sq, hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (1, sq, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (1, sq, hkv, dh), jnp.float32)
    got = chunked_attention(q, k, v, q_block=qb, kv_block=qb)
    want = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-3, atol=3e-3)


def test_decode_matches_full_attention():
    """Decoding token t against the cache == row t of full attention."""
    b, s, hq, hkv, dh = 2, 24, 4, 2, 16
    ks = jax.random.split(RNG, 3)
    q_all = jax.random.normal(ks[0], (b, s, hq, dh), jnp.float32)
    k_all = jax.random.normal(ks[1], (b, s, hkv, dh), jnp.float32)
    v_all = jax.random.normal(ks[2], (b, s, hkv, dh), jnp.float32)
    full = naive_attention(q_all, k_all, v_all, causal=True)

    t = s - 1
    slot = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    cur = jnp.full((b,), t, jnp.int32)
    got = decode_attention(q_all[:, t], k_all, v_all, slot, cur)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3)


def test_ring_cache_write_decode():
    """Ring writes land at pos % S and evict the oldest entry."""
    b, s, hkv, dh = 1, 4, 1, 8
    ck = jnp.zeros((b, s, hkv, dh))
    cv = jnp.zeros((b, s, hkv, dh))
    sp = jnp.full((b, s), -1, jnp.int32)
    for pos in range(7):
        k_new = jnp.full((b, hkv, dh), float(pos))
        ck, cv, sp = cache_write_decode(ck, cv, sp, k_new, k_new, jnp.array([pos]), ring=True)
    # positions 3..6 should be resident (7 writes into 4 slots)
    assert sorted(np.asarray(sp[0]).tolist()) == [3, 4, 5, 6]
    slot_of_6 = int(np.argmax(np.asarray(sp[0]) == 6))
    assert float(ck[0, slot_of_6, 0, 0]) == 6.0


def test_cache_write_prefill_overflow_keeps_tail():
    b, s_new, s_cache, hkv, dh = 1, 8, 4, 1, 2
    k_new = jnp.arange(s_new, dtype=jnp.float32)[None, :, None, None] * jnp.ones((b, s_new, hkv, dh))
    ck = jnp.zeros((b, s_cache, hkv, dh))
    sp = jnp.full((b, s_cache), -1, jnp.int32)
    ck2, _, sp2 = cache_write_prefill(ck, ck, sp, k_new, k_new, ring=True)
    assert sorted(np.asarray(sp2[0]).tolist()) == [4, 5, 6, 7]
    # ring invariant: entry with absolute position p sits at slot p % S
    for slot in range(s_cache):
        p = int(sp2[0, slot])
        assert p % s_cache == slot
