"""Serving: prefill-vs-decode consistency, continuous batching, and the
trace-capture shim that calibrates the platform's batch-step model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models.model import build
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.engine import generate
from repro.serving.trace_capture import (
    calibrated_batch_model,
    calibration_residuals,
    capture_step_timings,
    fit_affine,
)

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2.5-32b", "mamba2-130m", "olmoe-1b-7b"])
def test_prefill_decode_consistency(arch):
    """Logits from decode steps must match teacher-forced prefill logits.

    Prefill(t[0:n]) gives cache+logits for position n-1; decode_step with
    token t[n] must produce (approximately) the logits a fresh prefill of
    t[0:n+1] would give at its last position.
    """
    cfg = get_smoke(arch)
    api = build(cfg)
    params = api.init_params(RNG)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab_size)

    # reference: prefill the full S+1 prompt
    full_logits, _ = jax.jit(api.prefill)(
        params, tokens, jnp.full((B,), S + 1, jnp.int32)
    )
    # candidate: prefill S (padded to S+1 width), then decode token S
    plens = jnp.full((B,), S, jnp.int32)
    _, cache = jax.jit(api.prefill)(params, tokens, plens)  # pads ignored via plens
    step_logits, _ = jax.jit(api.decode_step)(params, cache, tokens[:, S])

    a = np.asarray(full_logits, np.float32)
    b = np.asarray(step_logits, np.float32)
    # compare top-1 and logit values (bf16 accumulation tolerance)
    np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-1)
    assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() >= 0.5


def test_continuous_batcher_matches_sequential_generate():
    cfg = get_smoke("granite-8b")
    api = build(cfg)
    params = api.init_params(RNG)
    cache_len, max_new = 24, 6
    prompts = [
        [5, 9, 2, 7], [1, 2, 3], [11, 4, 8, 15, 16],
    ]
    batcher = ContinuousBatcher(api, params, num_slots=2, cache_len=cache_len)
    for rid, p in enumerate(prompts):
        batcher.submit(Request(rid, p, max_new_tokens=max_new))
    results = batcher.run_to_completion()
    assert sorted(results) == [0, 1, 2]
    assert all(len(v) == max_new for v in results.values())

    # sequential reference per request (greedy): same tokens
    for rid, p in enumerate(prompts):
        toks = jnp.asarray([p + [0] * (cache_len - len(p))], jnp.int32)
        plen = jnp.asarray([len(p)], jnp.int32)
        seq = generate(api, params, toks, plen, max_new)
        want = np.asarray(seq[0]).tolist()
        assert results[rid] == want, f"req {rid}: {results[rid]} != {want}"


def test_trace_capture_calibrates_batch_model():
    """Real jitted step timings fit the platform's BatchStepModel shape:
    the calibrated model reproduces the measured affine decode curve."""
    cfg = get_smoke("mamba2-130m")
    api = build(cfg)
    params = api.init_params(RNG)
    timings = capture_step_timings(
        api, params, batches=(1, 2), cache_len=16, prompt_len=4, samples=2,
    )
    assert [t.batch for t in timings] == [1, 2]
    assert all(t.prefill_s > 0 and t.decode_s > 0 for t in timings)
    fixed, per_seq = fit_affine(timings)
    model = calibrated_batch_model(timings)
    assert model.step_s(1) == pytest.approx(fixed + per_seq)
    assert model.step_s(2) == pytest.approx(fixed + 2 * per_seq)
    # batching a calibrated model never beats per-sequence linearity
    assert model.step_s(4) <= 4 * model.step_s(1) + 1e-12
    # the residual report scores the fit through the vectorized pricing
    # path; a 2-point affine fit of 2 points is (near) exact unless the
    # lstsq clamp to nonnegative coefficients kicked in
    res = calibration_residuals(timings, model)
    assert [b for b, _ in res] == [1, 2]
    if fixed > 0 and per_seq > 0:
        assert all(abs(r) < 1e-6 for _, r in res)


def test_batcher_frees_slots_and_admits_waiting():
    cfg = get_smoke("mamba2-130m")
    api = build(cfg)
    params = api.init_params(RNG)
    batcher = ContinuousBatcher(api, params, num_slots=2, cache_len=16)
    for rid in range(5):  # more requests than slots
        batcher.submit(Request(rid, [1 + rid, 2, 3], max_new_tokens=3))
    results = batcher.run_to_completion()
    assert sorted(results) == [0, 1, 2, 3, 4]
    assert all(len(v) == 3 for v in results.values())
