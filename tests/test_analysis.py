"""The static-analysis subsystem: purity verifier, determinism lint,
composition lint, and the SDK/platform verification gate.

Fixture payloads are written to a real file and imported (``inspect``
must see source; ``exec``-built code is exactly what the
``source-unavailable`` rule is for). Rule tests assert on rule ids and
locations, not message prose, so wording can evolve.
"""
import ast
import importlib.util
import os
import random
import sys
import warnings
from pathlib import Path

import pytest

from repro.analysis import (
    Finding,
    PurityReport,
    Report,
    RULES,
    analyze_callable,
    clear_cache,
    lint_composition,
    lint_paths,
    lint_source,
    registration_lint_hook,
)
from repro.analysis.findings import ERROR, INFO, WARN
from repro.core import FunctionRegistry, Item
from repro.core.dag import (
    Composition,
    RetryPolicy,
    add_registration_hook,
    remove_registration_hook,
)
from repro import sdk
from repro.sdk import PlatformConfig, PurityError
from repro.sdk.errors import DeploymentError

REPO = Path(__file__).resolve().parent.parent

FIXTURE_SOURCE = '''\
"""Purity-rule fixture payloads (imported from a real file)."""
import datetime
import os
import random
import subprocess
import time
import zlib
from time import perf_counter

import numpy as np

SHARED = {"hits": 0}
ITEMS = []


def clock_direct(ins):
    return {"out": [time.time()]}


def clock_aliased(ins):
    return {"out": [perf_counter()]}


def clock_datetime(ins):
    return {"out": [datetime.datetime.now()]}


def rng_global(ins):
    return {"out": [random.random()]}


def rng_unseeded_np(ins):
    g = np.random.default_rng()
    return {"out": [g.normal()]}


def rng_seeded_np(ins):
    g = np.random.default_rng(7)
    return {"out": [g.normal()]}


def io_print(ins):
    print("side effect")
    return {"out": []}


def io_open(ins):
    with open("/tmp/x") as f:
        return {"out": [f.read()]}


def io_subprocess(ins):
    return {"out": [subprocess.run(["ls"])]}


def io_os(ins):
    return {"out": [os.getpid()]}


def io_os_path_ok(ins):
    return {"out": [os.path.join("a", "b")]}


def mutates_global(ins):
    SHARED["hits"] += 1
    return {"out": []}


def mutates_global_method(ins):
    ITEMS.append(1)
    return {"out": []}


def mutates_local_ok(ins):
    items = []
    items.append(1)
    return {"out": items}


def global_stmt(ins):
    global SHARED
    SHARED = {}
    return {"out": []}


def set_iter_loop(ins):
    acc = []
    for x in {1, 2, 3}:
        acc.append(x)
    return {"out": acc}


def set_iter_sum_ok(ins):
    return {"out": [sum(x for x in {1, 2, 3})]}


def hash_builtin(ins):
    return {"out": [hash("name")]}


def hash_crc_ok(ins):
    return {"out": [zlib.crc32(b"name")]}


def waived_clock(ins):
    t = time.time()  # det-lint: waive[wall-clock] reason=fixture: real path
    return {"out": [t]}


def waived_above(ins):
    # det-lint: waive[wall-clock] reason=fixture: pragma on line above
    t = time.time()
    return {"out": [t]}


def waived_no_reason(ins):
    t = time.time()  # det-lint: waive[wall-clock]
    return {"out": [t]}


def _helper_prints(x):
    print(x)
    return x


def calls_helper(ins):
    return {"out": [_helper_prints(1)]}


def _deep2(x):
    return time.time() + x


def _deep1(x):
    return _deep2(x)


def calls_deep(ins):
    return {"out": [_deep1(0)]}


def clean(ins):
    g = np.random.default_rng(0)
    vals = sorted({1, 2, 3})
    return {"out": [g.normal() + sum(vals)]}
'''


@pytest.fixture(scope="module")
def fixture_mod(tmp_path_factory):
    path = tmp_path_factory.mktemp("analysis") / "purity_fixtures.py"
    path.write_text(FIXTURE_SOURCE)
    spec = importlib.util.spec_from_file_location("purity_fixtures", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    clear_cache()
    yield mod
    clear_cache()


def rules_of(findings, *, include_waived=False):
    return sorted({f.rule for f in findings
                   if include_waived or not f.waived})


def fixture_line(marker: str) -> int:
    """1-based line of the first fixture-source line containing marker."""
    for i, line in enumerate(FIXTURE_SOURCE.splitlines(), start=1):
        if marker in line:
            return i
    raise AssertionError(f"marker {marker!r} not in fixture source")


# ===========================================================================
# 1. purity rules, one by one
# ===========================================================================
@pytest.mark.parametrize("fn_name,rule", [
    ("clock_direct", "wall-clock"),
    ("clock_aliased", "wall-clock"),       # from-import alias resolved
    ("clock_datetime", "wall-clock"),
    ("rng_global", "rng"),
    ("rng_unseeded_np", "rng"),            # np alias resolved
    ("io_print", "io"),
    ("io_open", "io"),
    ("io_subprocess", "io"),
    ("io_os", "io"),
    ("mutates_global", "global-mutation"),
    ("mutates_global_method", "global-mutation"),
    ("global_stmt", "global-mutation"),
    ("set_iter_loop", "set-iter"),
    ("hash_builtin", "builtin-hash"),
])
def test_rule_fires(fixture_mod, fn_name, rule):
    findings = analyze_callable(getattr(fixture_mod, fn_name))
    assert rule in rules_of(findings), (fn_name, findings)
    assert all(f.severity == ERROR for f in findings if f.rule == rule)


@pytest.mark.parametrize("fn_name", [
    "rng_seeded_np", "io_os_path_ok", "mutates_local_ok",
    "set_iter_sum_ok", "hash_crc_ok", "clean",
])
def test_rule_negative(fixture_mod, fn_name):
    findings = analyze_callable(getattr(fixture_mod, fn_name))
    assert rules_of(findings) == [], (fn_name, findings)


def test_findings_carry_file_and_line(fixture_mod):
    (f,) = [f for f in analyze_callable(fixture_mod.clock_direct)
            if f.rule == "wall-clock"]
    assert f.file.endswith("purity_fixtures.py")
    assert f.line == fixture_line('return {"out": [time.time()]}')
    assert f.function == "clock_direct"


def test_every_flagged_rule_is_in_catalog(fixture_mod):
    for name in ("clock_direct", "rng_global", "io_print",
                 "mutates_global", "set_iter_loop", "hash_builtin"):
        for f in analyze_callable(getattr(fixture_mod, name)):
            assert f.rule in RULES


# ===========================================================================
# 2. callee recursion and source availability
# ===========================================================================
def test_callee_recursion_flags_helper(fixture_mod):
    findings = analyze_callable(fixture_mod.calls_helper)
    ios = [f for f in findings if f.rule == "io"]
    assert ios, findings
    assert "in callee _helper_prints()" in ios[0].message
    assert ios[0].line == fixture_line("    print(x)")


def test_callee_recursion_depth_two(fixture_mod):
    findings = analyze_callable(fixture_mod.calls_deep)
    assert "wall-clock" in rules_of(findings)     # via _deep1 -> _deep2


def test_callee_recursion_is_depth_bounded(fixture_mod):
    assert rules_of(analyze_callable(fixture_mod.calls_deep,
                                     depth=1)) == []


def test_unanalyzable_payload_is_advisory_not_blocking():
    findings = analyze_callable(len, name="builtin_len")
    assert [f.rule for f in findings] == ["source-unavailable"]
    assert findings[0].severity == INFO
    assert Report(findings).ok


def test_exec_built_code_is_source_unavailable():
    ns = {}
    exec("def made(ins):\n    return {}", ns)
    findings = analyze_callable(ns["made"])
    assert [f.rule for f in findings] == ["source-unavailable"]


def test_memoized_by_code_object(fixture_mod):
    a = analyze_callable(fixture_mod.clock_direct)
    b = analyze_callable(fixture_mod.clock_direct)
    assert a == b


# ===========================================================================
# 3. waiver pragmas
# ===========================================================================
def test_line_waiver_keeps_finding_but_unblocks(fixture_mod):
    findings = analyze_callable(fixture_mod.waived_clock)
    (f,) = [f for f in findings if f.rule == "wall-clock"]
    assert f.waived and "real path" in f.waive_reason
    assert Report(findings).ok


def test_comment_only_waiver_covers_next_line(fixture_mod):
    findings = analyze_callable(fixture_mod.waived_above)
    (f,) = [f for f in findings if f.rule == "wall-clock"]
    assert f.waived


def test_waiver_without_reason_is_its_own_finding(fixture_mod):
    findings = analyze_callable(fixture_mod.waived_no_reason)
    rules = rules_of(findings)
    assert "bad-waiver" in rules          # the pragma itself
    assert "wall-clock" in rules          # ...and it waives nothing
    assert not Report(findings).ok


def test_file_scope_waiver_and_star():
    src = ("# det-lint: file waive[wall-clock] reason=whole-file test\n"
           "import time\n"
           "def f():\n"
           "    t = time.time()\n"
           "    g = __import__('random')\n"
           "    return sorted([], key=lambda x: (id(x), x))  "
           "# det-lint: waive[*] reason=star test\n")
    findings = lint_source(src, "t.py")
    assert findings, "expected findings"
    assert all(f.waived for f in findings), findings


# ===========================================================================
# 4. determinism lint (module-level pass)
# ===========================================================================
def test_det_lint_scope_separation_no_duplicates():
    src = ("import time\n"
           "def outer():\n"
           "    def inner():\n"
           "        return time.time()\n"
           "    return inner\n")
    findings = lint_source(src, "t.py")
    assert len(findings) == 1
    assert findings[0].function == "outer.inner"


def test_det_lint_id_order_rule():
    src = "def f(xs):\n    return sorted(xs, key=lambda x: id(x))\n"
    assert rules_of(lint_source(src, "t.py")) == ["id-order"]


def test_det_lint_id_as_dict_key_not_flagged():
    src = ("def f(xs, load):\n"
           "    return min(xs, key=lambda x: load[id(x)])\n")
    assert rules_of(lint_source(src, "t.py")) == []


def test_det_lint_set_typed_local_tracked_across_statements():
    src = ("def f():\n"
           "    s = set([3, 1])\n"
           "    out = [x for x in s]\n"
           "    return out\n")
    assert rules_of(lint_source(src, "t.py")) == ["set-iter"]


def test_det_lint_does_not_run_purity_rules():
    src = "def f():\n    print('fine for the simulator itself')\n"
    assert lint_source(src, "t.py") == []


def test_repo_source_is_unwaived_clean():
    """The tentpole gate: zero unwaived findings over src/repro, and
    every waiver carries a reason (the pragma grammar enforces it)."""
    report = lint_paths([REPO / "src" / "repro"])
    assert report.unwaived == [], report.render(show_waived=False)
    assert all(f.waive_reason for f in report.waived)


# ===========================================================================
# 5. report model: deterministic ordering, rendering
# ===========================================================================
def test_report_order_is_input_order_independent():
    base = [Finding(rule="io", severity=ERROR, file=f, line=n,
                    message=f"m{n}", function="fn")
            for f in ("b.py", "a.py") for n in (9, 2, 5)]
    rng = random.Random(0)
    renders = set()
    for _ in range(5):
        shuffled = list(base)
        rng.shuffle(shuffled)
        renders.add(Report(shuffled).render())
    assert len(renders) == 1
    ordered = Report(base).findings
    assert [(f.file, f.line) for f in ordered] == sorted(
        (f.file, f.line) for f in base)


def test_report_summary_counts():
    fs = [
        Finding(rule="io", severity=ERROR, file="a", line=1, message="x"),
        Finding(rule="graph-unreachable", severity=WARN, file="a", line=2,
                message="y"),
        Finding(rule="io", severity=ERROR, file="a", line=3, message="z",
                waived=True, waive_reason="r"),
    ]
    r = Report(fs)
    assert len(r.blocking) == 1 and len(r.waived) == 1 and not r.ok
    assert "3 finding(s): 1 blocking, 1 advisory, 1 waived" in r.render()
    assert len(r.render(show_waived=False).splitlines()) == 3


# ===========================================================================
# 6. composition lint
# ===========================================================================
def bad_graph() -> Composition:
    c = Composition("bad")
    a = c.compute("a", "fa", inputs=("i",), outputs=("o",))
    c.compute("island", "fb", inputs=(), outputs=("o2",))
    h = c.http("h")
    c.vertices["h"].retry = RetryPolicy(max_retries=3)
    c.bind_input("in", a["i"])
    c.edge(a["o"], h["requests"], mode="each")
    c.bind_output("out", h["responses"])
    return c


def test_graph_lint_rules_fire():
    report = lint_composition(bad_graph(), cluster=True, crossnode=False)
    by = {f.rule: f for f in report.findings}
    assert set(by) == {"graph-unreachable", "graph-dangling-output",
                       "graph-comm-retry", "graph-fanout-local"}
    assert by["graph-unreachable"].severity == WARN
    assert by["graph-unreachable"].function == "island"
    assert by["graph-comm-retry"].severity == WARN
    assert by["graph-dangling-output"].severity == INFO
    assert report.ok                       # none of these blocks strict


def test_graph_fanout_rule_needs_cluster_without_crossnode():
    comp = bad_graph()
    for cluster, crossnode in ((False, False), (True, True)):
        report = lint_composition(comp, cluster=cluster,
                                  crossnode=crossnode)
        assert report.by_rule("graph-fanout-local") == []


def test_graph_lint_clean_composition():
    c = Composition("ok")
    a = c.compute("a", "fa", inputs=("i",), outputs=("o",))
    c.bind_input("in", a["i"])
    c.bind_output("out", a["o"])
    assert lint_composition(c).findings == ()


def test_registration_hook_strict_blocks_registration():
    hook = registration_lint_hook("strict")
    add_registration_hook(hook)
    try:
        reg = FunctionRegistry()
        for fname in ("fa", "fb"):
            reg.register_function(fname, lambda ins: {}, context_bytes=1)
        with pytest.raises(ValueError, match="graph-unreachable"):
            reg.register_composition(bad_graph())
        assert "bad" not in reg.compositions
    finally:
        remove_registration_hook(hook)
    # hook removed: the same composition now registers
    reg2 = FunctionRegistry()
    for fname in ("fa", "fb"):
        reg2.register_function(fname, lambda ins: {}, context_bytes=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        reg2.register_composition(bad_graph())
    assert "bad" in reg2.compositions


def test_registration_hook_mode_validated():
    with pytest.raises(ValueError):
        registration_lint_hook("loud")


# ===========================================================================
# 7. sdk.verify + the Platform gate
# ===========================================================================
def impure_spec(fixture_mod, **kw):
    return sdk.declare("impure_fixture", fixture_mod.clock_direct,
                       inputs=("x",), outputs=("out",), **kw)


def test_verify_returns_purity_report(fixture_mod):
    report = sdk.verify(impure_spec(fixture_mod))
    assert isinstance(report, PurityReport)
    assert report.checked == ("impure_fixture",)
    assert not report.ok
    assert "wall-clock" in {f.rule for f in report.blocking}


def test_verify_pure_unsafe_waives_and_records(fixture_mod):
    report = sdk.verify(impure_spec(fixture_mod, pure_unsafe=True))
    assert report.ok
    assert report.unsafe == ("impure_fixture",)
    assert any(f.rule == "wall-clock" and f.waived for f in report.findings)
    assert "pure_unsafe" in report.render()


def test_verify_rejects_unknown_target():
    with pytest.raises(TypeError):
        sdk.verify(42)


def test_strict_deploy_raises_typed_error_naming_everything(fixture_mod):
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=2),
                            verify="strict")
    with pytest.raises(PurityError) as exc:
        platform.deploy(impure_spec(fixture_mod))
    msg = str(exc.value)
    assert "[wall-clock]" in msg
    assert "impure_fixture" in msg
    line = fixture_line('return {"out": [time.time()]}')
    assert f":{line}" in msg
    assert isinstance(exc.value.report, PurityReport)
    assert "impure_fixture" not in platform.registry.functions


def test_default_mode_warns_and_deploys(fixture_mod):
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=2))
    with pytest.warns(UserWarning, match="wall-clock"):
        platform.deploy(impure_spec(fixture_mod))
    assert "impure_fixture" in platform.registry.functions
    assert not platform.last_verify_report.ok


def test_off_mode_skips_analysis(fixture_mod):
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=2), verify="off")
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # any warning fails the test
        platform.deploy(impure_spec(fixture_mod))
    assert platform.last_verify_report is None


def test_strict_deploy_accepts_clean_app_end_to_end(fixture_mod):
    spec = sdk.declare("clean_fixture", fixture_mod.clean,
                       inputs=("x",), outputs=("out",))
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=2),
                            verify="strict")
    comp = platform.deploy(sdk.single_function_app(spec))
    assert comp.name in platform.registry.compositions
    assert platform.last_verify_report.ok


def test_pure_unsafe_deploys_under_strict(fixture_mod):
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=2),
                            verify="strict")
    platform.deploy(impure_spec(fixture_mod, pure_unsafe=True))
    assert platform.last_verify_report.unsafe == ("impure_fixture",)


# ===========================================================================
# 8. PlatformConfig front door
# ===========================================================================
def test_verify_env_parsed_and_validated():
    assert PlatformConfig.from_env({}).verify is None
    for mode in ("off", "warn", "strict"):
        assert PlatformConfig.from_env(
            {"DANDELION_VERIFY": mode}).verify == mode
    with pytest.raises(DeploymentError, match="DANDELION_VERIFY"):
        PlatformConfig.from_env({"DANDELION_VERIFY": "LOUD"})


def test_verify_field_validated_on_construction():
    with pytest.raises(DeploymentError):
        PlatformConfig(verify="yes")


def test_explicit_kwarg_beats_env(fixture_mod, monkeypatch):
    monkeypatch.setenv("DANDELION_VERIFY", "strict")
    platform = sdk.Platform(node=sdk.NodeSpec(num_slots=2), verify="off")
    assert platform.config.verify == "off"
    platform.deploy(impure_spec(fixture_mod))   # off: no raise, no warn
    with_env = sdk.Platform(node=sdk.NodeSpec(num_slots=2))
    assert with_env.config.verify == "strict"


def test_with_overrides_only_touches_named_fields():
    cfg = PlatformConfig(crossnode=True)
    out = cfg.with_overrides(verify="strict")
    assert out.verify == "strict" and out.crossnode is True
    assert cfg.verify is None              # frozen: original untouched


# ===========================================================================
# 9. the property: verification must not move benchmark bytes
# ===========================================================================
def test_fig10_rows_identical_under_strict_verification(monkeypatch):
    """Analysis is observation-free: running every deploy through the
    strict verifier changes nothing in the fig10 rows (the byte-identity
    contract tools/check_bench_identity.py pins across PRs)."""
    import importlib

    monkeypatch.setenv("FIG10_DURATION_S", "30")
    monkeypatch.delenv("DANDELION_VERIFY", raising=False)
    mod = importlib.import_module("benchmarks.fig10_azure_trace")
    ref = mod.run()
    monkeypatch.setenv("DANDELION_VERIFY", "strict")
    got = mod.run()
    assert got == ref
