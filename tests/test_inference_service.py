"""Serving-on-Dandelion workload invariants.

Pins the contracts the fig13 benchmark and the platform batching engine
rely on:

  * modeled step durations (and therefore latencies/timelines) are
    byte-identical across runs given the same seeds;
  * every KV-cache-carrying MemoryContext is freed exactly once —
    committed bytes return to zero after the last request drains, on a
    single node and across cross-node KV migration (CROSSNODE both
    ways; CI runs this module under both env settings);
  * batching on vs off produces identical token streams (batching may
    only reshape *durations*, never dataflow);
  * WeightStore residency: pinned stores never go cold, keep-alive
    stores release in idle valleys, inflight refcounts protect
    back-to-back decode steps at keepalive 0.
"""
import itertools

import numpy as np
import pytest

from repro.apps.inference_service import (
    LMSpec,
    build_request_composition,
    expected_tokens,
    register_inference_service,
    request_app,
)
from repro.core import (
    BatchStepModel,
    ClusterManager,
    EventLoop,
    FunctionRegistry,
    Item,
    TransferProfile,
    WeightStore,
    WorkerNode,
)

SPEC = LMSpec()


def _platform(*, batch_slots=1, max_batch=16, keepalive_s=0.0, pinned=False,
              seed=1, loop=None):
    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC)
    loop = loop or EventLoop()
    ws = svc.make_weight_store(keepalive_s=keepalive_s, pinned=pinned)
    node = WorkerNode(
        reg, loop=loop, num_slots=6, profiles=svc.profiles,
        batch_slots=batch_slots, batch_model=svc.batch_model,
        max_batch=max_batch, weight_store=ws, seed=seed,
    )
    return reg, svc, loop, node, ws


def _requests(n=6, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        p = int(rng.integers(8, 24))
        d = int(rng.integers(3, 9))
        prompt = (f"req{rid}:".encode() * p)[: 4 * p]
        out.append((0.01 * rid, prompt, p, d))
    return out


def _run(node_or_cm, loop, requests, invoke):
    results = {}
    for t, prompt, p, d in requests:
        comp = build_request_composition(SPEC, prompt_len=p, n_decode=d)

        def done(inv, prompt=prompt):
            assert not inv.failed, inv.failed
            results[prompt] = inv
        loop.after(t, lambda c=comp, pr=prompt, cb=done: invoke(
            c, {"prompt": [Item(pr)]}, cb))
    loop.run()
    return results


def _tokens_of(inv):
    text = inv.outputs["text"][0].data.decode()
    return [int(t) for t in text[len("tok:"):].split(",")]


# ---------------------------------------------------------------- tokens
def test_tokens_match_reference_and_batching_invariant():
    """Identical token streams with the batching engine on, serialized
    (max_batch=1), and absent (batch_slots=0) — and all equal to the
    pure-function reference."""
    reqs = _requests()
    streams = []
    for batch_slots, max_batch in ((1, 16), (1, 1), (0, 16)):
        if batch_slots == 0:
            reg = FunctionRegistry()
            svc = register_inference_service(reg, SPEC)
            loop = EventLoop()
            node = WorkerNode(reg, loop=loop, num_slots=6,
                              profiles=svc.profiles,
                              weight_store=svc.make_weight_store(), seed=1)
        else:
            _, _, loop, node, _ = _platform(
                batch_slots=batch_slots, max_batch=max_batch)
        results = _run(node, loop, reqs, node.invoke)
        streams.append({p: _tokens_of(inv) for p, inv in results.items()})
    assert streams[0] == streams[1] == streams[2]
    for t, prompt, p, d in reqs:
        assert streams[0][prompt] == expected_tokens(prompt, SPEC, d)


# ----------------------------------------------------------- determinism
def test_modeled_durations_deterministic_across_runs():
    def latencies(max_batch):
        _, _, loop, node, _ = _platform(max_batch=max_batch, keepalive_s=0.5)
        results = _run(node, loop, _requests(), node.invoke)
        lats = sorted((p, inv.latency, inv.t_end) for p, inv in results.items())
        points = list(node.tracker.timeline.points)
        return lats, points

    a = latencies(16)
    b = latencies(16)
    assert a == b  # latencies AND the full committed-memory step function
    # batching changes durations, not dataflow: serialized steps differ
    c = latencies(1)
    assert [p for p, _, _ in a[0]] == [p for p, _, _ in c[0]]
    assert a != c


# ------------------------------------------------------- freed exactly once
def test_kv_contexts_freed_exactly_once_single_node():
    _, _, loop, node, ws = _platform(keepalive_s=0.0)
    results = _run(node, loop, _requests(n=8), node.invoke)
    assert len(results) == 8
    # weights released at inflight 0 (keepalive 0) and every KV context
    # freed exactly once: committed bytes return to zero
    assert node.tracker.committed == 0
    assert all(s.inflight == 0 for s in ws._models.values())
    assert ws.summary()["cold_touches"] >= 1


@pytest.mark.parametrize("crossnode", [False, True])
def test_kv_freed_exactly_once_crossnode_migration(crossnode):
    """Decode vertices migrating between nodes stage the KV cache in
    transfer contexts; committed bytes on BOTH nodes must return to zero
    and every cross-node KV edge is charged with real cache bytes."""
    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC)
    loop = EventLoop()
    nodes = []
    for i in range(2):
        nodes.append(WorkerNode(
            reg, loop=loop, num_slots=4, profiles=svc.profiles,
            batch_slots=1, batch_model=svc.batch_model,
            weight_store=svc.make_weight_store(keepalive_s=0.0),
            seed=7 + i, name=f"kv{i}",
        ))
    cm = ClusterManager(nodes, loop, crossnode=crossnode,
                        transfer_profile=TransferProfile())
    if crossnode:
        # force ping-pong placement so every KV edge crosses nodes: the
        # load-based policy happily co-locates a decode chain (cheap),
        # but this test is about the migration mechanics — staging
        # contexts, ownership transfer, byte-exact charging
        flip = itertools.count()
        cm.placer._pick = lambda fn, home: nodes[next(flip) % 2]
    reqs = _requests(n=6, seed=3)
    results = _run(cm, loop, reqs, cm.invoke)
    assert len(results) == len(reqs)
    for t, prompt, p, d in reqs:
        assert _tokens_of(results[prompt]) == expected_tokens(prompt, SPEC, d)
    for n in nodes:
        assert n.tracker.committed == 0, n.name
    if crossnode:
        stats = cm.placer.stats
        assert stats.remote_placements > 0
        assert stats.transfers > 0
        # migrated KV edges move real cache bytes (>= one prompt's cache)
        min_kv = min(p for _, _, p, _ in reqs) * SPEC.kv_bytes_per_token
        assert stats.bytes_total >= min_kv
    else:
        assert cm.placer is None


# ------------------------------------------------------------ weight store
def test_weight_store_keepalive_and_pinning():
    spec = SPEC

    # pinned: committed at bind, never cold, never released
    _, svc, ploop, pnode, pws = _platform(pinned=True)
    assert pnode.tracker.committed == spec.param_bytes
    _run(pnode, ploop, _requests(n=2), pnode.invoke)
    assert pws.summary()["cold_touches"] == 0
    assert pnode.tracker.committed == spec.param_bytes

    # keep-alive: resident through the run, released after the idle gap
    _, _, loop, node, ws = _platform(keepalive_s=0.5)
    _run(node, loop, _requests(n=2), node.invoke)
    assert node.tracker.committed == spec.param_bytes  # still warm
    loop.run(until=loop.now + 1.0)                     # let the reap fire
    assert node.tracker.committed == 0
    # a second burst pays exactly one more cold touch
    _run(node, loop, _requests(n=2, seed=9), node.invoke)
    assert ws.summary()["cold_touches"] == 2


def test_isolated_request_pays_exactly_one_cold_at_keepalive_zero():
    """A single request with no concurrent traffic on a keepalive-0
    store: the refcount release happens AFTER successor decode steps are
    submitted, so the chain holds its weights — one cold touch for the
    whole request, not one per step."""
    _, _, loop, node, ws = _platform(keepalive_s=0.0)
    results = _run(node, loop, _requests(n=1), node.invoke)
    inv = next(iter(results.values()))
    assert ws.summary()["cold_touches"] == 1
    # and the latency reflects ONE weight load, not one per decode step
    cold = ws._models[SPEC.name].param_bytes  # sanity: store is bound
    assert cold == SPEC.param_bytes
    assert inv.latency < 2.0 * node.dispatcher.profiles[
        f"{SPEC.name}_prefill"].cold_setup_s
    assert node.tracker.committed == 0


def test_code_cache_miss_never_bills_resident_weights():
    """The weight store, not the code-cache bit, decides cold_setup_s:
    with a 100% code-miss rate and resident weights, no request after
    the first pays the multi-second weight load."""
    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC)
    loop = EventLoop()
    node = WorkerNode(
        reg, loop=loop, num_slots=6, profiles=svc.profiles,
        batch_slots=1, batch_model=svc.batch_model,
        cache_miss_rate=1.0,      # every submit is a code-cache miss
        weight_store=svc.make_weight_store(keepalive_s=5.0), seed=1,
    )
    results = _run(node, loop, _requests(n=3), node.invoke)
    cold_s = svc.profiles[f"{SPEC.name}_prefill"].cold_setup_s
    lats = sorted(inv.latency for inv in results.values())
    assert lats[-1] > cold_s         # the first request pays the load
    assert lats[0] < 0.5 * cold_s    # the rest never do, despite the
    assert lats[1] < 0.5 * cold_s    # forced 100% code-miss rate


def test_batch_timeout_matches_compute_path():
    """A batchable task whose duration exceeds its vertex timeout fails
    identically with the batching engine on or off (dataflow invariance
    covers outcomes, not just tokens)."""
    from repro.core.dag import Composition

    def run_with(batch_slots):
        reg = FunctionRegistry()
        svc = register_inference_service(reg, SPEC)
        loop = EventLoop()
        node = WorkerNode(
            reg, loop=loop, num_slots=4, profiles=svc.profiles,
            batch_slots=batch_slots, batch_model=svc.batch_model,
            weight_store=svc.make_weight_store(), seed=1,
        )
        c = Composition("tight")
        v = c.compute("d", f"{SPEC.name}_decode", inputs=("kv", "tok"),
                      outputs=("kv", "tok"), timeout_s=1e-6)
        c.bind_input("kv", v["kv"])
        c.bind_input("tok", v["tok"])
        c.bind_output("tok", v["tok"])
        c.validate()
        from repro.apps.inference_service import KVCache
        out = []
        node.invoke(c, {"kv": [Item(KVCache(SPEC.name, "ab", 4,
                                            SPEC.kv_bytes_per_token))],
                        "tok": [Item(1)]},
                    on_done=out.append)
        loop.run()
        return out[0].failed

    on, off = run_with(1), run_with(0)
    assert on is not None and "timeout" in on
    assert off is not None and "timeout" in off


def test_batch_step_model_amortizes():
    m = BatchStepModel(
        flops_per_seq=2.6e9, fixed_bytes=2.6e9, bytes_per_seq=30e6,
        peak_flops=197e12, hbm_bw=819e9, overhead_s=100e-6,
    )
    assert m.step_s(0) == 0.0
    assert m.step_s(16) < 16 * m.step_s(1)      # coalescing wins
    assert m.step_s(16) > m.step_s(1)           # but is not free
    assert m.amortization(16) > 4.0
    # monotone in batch size
    steps = [m.step_s(n) for n in range(1, 33)]
    assert steps == sorted(steps)


def test_weight_cold_rate_prices_hlo_terms():
    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC)
    wc = svc.weight_cold
    assert wc.load_s == pytest.approx(SPEC.param_bytes / 2e9)
    assert wc.hlo_ops == SPEC.hlo_ops_estimate
    assert svc.profiles[f"{SPEC.name}_prefill"].cold_setup_s == pytest.approx(
        wc.total_s)
    # cold start dominates a warm request end-to-end
    assert wc.total_s > 100 * svc.prefill_step_s


# ----------------------------------------------- fast builder / memoization
def test_fast_builder_matches_sdk_compile():
    """``build_request_composition`` (the direct-IR fast builder fig13
    hot-loops over) is field-for-field structurally identical to the SDK
    reference path ``request_app(...).compile()``: same vertex
    declaration order, same edge append order, same bindings and
    adjacency — the contract its docstring states."""
    reg = FunctionRegistry()
    register_inference_service(reg, SPEC)
    for p, d in ((32, 8), (77, 1), (128, 32), (40, 0)):
        fast = build_request_composition(SPEC, prompt_len=p, n_decode=d)
        ref = request_app(SPEC, prompt_len=p, n_decode=d).compile(reg)
        assert fast.name == ref.name
        assert list(fast.vertices) == list(ref.vertices)
        for name in fast.vertices:
            assert fast.vertices[name] == ref.vertices[name], name
        assert fast.edges == ref.edges
        assert fast.input_bindings == ref.input_bindings
        assert fast.output_bindings == ref.output_bindings
        for v in fast.vertices:
            assert fast.in_edges(v) == ref.in_edges(v)
            assert fast.out_edges(v) == ref.out_edges(v)
        fast.validate()


def test_kv_fingerprint_drives_decode_memo_hits():
    """The memoized-decode contract: ``KVCache.fingerprint()`` gives
    decode inputs a stable content identity, so replaying the same
    requests turns every tokenize/prefill/decode/detok call into a
    payload-memo hit — no new misses, identical token streams."""
    reqs = _requests(n=3, seed=5)
    _, _, loop, node, _ = _platform()
    first = _run(node, loop, reqs, node.invoke)
    memo = node.registry.memo
    assert memo is not None
    hits0, misses0 = memo.hits, memo.misses
    assert misses0 > 0                    # first pass populated the memo

    second = _run(node, loop, reqs, node.invoke)
    assert memo.misses == misses0         # full replay: no new misses
    assert memo.hits > hits0
    assert {p: _tokens_of(i) for p, i in first.items()} == \
           {p: _tokens_of(i) for p, i in second.items()}


def test_real_exec_matches_modeled_token_streams():
    """The FIG13_REAL_EXEC contract: dropping the calibrated profiles
    (so engines take the real measured cold-start path and actually run
    the registered payloads) may change durations, never dataflow —
    token streams and output text match the modeled default exactly."""
    reqs = _requests()
    _, _, loop, node, _ = _platform()                    # modeled default
    modeled = _run(node, loop, reqs, node.invoke)

    reg = FunctionRegistry()
    svc = register_inference_service(reg, SPEC)
    loop2 = EventLoop()
    real = WorkerNode(
        reg, loop=loop2, num_slots=6, profiles=None,
        batch_slots=1, batch_model=svc.batch_model, max_batch=16,
        weight_store=svc.make_weight_store(), seed=1,
    )
    real_res = _run(real, loop2, reqs, real.invoke)
    assert {p: _tokens_of(i) for p, i in modeled.items()} == \
           {p: _tokens_of(i) for p, i in real_res.items()}
    for t, prompt, p, d in reqs:
        assert _tokens_of(real_res[prompt]) == expected_tokens(prompt, SPEC, d)
