"""``Timeline.average`` historical-window contract.

``record`` maintains a per-point cumulative integral (``_cum``) so a
historical query (``t_end`` before the last recorded point — e.g. a
measurement window read after draining stragglers) is an O(log n)
bisect. These tests pin the identity the sim.py docstring states:
``_integral_until`` is *bit-identical* to the retained O(n) reference
walk ``_scan_integral``, and both match an independent brute-force
rebuild of the step function — under property-drawn step functions and
query points, including ties at recorded times and queries beyond the
last point.
"""
from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.sim import Timeline


def _brute_force(points, t_end):
    """Independent re-derivation: integral of the step function defined
    by ``points`` over [points[0].t, t_end]."""
    total = 0.0
    for (t0, v), nxt in zip(points, points[1:] + [None]):
        if t0 >= t_end:
            break
        t1 = t_end if nxt is None else min(nxt[0], t_end)
        total += v * (t1 - t0)
    return total


def _build(deltas, values):
    tl = Timeline()
    t = 0.0
    for dt, v in zip(deltas, values):
        t += dt
        tl.record(t, v)
    return tl, t


@settings(max_examples=30)
@given(
    st.lists(st.floats(0.0, 3.0), min_size=1, max_size=40),
    st.lists(st.floats(0.0, 100.0), min_size=40, max_size=40),
    st.floats(0.0, 1.0),
)
def test_streaming_cum_matches_scan_and_bruteforce(deltas, values, frac):
    tl, t_last = _build(deltas, values)
    # historical, boundary, and past-the-end query points — plus every
    # recorded time exactly (the bisect tie-break path)
    queries = [frac * t_last, t_last, t_last + 1.0]
    queries += [t for t, _ in tl.points]
    for t_end in queries:
        if t_end < t_last:
            fast = tl._integral_until(t_end)
            assert fast == tl._scan_integral(t_end)          # bit-identical
            assert abs(fast - _brute_force(tl.points, t_end)) <= 1e-9 * (
                1.0 + abs(fast)
            )
        # average() must agree with a from-scratch reference either way
        span = t_end - tl.points[0][0]
        if span > 0:
            want = _brute_force(
                tl.points, min(t_end, t_last)
            ) + (tl.last_value * (t_end - t_last) if t_end > t_last else 0.0)
            got = tl.average(t_end)
            assert abs(got - want / span) <= 1e-9 * (1.0 + abs(got))


def test_average_excludes_points_past_the_window():
    """The fig10/fig13 pattern: drain stragglers past the measurement
    window, then read the window average — later points must not leak
    into it, and the streaming answer equals the reference walk's."""
    tl = Timeline()
    for t, v in ((0.0, 0.0), (1.0, 100.0), (4.0, 50.0), (10.0, 0.0),
                 (12.0, 400.0), (13.0, 0.0)):
        tl.record(t, v)
    window = 10.0
    assert tl._integral_until(window) == tl._scan_integral(window)
    # 1..4 at 100 plus 4..10 at 50, over a 10 s window
    assert tl.average(window) == (3 * 100.0 + 6 * 50.0) / 10.0


def test_historical_average_requires_points():
    tl = Timeline(keep_points=False)
    for t, v in ((0.0, 1.0), (5.0, 2.0)):
        tl.record(t, v)
    assert tl.average(5.0) == (5.0 * 1.0) / 5.0   # streaming path still fine
    try:
        tl.average(2.5)                            # historical needs points
    except ValueError as e:
        assert "keep_points" in str(e)
    else:
        raise AssertionError("expected ValueError for historical window")
