"""Training substrate: optimization progress, grad-accum equivalence,
compression properties, checkpoint fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.config.parallel import ParallelPlan
from repro.configs import get_smoke
from repro.models.model import build
from repro.training import compress
from repro.training.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.data import make_batch
from repro.training.train_step import build_train_step, init_train_state
from repro.config.shapes import ShapeConfig

RNG = jax.random.PRNGKey(0)
SHAPE = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")


def _setup(arch="granite-8b", plan=None):
    cfg = get_smoke(arch)
    api = build(cfg)
    plan = plan or ParallelPlan(remat="none").restrict_to(("data", "model"))
    step = jax.jit(build_train_step(api, plan, lr=1e-2, warmup_steps=2, total_steps=50))
    state = init_train_state(api, RNG, plan)
    return cfg, api, step, state


def _batch(cfg, step_i):
    b = make_batch(cfg, SHAPE, step_i)
    return jax.tree_util.tree_map(jnp.asarray, b)


def test_loss_decreases():
    cfg, api, step, state = _setup()
    first = last = None
    for i in range(20):
        state, metrics = step(state, _batch(cfg, 0))  # same batch: must overfit
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.5, f"no optimization progress: {first} -> {last}"


def test_grad_accum_matches_full_batch():
    cfg = get_smoke("granite-8b")
    api = build(cfg)
    p1 = ParallelPlan(remat="none", grad_accum=1).restrict_to(("data",))
    p2 = ParallelPlan(remat="none", grad_accum=2).restrict_to(("data",))
    s1 = jax.jit(build_train_step(api, p1, lr=1e-2))
    s2 = jax.jit(build_train_step(api, p2, lr=1e-2))
    st0 = init_train_state(api, RNG, p1)
    b = _batch(cfg, 0)
    st1, m1 = s1(st0, b)
    st0b = init_train_state(api, RNG, p2)
    st2, m2 = s2(st0b, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    l1 = jax.tree_util.tree_leaves(st1.params)
    l2 = jax.tree_util.tree_leaves(st2.params)
    for a, b_ in zip(l1, l2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32),
            rtol=2e-2, atol=2e-2,
        )


def test_remat_matches_no_remat():
    cfg = get_smoke("glm4-9b")
    api = build(cfg)
    b = _batch(cfg, 0)
    p_none = ParallelPlan(remat="none").restrict_to(())
    p_full = ParallelPlan(remat="full").restrict_to(())
    loss_n, g_n = jax.jit(jax.value_and_grad(lambda p: api.train_loss(p, b, remat="none")))(
        init_train_state(api, RNG, p_none).params
    ), None
    params = init_train_state(api, RNG, p_none).params
    l1, g1 = jax.jit(jax.value_and_grad(lambda p: api.train_loss(p, b, remat="none")))(params)
    l2, g2 = jax.jit(jax.value_and_grad(lambda p: api.train_loss(p, b, remat="full")))(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-3)
    for a, c in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32), rtol=2e-2, atol=1e-2
        )


# ----------------------------------------------------------- compression
@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,), jnp.float32) * (seed % 7 + 1)
    q, scale = compress.quantize(x)
    err = np.abs(np.asarray(compress.dequantize(q, scale) - x))
    assert np.all(err <= float(scale) * 0.5 + 1e-6)


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated dequantized sum tracks the true
    gradient sum (residual stays bounded)."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros((32,), jnp.float32)
    true_sum = np.zeros((32,))
    deq_sum = np.zeros((32,))
    for t in range(200):
        g = jnp.asarray(rng.normal(size=32) * 0.01, jnp.float32)
        q, scale, residual = compress.compress_with_feedback(g, residual)
        deq_sum += np.asarray(compress.dequantize(q, scale))
        true_sum += np.asarray(g)
    # total drift equals the final residual (telescoping), which is bounded
    drift = np.abs(true_sum - deq_sum)
    assert np.all(drift <= np.abs(np.asarray(residual)) + 1e-5)


def test_compressed_training_still_converges():
    plan = ParallelPlan(remat="none", compress_grads=True).restrict_to(())
    cfg, api, step, state = _setup(plan=plan)
    first = last = None
    for i in range(20):
        state, metrics = step(state, _batch(cfg, 0))
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.3


# ----------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, api, step, state = _setup()
    for i in range(3):
        state, _ = step(state, _batch(cfg, i))
    save_checkpoint(str(tmp_path), 3, state)
    assert latest_step(str(tmp_path)) == 3

    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    restored, step_no = restore_checkpoint(str(tmp_path), None, abstract)
    assert step_no == 3
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # resuming produces bitwise-identical trajectories
    s_a, _ = step(state, _batch(cfg, 3))
    s_b, _ = step(restored, _batch(cfg, 3))
    for a, b in zip(jax.tree_util.tree_leaves(s_a.params), jax.tree_util.tree_leaves(s_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    cfg, api, step, state = _setup()
    path = save_checkpoint(str(tmp_path), 1, state)
    shard = os.path.join(path, "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad\xbe\xef")
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    with pytest.raises(IOError, match="corrupt"):
        restore_checkpoint(str(tmp_path), 1, abstract)


def test_async_checkpointer_gc_and_wait(tmp_path):
    cfg, api, step, state = _setup()
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for i in range(4):
        ck.save(i, state)
    ck.close()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000003"
